"""Data pipeline: deterministic sharded token streams.

Design for 1000+ nodes (DESIGN.md §6):
  * the dataset is a flat token array (memory-mapped .npy in production;
    synthetic generator for tests) carved into fixed-size sequences;
  * step -> sequence assignment is a *pure function* of (step, global batch,
    host count, seed) — any host can recompute any shard, which is what makes
    straggler work-stealing and elastic re-meshing possible without a
    coordinator;
  * a background prefetch thread keeps `prefetch` batches ready.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

__all__ = ["DataConfig", "TokenDataset", "synthetic_tokens", "HostDataLoader"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    vocab: int = 32000


def synthetic_tokens(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Zipf-ish synthetic corpus (deterministic)."""
    rng = np.random.default_rng(seed)
    z = rng.zipf(1.3, size=n_tokens).astype(np.int64)
    return (z % vocab).astype(np.int32)


class TokenDataset:
    """Flat token array -> (seq_len+1)-sized samples, shuffled per epoch by a
    stateless permutation."""

    def __init__(self, tokens: np.ndarray, cfg: DataConfig) -> None:
        self.tokens = tokens
        self.cfg = cfg
        self.n_samples = (tokens.shape[0] - 1) // cfg.seq_len

    @classmethod
    def mmap(cls, path: str, cfg: DataConfig) -> "TokenDataset":
        return cls(np.load(path, mmap_mode="r"), cfg)

    def _perm_index(self, epoch: int, i: int) -> int:
        """Stateless pseudo-random permutation (multiplicative hash walk)."""
        n = self.n_samples
        h = (i * 0x9E3779B97F4A7C15 + epoch * 2654435761
             + self.cfg.seed) % (1 << 64)
        return int(h % n)

    def sample(self, epoch: int, i: int) -> np.ndarray:
        j = self._perm_index(epoch, i)
        s = self.cfg.seq_len
        chunk = np.asarray(self.tokens[j * s: j * s + s + 1])
        return chunk

    def batch_for_step(self, step: int, host: int, n_hosts: int):
        """Deterministic (tokens, labels) for this host's slice of the global
        batch at `step`.  Pure function of its arguments."""
        gb = self.cfg.global_batch
        per_host = gb // n_hosts
        base = step * gb
        epoch = base // max(self.n_samples, 1)
        idx = [base + host * per_host + k for k in range(per_host)]
        rows = np.stack([self.sample(epoch, i % self.n_samples) for i in idx])
        return rows[:, :-1].astype(np.int32), rows[:, 1:].astype(np.int32)


class HostDataLoader:
    """Background prefetcher over TokenDataset.batch_for_step."""

    def __init__(self, ds: TokenDataset, host: int, n_hosts: int,
                 start_step: int = 0, prefetch: int = 2) -> None:
        self.ds = ds
        self.host = host
        self.n_hosts = n_hosts
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.ds.batch_for_step(step, self.host, self.n_hosts)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
