"""repro.data substrate."""
