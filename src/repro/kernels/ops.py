"""Public jit'd wrappers over the Pallas kernels with impl dispatch.

impl:
  * "ref"               — pure-jnp oracle (default on CPU; what the engine uses)
  * "pallas_interpret"  — Pallas kernel body executed in interpret mode (CI)
  * "pallas"            — compiled Pallas (real TPU)
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .plr_lookup import plr_lookup_pallas
from .bounded_search import bounded_search_pallas
from .bloom_probe import bloom_probe_pallas, bloom_probe_stack_pallas
from .sstable_search import sstable_search_pallas

__all__ = ["plr_lookup", "bounded_search", "bloom_probe",
           "bloom_probe_stack", "sstable_search"]


def _mode(impl: str) -> tuple[bool, bool]:
    if impl == "ref":
        return False, False
    if impl == "pallas_interpret":
        return True, True
    if impl == "pallas":
        return True, False
    raise ValueError(impl)


def plr_lookup(starts, slopes, icepts, nseg, probes, n_max, impl="ref",
               block_b: int = 256):
    use_pallas, interp = _mode(impl)
    if not use_pallas:
        return _ref.plr_lookup_ref(starts, slopes, icepts,
                                   jnp.asarray(nseg, jnp.int32), probes,
                                   jnp.asarray(n_max, jnp.int32))
    return plr_lookup_pallas(starts, slopes, icepts, nseg, probes, n_max,
                             block_b=block_b, interpret=interp)


def bounded_search(keys, pos, probes, n, delta: int = 8, impl="ref",
                   block_b: int = 256):
    use_pallas, interp = _mode(impl)
    if not use_pallas:
        return _ref.bounded_search_ref(keys, pos, probes,
                                       jnp.asarray(n, jnp.int32), delta)
    return bounded_search_pallas(keys, pos, probes, n, delta=delta,
                                 block_b=block_b, interpret=interp)


def bloom_probe(bits, probes, n_words, k_hashes: int = 7, impl="ref",
                block_b: int = 256):
    use_pallas, interp = _mode(impl)
    if not use_pallas:
        return _ref.bloom_probe_kernel_ref(bits, probes, k_hashes,
                                           jnp.asarray(n_words))
    return bloom_probe_pallas(bits, probes, n_words, k_hashes=k_hashes,
                              block_b=block_b, interpret=interp)


def bloom_probe_stack(bits, n_words, probes, k_hashes: int = 7, impl="ref",
                      block_b: int = 256):
    """Filter plane: (L, W) stacked per-level filters -> (L, B) maybe-mask."""
    use_pallas, interp = _mode(impl)
    if not use_pallas:
        return _ref.bloom_probe_stack_ref(bits, jnp.asarray(n_words),
                                          probes, k_hashes)
    return bloom_probe_stack_pallas(bits, n_words, probes, k_hashes=k_hashes,
                                    block_b=block_b, interpret=interp)


def sstable_search(fences, keys, probes, n_blocks, n, block_records: int = 256,
                   impl="ref", block_b: int = 256):
    use_pallas, interp = _mode(impl)
    if not use_pallas:
        return _ref.sstable_search_ref(fences, keys, probes,
                                       jnp.asarray(n_blocks, jnp.int32),
                                       jnp.asarray(n, jnp.int32),
                                       block_records)
    return sstable_search_pallas(fences, keys, probes, n_blocks, n,
                                 block_records=block_records,
                                 block_b=block_b, interpret=interp)
