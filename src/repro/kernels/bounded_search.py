"""Pallas TPU kernel: delta-window probe (LoadChunk + LocateKey, Fig. 6 5-6).

This is the TPU-native analogue of Bourbon's small chunk read: instead of a
4KB disk block, each probe DMAs a (2*delta+3)-record window around the PLR
prediction from the HBM-resident key array into VMEM and does a vectorized
compare.  The window is the paper's error-bound guarantee made physical:
delta bounds the bytes moved per lookup.

The sorted key array stays in ANY/HBM memory space; per-probe windows are
fetched with dynamic slices inside the kernel (async copy on real TPU,
emulated in interpret mode).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bounded_search_pallas"]


def _bounded_kernel(n_ref, pos_ref, probes_ref, keys_ref, idx_ref, found_ref,
                    *, delta: int, win: int):
    C = keys_ref.shape[0]
    n = n_ref[0]
    BB = pos_ref.shape[0]

    def body(i, _):
        pos = pos_ref[i]
        probe = probes_ref[i]
        start = jnp.clip(pos - (delta + 1), 0, jnp.maximum(C - win, 0))
        window = keys_ref[pl.dslice(start, win)]   # bounded DMA
        eq = window == probe
        hit = jnp.any(eq)
        rel = jnp.argmax(eq)
        idx = (start + rel).astype(jnp.int32)
        idx_ref[i] = idx
        found_ref[i] = hit & (idx < n)
        return 0

    jax.lax.fori_loop(0, BB, body, 0)


@partial(jax.jit, static_argnames=("delta", "block_b", "interpret"))
def bounded_search_pallas(keys, pos, probes, n, delta: int = 8,
                          block_b: int = 256, interpret: bool = True):
    """Matches kernels.ref.bounded_search_ref (idx may differ only when the
    same key appears at the window edge twice — keys are unique, so exact)."""
    B = probes.shape[0]
    C = keys.shape[0]
    assert B % block_b == 0
    win = 2 * delta + 3
    # round window to a lane-friendly multiple of 8 (int64 sublane packing)
    win = -(-win // 8) * 8
    win = min(win, C)
    grid = (B // block_b,)
    n_a = jnp.asarray(n, jnp.int32).reshape(1)
    idx, found = pl.pallas_call(
        partial(_bounded_kernel, delta=delta, win=win),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.bool_)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),     # keys stay in HBM
        ],
        out_specs=(pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,))),
        interpret=interpret,
    )(n_a, pos, probes, keys)
    return idx, found
