"""Pallas TPU kernel: vectorized bloom-filter probe (SearchFB, Fig. 6 step 4).

k double-hash probes per key, unrolled; the packed filter words live in VMEM
(a per-file filter at 10 bits/key for <=256K records is <=320KB).  Gathers are
word-indexed loads from the VMEM-resident filter.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bloom_probe_pallas"]


def _bloom_kernel(nw_ref, bits_ref, probes_ref, out_ref, *, k_hashes: int):
    probes = probes_ref[...]
    bits = bits_ref[...]
    m = nw_ref[0].astype(jnp.uint64) * jnp.uint64(64)
    kk = probes.astype(jnp.uint64)
    h1 = kk * jnp.uint64(0x9E3779B97F4A7C15)
    h1 = h1 ^ (h1 >> jnp.uint64(29))
    h2 = (kk * jnp.uint64(0xC2B2AE3D27D4EB4F)) | jnp.uint64(1)
    h2 = h2 ^ (h2 >> jnp.uint64(31))
    maybe = jnp.ones(probes.shape, jnp.bool_)
    W = bits.shape[0]
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2) % m
        widx = jnp.clip((pos >> jnp.uint64(6)).astype(jnp.int32), 0, W - 1)
        word = jnp.take(bits, widx, axis=0)
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    out_ref[...] = maybe


@partial(jax.jit, static_argnames=("k_hashes", "block_b", "interpret"))
def bloom_probe_pallas(bits, probes, n_words, k_hashes: int = 7,
                       block_b: int = 256, interpret: bool = True):
    """Matches core.bloom.bloom_probe_ref for a shared (W,) filter."""
    B = probes.shape[0]
    W = bits.shape[0]
    assert B % block_b == 0
    nw = jnp.asarray(n_words, jnp.int32).reshape(1)
    return pl.pallas_call(
        partial(_bloom_kernel, k_hashes=k_hashes),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.bool_),
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((W,), lambda i: (0,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        interpret=interpret,
    )(nw, bits, probes)
