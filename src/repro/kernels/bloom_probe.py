"""Pallas TPU kernel: vectorized bloom-filter probe (SearchFB, Fig. 6 step 4).

k double-hash probes per key, unrolled; the packed filter words live in VMEM
(a per-file filter at 10 bits/key for <=256K records is <=320KB).  Gathers are
word-indexed loads from the VMEM-resident filter.

Two entry points:

* ``bloom_probe_pallas`` — one shared (W,) filter, (B,) probes -> (B,) maybe.
* ``bloom_probe_stack_pallas`` — a padded (L, W) stack of per-level filters
  probed by the whole batch at once -> (L, B) maybe-mask.  One kernel call
  covers every level ahead of the PLR descent; a level with ``n_words == 0``
  has no filter and yields all-True (never prune without evidence).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bloom_probe_pallas", "bloom_probe_stack_pallas"]


def _hash_pair(probes):
    kk = probes.astype(jnp.uint64)
    h1 = kk * jnp.uint64(0x9E3779B97F4A7C15)
    h1 = h1 ^ (h1 >> jnp.uint64(29))
    h2 = (kk * jnp.uint64(0xC2B2AE3D27D4EB4F)) | jnp.uint64(1)
    h2 = h2 ^ (h2 >> jnp.uint64(31))
    return h1, h2


def _bloom_kernel(nw_ref, bits_ref, probes_ref, out_ref, *, k_hashes: int):
    probes = probes_ref[...]
    bits = bits_ref[...]
    m = nw_ref[0].astype(jnp.uint64) * jnp.uint64(64)
    h1, h2 = _hash_pair(probes)
    maybe = jnp.ones(probes.shape, jnp.bool_)
    W = bits.shape[0]
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2) % m
        widx = jnp.clip((pos >> jnp.uint64(6)).astype(jnp.int32), 0, W - 1)
        word = jnp.take(bits, widx, axis=0)
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    out_ref[...] = maybe


@partial(jax.jit, static_argnames=("k_hashes", "block_b", "interpret"))
def bloom_probe_pallas(bits, probes, n_words, k_hashes: int = 7,
                       block_b: int = 256, interpret: bool = True):
    """Matches core.bloom.bloom_probe_ref for a shared (W,) filter.

    Arbitrary batch sizes are supported: the probe batch is padded up to a
    multiple of ``block_b`` inside this wrapper (padded lanes are probed and
    discarded — the grid never sees a ragged block).
    """
    B = probes.shape[0]
    W = bits.shape[0]
    pad = (-B) % block_b
    if pad:
        probes = jnp.concatenate(
            [probes, jnp.zeros((pad,), probes.dtype)])
    Bp = B + pad
    nw = jnp.asarray(n_words, jnp.int32).reshape(1)
    out = pl.pallas_call(
        partial(_bloom_kernel, k_hashes=k_hashes),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.bool_),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((W,), lambda i: (0,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        interpret=interpret,
    )(nw, bits, probes)
    return out[:B] if pad else out


def _bloom_stack_kernel(nw_ref, bits_ref, probes_ref, out_ref, *,
                        k_hashes: int):
    probes = probes_ref[...]
    bits = bits_ref[0]                      # this level's (W,) filter words
    nw = nw_ref[0]
    no_filter = nw == 0
    m = jnp.maximum(nw, 1).astype(jnp.uint64) * jnp.uint64(64)
    h1, h2 = _hash_pair(probes)
    maybe = jnp.ones(probes.shape, jnp.bool_)
    W = bits.shape[0]
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2) % m
        widx = jnp.clip((pos >> jnp.uint64(6)).astype(jnp.int32), 0, W - 1)
        word = jnp.take(bits, widx, axis=0)
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    out_ref[0, :] = maybe | no_filter


@partial(jax.jit, static_argnames=("k_hashes", "block_b", "interpret"))
def bloom_probe_stack_pallas(bits, n_words, probes, k_hashes: int = 7,
                             block_b: int = 256, interpret: bool = True):
    """Probe the whole batch against a stacked (L, W) filter plane.

    bits: (L, W) uint64 — per-level filter words, width-padded to a common W.
    n_words: (L,) int32 — each level's *build-time* word count (the hash
    modulus); 0 marks a level with no filter, which yields all-True.
    probes: (B,) int64.  Returns (L, B) bool: True = maybe present at level.
    """
    L, W = bits.shape
    B = probes.shape[0]
    pad = (-B) % block_b
    if pad:
        probes = jnp.concatenate(
            [probes, jnp.zeros((pad,), probes.dtype)])
    Bp = B + pad
    nw = jnp.asarray(n_words, jnp.int32)
    out = pl.pallas_call(
        partial(_bloom_stack_kernel, k_hashes=k_hashes),
        out_shape=jax.ShapeDtypeStruct((L, Bp), jnp.bool_),
        grid=(L, Bp // block_b),
        in_specs=[
            pl.BlockSpec((1,), lambda li, bi: (li,)),
            pl.BlockSpec((1, W), lambda li, bi: (li, 0)),
            pl.BlockSpec((block_b,), lambda li, bi: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, block_b), lambda li, bi: (li, bi)),
        interpret=interpret,
    )(nw, bits, probes)
    return out[:, :B] if pad else out
