"""Pallas TPU kernel: batched PLR inference (ModelLookup, paper Fig. 6 step 3).

Per probe: bisect the segment-start array (resident in VMEM — a file model is
a few KB), then one FMA, then clamp.  Probes are tiled over the grid; the
model arrays are broadcast to every grid step.

TPU adaptation notes (DESIGN.md §2): key math is f64 — on TPU v5e 64-bit is
emulated by Mosaic, acceptable for this non-MXU lookup path; the segment
bisect uses gather steps over a VMEM-resident vector.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["plr_lookup_pallas"]


def _plr_kernel(nseg_ref, nmax_ref, starts_ref, slopes_ref, icepts_ref,
                probes_ref, out_ref, *, steps: int):
    probes = probes_ref[...]                      # (BB,) int64
    starts = starts_ref[...]                      # (S,) f64
    nseg = jnp.maximum(nseg_ref[0], 1)
    p = probes.astype(jnp.float64)

    S = starts.shape[0]
    lo = jnp.zeros(probes.shape, jnp.int32)
    hi = jnp.broadcast_to(nseg.astype(jnp.int32), probes.shape)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kv = jnp.take(starts, jnp.clip(mid, 0, S - 1), axis=0)
        go_right = kv <= p                        # bisect_right
        lo2 = jnp.where(go_right, mid + 1, lo)
        hi2 = jnp.where(go_right, hi, mid)
        return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    seg = jnp.maximum(lo - 1, 0)
    slope = jnp.take(slopes_ref[...], seg, axis=0)
    icept = jnp.take(icepts_ref[...], seg, axis=0)
    pos = slope * p + icept
    nmax = nmax_ref[0]
    out_ref[...] = jnp.clip(jnp.round(pos).astype(jnp.int32), 0,
                            jnp.maximum(nmax - 1, 0))


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def plr_lookup_pallas(starts, slopes, icepts, nseg, probes, n_max,
                      block_b: int = 256, interpret: bool = True):
    """Matches kernels.ref.plr_lookup_ref exactly."""
    B = probes.shape[0]
    S = starts.shape[0]
    assert B % block_b == 0, (B, block_b)
    steps = max(1, math.ceil(math.log2(S + 1)))
    grid = (B // block_b,)
    nseg_a = jnp.asarray(nseg, jnp.int32).reshape(1)
    nmax_a = jnp.asarray(n_max, jnp.int32).reshape(1)
    return pl.pallas_call(
        partial(_plr_kernel, steps=steps),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # nseg (scalar prefetch)
            pl.BlockSpec((1,), lambda i: (0,)),       # nmax
            pl.BlockSpec((S,), lambda i: (0,)),       # starts, whole model in VMEM
            pl.BlockSpec((S,), lambda i: (0,)),       # slopes
            pl.BlockSpec((S,), lambda i: (0,)),       # icepts
            pl.BlockSpec((block_b,), lambda i: (i,)),  # probe tile
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        interpret=interpret,
    )(nseg_a, nmax_a, starts, slopes, icepts, probes)
