"""Pallas TPU kernels for Bourbon's lookup hot path + jnp oracles.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated against
ref.py in interpret mode; ops.py is the dispatching public API.
"""

from .ops import (plr_lookup, bounded_search, bloom_probe,
                  bloom_probe_stack, sstable_search)

__all__ = ["plr_lookup", "bounded_search", "bloom_probe",
           "bloom_probe_stack", "sstable_search"]
