"""Pure-jnp oracles for every kernel in this package.

These are the contracts the Pallas kernels must match bit-for-bit (exact
integer outputs; float64 position math).  The engine (core/engine.py) calls
these on CPU; on TPU the ops.py wrappers dispatch to the Pallas versions.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["plr_lookup_ref", "bounded_search_ref", "bloom_probe_kernel_ref",
           "bloom_probe_stack_ref", "sstable_search_ref"]


def _bisect(keys: jnp.ndarray, probes: jnp.ndarray, hi0: jnp.ndarray,
            side: str) -> jnp.ndarray:
    """Vectorized bisect of (B,) probes into a single sorted (N,) array."""
    N = keys.shape[0]
    steps = max(1, math.ceil(math.log2(N + 1)))
    lo = jnp.zeros(probes.shape, jnp.int32)
    hi = jnp.broadcast_to(hi0.astype(jnp.int32), probes.shape)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kv = keys[jnp.clip(mid, 0, N - 1)]
        go_right = (kv < probes) if side == "left" else (kv <= probes)
        lo2 = jnp.where(go_right, mid + 1, lo)
        hi2 = jnp.where(go_right, hi, mid)
        return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def plr_lookup_ref(starts: jnp.ndarray, slopes: jnp.ndarray,
                   icepts: jnp.ndarray, nseg: jnp.ndarray,
                   probes: jnp.ndarray, n_max: jnp.ndarray) -> jnp.ndarray:
    """ModelLookup: segment bisect_right + FMA -> clamped int32 position.

    starts/slopes/icepts: (S,) f64 (+inf padded); nseg: () int32;
    probes: (B,) int64; n_max: () int32 (file record count).
    """
    p = probes.astype(jnp.float64)
    seg = _bisect(starts, p, jnp.maximum(nseg, 1), side="right") - 1
    seg = jnp.maximum(seg, 0)
    pos = slopes[seg] * p + icepts[seg]
    return jnp.clip(jnp.round(pos).astype(jnp.int32), 0,
                    jnp.maximum(n_max - 1, 0))


def bounded_search_ref(keys: jnp.ndarray, pos: jnp.ndarray,
                       probes: jnp.ndarray, n: jnp.ndarray,
                       delta: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """LoadChunk + LocateKey: probe the delta-window around predicted pos.

    keys: (C,) int64 sorted (+SENTINEL pad); pos: (B,) int32; n: () int32.
    Returns (idx (B,) int32, found (B,) bool).
    """
    C = keys.shape[0]
    offs = jnp.arange(-(delta + 1), delta + 2, dtype=jnp.int32)
    win_idx = jnp.clip(pos[:, None] + offs[None, :], 0, C - 1)
    win = keys[win_idx]
    eq = win == probes[:, None]
    found = jnp.any(eq, axis=-1)
    rel = jnp.argmax(eq, axis=-1)
    idx = win_idx[jnp.arange(probes.shape[0]), rel]
    found = found & (idx < n)
    return idx.astype(jnp.int32), found


def bloom_probe_kernel_ref(bits: jnp.ndarray, probes: jnp.ndarray,
                           k_hashes: int, n_words: jnp.ndarray) -> jnp.ndarray:
    """Shared-filter bloom probe (same math as core.bloom.bloom_probe_ref)."""
    from repro.core.bloom import bloom_probe_ref
    return bloom_probe_ref(bits, probes, k_hashes, n_words=n_words)


def bloom_probe_stack_ref(bits: jnp.ndarray, n_words: jnp.ndarray,
                          probes: jnp.ndarray,
                          k_hashes: int) -> jnp.ndarray:
    """Filter-plane probe: (L, W) stacked filters x (B,) probes -> (L, B).

    ``n_words[l] == 0`` marks a level with no filter (all-True row); the
    hash modulus is each level's build-time word count, never the padded W.
    """
    L, W = bits.shape
    nw = jnp.asarray(n_words, jnp.int32)
    m = jnp.maximum(nw, 1).astype(jnp.uint64)[:, None] * jnp.uint64(64)
    kk = probes.astype(jnp.uint64)
    h1 = kk * jnp.uint64(0x9E3779B97F4A7C15)
    h1 = h1 ^ (h1 >> jnp.uint64(29))
    h2 = (kk * jnp.uint64(0xC2B2AE3D27D4EB4F)) | jnp.uint64(1)
    h2 = h2 ^ (h2 >> jnp.uint64(31))
    maybe = jnp.ones((L, probes.shape[0]), bool)
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2)[None, :] % m
        widx = jnp.clip((pos >> jnp.uint64(6)).astype(jnp.int32), 0, W - 1)
        word = jnp.take_along_axis(bits, widx, axis=1)
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    return maybe | (nw == 0)[:, None]


def sstable_search_ref(fences: jnp.ndarray, keys: jnp.ndarray,
                       probes: jnp.ndarray, n_blocks: jnp.ndarray,
                       n: jnp.ndarray, block_records: int
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline path: SearchIB (fence bisect) + SearchDB (in-block bisect).

    fences: (NB,) int64; keys: (C,) int64; probes: (B,) int64.
    Returns (idx (B,) int32, found (B,) bool).
    """
    C = keys.shape[0]
    blk = _bisect(fences, probes, jnp.maximum(n_blocks, 1), side="right") - 1
    blk = jnp.maximum(blk, 0)
    lo = blk * block_records
    hi = jnp.minimum(lo + block_records, n)
    # bisect within [lo, hi)
    steps = max(1, math.ceil(math.log2(block_records + 1)))
    lo_ = lo.astype(jnp.int32)
    hi_ = hi.astype(jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kv = keys[jnp.clip(mid, 0, C - 1)]
        go_right = kv < probes
        lo2 = jnp.where(go_right, mid + 1, lo)
        hi2 = jnp.where(go_right, hi, mid)
        return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

    idx, _ = jax.lax.fori_loop(0, steps, body, (lo_, hi_))
    kv = keys[jnp.clip(idx, 0, C - 1)]
    found = (idx < n) & (kv == probes)
    return idx.astype(jnp.int32), found
