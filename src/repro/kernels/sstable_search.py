"""Pallas TPU kernel: baseline sstable search (SearchIB + SearchDB).

The WiscKey binary-search path as one kernel: fence keys (index block) are
VMEM-resident; the in-block bisect then touches one block_records-sized
region of the HBM key array per probe via a bounded dynamic-slice load — the
analogue of LevelDB loading one data block.

This kernel exists to make the baseline/model comparison fair on TPU: both
paths pay one bounded HBM->VMEM fetch; the model path's window (2*delta+3)
is ~10x smaller than a 256-record block, which is exactly the paper's
LoadData reduction (Fig. 8).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sstable_search_pallas"]


def _search_kernel(meta_ref, fences_ref, probes_ref, keys_ref, idx_ref,
                   found_ref, *, block_records: int, fence_steps: int):
    n_blocks = jnp.maximum(meta_ref[0], 1)
    n = meta_ref[1]
    fences = fences_ref[...]
    NB = fences.shape[0]
    probes = probes_ref[...]
    BB = probes.shape[0]

    # SearchIB: bisect_right over fences (vectorized across the probe tile)
    lo = jnp.zeros(probes.shape, jnp.int32)
    hi = jnp.broadcast_to(n_blocks.astype(jnp.int32), probes.shape)

    def fence_body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kv = jnp.take(fences, jnp.clip(mid, 0, NB - 1), axis=0)
        go_right = kv <= probes
        lo2 = jnp.where(go_right, mid + 1, lo)
        hi2 = jnp.where(go_right, hi, mid)
        return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

    lo, _ = jax.lax.fori_loop(0, fence_steps, fence_body, (lo, hi))
    blk = jnp.maximum(lo - 1, 0)

    # SearchDB: per-probe block fetch + in-block bisect
    C = keys_ref.shape[0]
    in_steps = max(1, math.ceil(math.log2(block_records + 1)))

    def body(i, _):
        probe = probes_ref[i]
        b = blk[i]
        start = jnp.clip(b * block_records, 0, jnp.maximum(C - block_records, 0))
        block = keys_ref[pl.dslice(start, block_records)]
        lo = jnp.int32(0)
        hi = jnp.minimum(jnp.int32(block_records), n - start)

        def bs(_, lohi):
            lo, hi = lohi
            active = lo < hi
            mid = (lo + hi) >> 1
            kv = jnp.take(block, jnp.clip(mid, 0, block_records - 1), axis=0)
            go_right = kv < probe
            lo2 = jnp.where(go_right, mid + 1, lo)
            hi2 = jnp.where(go_right, hi, mid)
            return (jnp.where(active, lo2, lo), jnp.where(active, hi2, hi))

        lo, hi = jax.lax.fori_loop(0, in_steps, bs, (lo, hi))
        idx = (start + lo).astype(jnp.int32)
        kv = jnp.take(block, jnp.clip(lo, 0, block_records - 1), axis=0)
        idx_ref[i] = idx
        found_ref[i] = (idx < n) & (kv == probe) & (lo < block_records)
        return 0

    jax.lax.fori_loop(0, BB, body, 0)


@partial(jax.jit, static_argnames=("block_records", "block_b", "interpret"))
def sstable_search_pallas(fences, keys, probes, n_blocks, n,
                          block_records: int = 256, block_b: int = 256,
                          interpret: bool = True):
    """Matches kernels.ref.sstable_search_ref on found probes."""
    B = probes.shape[0]
    NB = fences.shape[0]
    assert B % block_b == 0
    fence_steps = max(1, math.ceil(math.log2(NB + 1)))
    meta = jnp.stack([jnp.asarray(n_blocks, jnp.int32),
                      jnp.asarray(n, jnp.int32)])
    idx, found = pl.pallas_call(
        partial(_search_kernel, block_records=block_records,
                fence_steps=fence_steps),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.bool_)),
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((NB,), lambda i: (0,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),     # keys stay in HBM
        ],
        out_specs=(pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,))),
        interpret=interpret,
    )(meta, fences, probes, keys)
    return idx, found
