"""repro.serving substrate."""
