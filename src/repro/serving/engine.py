"""Serving engine: continuous batching over a paged KV cache, with the
Bourbon SessionStore as the request-id -> page-table index.

Small-scale-runnable core of a production engine:
  * fixed-size KV pages in a page pool (allocator = free list);
  * admission: new requests prefill (chunked attention path) and are
    registered in the SessionStore;
  * each engine step decodes one token for every active sequence
    (serve_step), evicting finished ones and admitting queued ones
    (continuous batching);
  * batched SessionStore lookups route every step through the learned index
    (the paper's lookup path in the serving hot loop).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_caches
from repro.models.config import ModelConfig
from .session_store import PageRecord, SessionStore

__all__ = ["EngineConfig", "Request", "ServingEngine"]


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 256
    page_tokens: int = 16
    n_pages: int = 4096
    eos_token: int = -1          # -1: run to max_new


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class PagePool:
    def __init__(self, n_pages: int) -> None:
        self.free = list(range(n_pages))

    def alloc(self, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError("page pool exhausted")
        pages, self.free = self.free[:n], self.free[n:]
        return pages

    def release(self, pages: list[int]) -> None:
        self.free.extend(pages)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 session_policy: str = "always") -> None:
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.pool = PagePool(ecfg.n_pages)
        self.sessions = SessionStore(policy=session_policy)
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self._pages: dict[int, list[int]] = {}
        self.caches = init_caches(cfg, ecfg.max_batch, ecfg.max_seq)
        self._slot_rid: list[int | None] = [None] * ecfg.max_batch
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, tokens=t))
        self.steps = 0

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and None in self._slot_rid:
            req = self.queue.pop(0)
            slot = self._slot_rid.index(None)
            self._slot_rid[slot] = req.rid
            self.active[req.rid] = req
            n_pages = -(-int(req.prompt.shape[0] + req.max_new)
                        // self.ecfg.page_tokens)
            pages = self.pool.alloc(n_pages)
            self._pages[req.rid] = pages
            self.sessions.register_batch(
                np.array([req.rid]),
                [PageRecord(pages[0], len(pages), req.prompt.shape[0])])
            # prefill: feed prompt tokens one-by-one into this slot's cache
            # (slot-local decode warmup; a chunked prefill kernel is the
            # production path, this keeps the example CPU-sized)
            for t in req.prompt:
                tok = np.zeros((self.ecfg.max_batch, 1), np.int32)
                tok[slot, 0] = t
                _, self.caches = self._decode(self.params, self.caches,
                                              jnp.asarray(tok))

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """One engine iteration; returns number of active sequences."""
        self._admit()
        rids = [r for r in self._slot_rid if r is not None]
        if not rids:
            return 0
        # learned-index lookup of every active session's page record
        found, recs = self.sessions.lookup_batch(np.array(rids, np.int64))
        assert found.all(), "active session missing from the store"
        tok = np.zeros((self.ecfg.max_batch, 1), np.int32)
        for slot, rid in enumerate(self._slot_rid):
            if rid is None:
                continue
            req = self.active[rid]
            last = req.generated[-1] if req.generated else int(req.prompt[-1])
            tok[slot, 0] = last
        logits, self.caches = self._decode(self.params, self.caches,
                                           jnp.asarray(tok))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        for slot, rid in enumerate(self._slot_rid):
            if rid is None:
                continue
            req = self.active[rid]
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.max_new or \
                    int(nxt[slot]) == self.ecfg.eos_token:
                req.done = True
                self.pool.release(self._pages.pop(rid))
                self.sessions.evict_batch(np.array([rid]))
                self._slot_rid[slot] = None
                del self.active[rid]
        self.steps += 1
        return len(self.active)

    def run_until_drained(self, max_steps: int = 10000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
