"""Bourbon-backed session/prefix-cache index — the paper's technique as a
first-class serving component (DESIGN.md §4).

The serving engine must map request/session ids -> KV-cache page locations.
Session ids are 64-bit hashes (sparse, uniform-ish); churn produces immutable
sorted snapshots — exactly the sstable regime Bourbon learns.  The store IS
a BourbonStore: batched lookups of every id in an incoming decode batch take
the learned (PLR) path once snapshots are learned, with the CBA deciding
whether a snapshot (generation) is worth learning under churn.

Values in the value log are page-table records: (first_page, n_pages,
prefix_len) packed into the 64-byte payload.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import BourbonStore, StoreConfig, LSMConfig
from repro.core.engine import EngineConfig

__all__ = ["SessionStore", "PageRecord"]


@dataclasses.dataclass
class PageRecord:
    first_page: int
    n_pages: int
    prefix_len: int

    def pack(self) -> np.ndarray:
        out = np.zeros(64, np.uint8)
        out[:24] = np.array([self.first_page, self.n_pages, self.prefix_len],
                            np.int64).view(np.uint8)
        return out

    @classmethod
    def unpack(cls, buf: np.ndarray) -> "PageRecord":
        vals = buf[:24].view(np.int64)
        return cls(int(vals[0]), int(vals[1]), int(vals[2]))


class SessionStore:
    """session_id (int64) -> PageRecord, on a learned-index LSM."""

    def __init__(self, policy: str = "cba") -> None:
        cfg = StoreConfig(
            mode="bourbon", policy=policy,
            lsm=LSMConfig(memtable_cap=1 << 12, file_cap=1 << 13,
                          l1_cap_records=1 << 15),
            engine=EngineConfig(seg_cap=2048),
            fetch_values=True)
        self.store = BourbonStore(cfg)

    def register_batch(self, session_ids: np.ndarray,
                       records: list[PageRecord]) -> None:
        vals = np.stack([r.pack() for r in records])
        self.store.put_batch(session_ids.astype(np.int64), vals)

    def lookup_batch(self, session_ids: np.ndarray
                     ) -> tuple[np.ndarray, list[PageRecord | None]]:
        found, vals = self.store.get_batch(session_ids.astype(np.int64))
        recs = [PageRecord.unpack(vals[i]) if found[i] else None
                for i in range(session_ids.shape[0])]
        return found, recs

    def evict_batch(self, session_ids: np.ndarray) -> None:
        self.store.delete_batch(session_ids.astype(np.int64))

    def stats(self) -> dict:
        return self.store.stats()
