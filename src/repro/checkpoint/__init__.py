"""repro.checkpoint substrate."""
