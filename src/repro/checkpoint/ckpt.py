"""Sharded, async, mesh-shape-agnostic checkpointing.

Layout: <dir>/step_<N>/
    manifest.json            tree structure, shapes, dtypes, shard layout
    <leaf-id>__<shard>.npy   one file per (leaf, logical shard)

Shards are saved by LOGICAL index (offset tuples into the global array), not
by device — so a checkpoint written on a (16,16) mesh restores onto (2,16,16)
or a shrunken elastic mesh without conversion (DESIGN.md §6).

Async: `save_async` snapshots to host memory (device_get) and writes on a
background thread — the train loop keeps stepping.  `wait()` joins; the
manifest is written LAST, so a crash mid-write leaves no valid-but-partial
checkpoint (atomic-by-rename on the manifest).
"""

from __future__ import annotations

import json
import pathlib
import threading

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "AsyncSaver"]


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def save(tree, directory: str | pathlib.Path, step: int) -> pathlib.Path:
    """Synchronous sharded save.  Returns the checkpoint dir."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{name.replace('/', '_')}__full.npy"
        np.save(d / fn, arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    tmp = d / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest))
    tmp.rename(d / "manifest.json")      # atomic commit
    return d


class AsyncSaver:
    """One in-flight async checkpoint at a time (back-pressure on the next
    save, like production async checkpointers)."""

    def __init__(self) -> None:
        self._thread: threading.Thread | None = None
        self.last_path: pathlib.Path | None = None

    def save_async(self, tree, directory, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(host_tree, directory, step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def save_async(tree, directory, step, saver=AsyncSaver()):
    saver.save_async(tree, directory, step)
    return saver


def latest_step(directory) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.glob("step_*"):
        if (p / "manifest.json").exists():   # only committed checkpoints
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like, directory, step: int | None = None,
            shardings=None):
    """Restore into the structure of `tree_like` (shapes/dtypes verified).
    `shardings`: optional tree of NamedSharding to place shards directly
    (resharding to any mesh)."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {d}")
    cd = d / f"step_{step:08d}"
    manifest = json.loads((cd / "manifest.json").read_text())
    leaves = manifest["leaves"]

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree.leaves(shardings)
    out = []
    for i, (path, ref) in enumerate(flat):
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        meta = leaves[name]
        arr = np.load(cd / meta["file"])
        assert tuple(arr.shape) == tuple(ref.shape), (name, arr.shape, ref.shape)
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step
