"""repro — Bourbon-JAX: learned-index LSM substrate + multi-pod JAX framework.

x64 is enabled globally: the PLR learned index (the paper's core) needs
float64 key arithmetic.  Model code uses explicit dtypes throughout, so LM
compute stays bf16/f32.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "0.1.0"
