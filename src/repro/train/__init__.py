"""repro.train substrate."""
