"""Training driver: step loop + fault tolerance.

Fault-tolerance posture (designed for 1000+ nodes, exercised here on CPU):
  * async sharded checkpoints every `ckpt_every` steps (checkpoint/ckpt.py);
  * auto-resume: on start, the trainer restores the latest *committed*
    checkpoint and continues — a killed/restarted job loses at most
    `ckpt_every` steps (tests/test_fault_tolerance.py kills a real process);
  * data is assigned by pure function of step (data/pipeline.py), so resume
    needs no data-loader state and any host can recompute any shard
    (straggler work-stealing / elastic shrink per launch/elastic.py);
  * an optional in-process failure injector exercises the recovery path.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.ckpt import AsyncSaver, latest_step, restore
from repro.data.pipeline import DataConfig, HostDataLoader, TokenDataset
from repro.launch.steps import TrainConfig, build_train_step
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import adamw_init

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    fail_at_step: int | None = None   # failure injection (tests)
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 dataset: TokenDataset, rules=None, mesh=None) -> None:
        self.cfg = cfg
        self.tcfg = tcfg
        self.ds = dataset
        self.saver = AsyncSaver()
        self.step_fn = jax.jit(build_train_step(cfg, tcfg.train, rules, mesh))
        self.metrics: list[dict] = []

    def init_or_restore(self):
        """Fresh init, or resume from the latest committed checkpoint."""
        params = init_params(self.cfg, jax.random.key(0))
        opt = adamw_init(params, self.tcfg.train.optim)
        start = 0
        last = latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            state, _ = restore({"p": params, "o": opt},
                               self.tcfg.ckpt_dir, last)
            params, opt = state["p"], state["o"]
            start = last + 1
        return params, opt, start

    def run(self) -> dict:
        params, opt, start = self.init_or_restore()
        loader = HostDataLoader(self.ds, host=0, n_hosts=1, start_step=start)
        losses = []
        try:
            for step in range(start, self.tcfg.steps):
                if self.tcfg.fail_at_step == step:
                    raise RuntimeError(f"injected failure at step {step}")
                _, (tokens, labels) = next(loader)
                batch = {"tokens": tokens, "labels": labels}
                params, opt, m = self.step_fn(params, opt, batch)
                if step % self.tcfg.log_every == 0 or \
                        step == self.tcfg.steps - 1:
                    loss = float(m["loss"])
                    losses.append((step, loss))
                    self.metrics.append({"step": step, "loss": loss,
                                         "grad_norm": float(m["grad_norm"])})
                if step % self.tcfg.ckpt_every == 0 and step > start:
                    self.saver.save_async({"p": params, "o": opt},
                                          self.tcfg.ckpt_dir, step)
        finally:
            loader.close()
            self.saver.wait()
        # final checkpoint
        self.saver.save_async({"p": params, "o": opt}, self.tcfg.ckpt_dir,
                              self.tcfg.steps - 1)
        self.saver.wait()
        return {"losses": losses, "params": params}
