"""Transformer block variants, each with shapes / forward / decode.

Block contract:
  shapes(cfg, dtype)                         -> param pytree of layers.Spec
  forward(x, p, cfg, aux)                    -> (x, aux_loss)
  decode(x, p, cfg, cache, aux)              -> (x, new_cache)
  init_cache(cfg, B, T, dtype)               -> cache pytree (zeros / specs)

aux carries cross-modal inputs (image embeddings) and layer metadata.
"""

from __future__ import annotations

import jax.numpy as jnp

from .attention import (cross_attention, cross_attn_shapes, gqa_attention,
                        gqa_decode, gqa_shapes, mla_attention, mla_decode,
                        mla_shapes)
from .layers import Spec, apply_norm, glu_mlp, mlp_shapes, norm_shapes
from .moe import moe_ffn, moe_shapes
from .ssm import (mamba, mamba_decode, mamba_shapes, mlstm, mlstm_decode,
                  mlstm_shapes, slstm, slstm_decode, slstm_shapes, _dt_rank)

__all__ = ["BLOCKS", "Block"]


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


# --------------------------------------------------------------- attn_mlp

class AttnMlp:
    """Pre-norm GQA attention + gated MLP; optional parallel block
    (command-r) and sliding window."""

    @staticmethod
    def shapes(cfg, dtype):
        p = {
            "ln1": norm_shapes(cfg, jnp.float32),
            "attn": gqa_shapes(cfg, dtype),
            "mlp": mlp_shapes(cfg, cfg.d_ff, dtype),
        }
        if not cfg.parallel_block:
            p["ln2"] = norm_shapes(cfg, jnp.float32)
        return p

    @staticmethod
    def forward(x, p, cfg, aux):
        if cfg.parallel_block:
            h = apply_norm(x, p["ln1"], cfg)
            return x + gqa_attention(h, p["attn"], cfg, window=cfg.window) \
                + glu_mlp(h, p["mlp"], cfg.act), 0.0
        h = apply_norm(x, p["ln1"], cfg)
        x = x + gqa_attention(h, p["attn"], cfg, window=cfg.window)
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        if cfg.parallel_block:
            h = apply_norm(x, p["ln1"], cfg)
            a, cache = gqa_decode(h, p["attn"], cfg, cache, window=cfg.window)
            return x + a + glu_mlp(h, p["mlp"], cfg.act), cache
        h = apply_norm(x, p["ln1"], cfg)
        a, cache = gqa_decode(h, p["attn"], cfg, cache, window=cfg.window)
        x = x + a
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), cache

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        Tc = min(T, cfg.window) if cfg.window else T
        kv = (B, Tc, cfg.n_kv_heads, cfg.hd)
        return {"k": _zeros(kv, dtype), "v": _zeros(kv, dtype),
                "pos": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------- attn_moe

class AttnMoe(AttnMlp):
    @staticmethod
    def shapes(cfg, dtype):
        return {
            "ln1": norm_shapes(cfg, jnp.float32),
            "attn": gqa_shapes(cfg, dtype),
            "ln2": norm_shapes(cfg, jnp.float32),
            "moe": moe_shapes(cfg, dtype),
        }

    @staticmethod
    def forward(x, p, cfg, aux):
        h = apply_norm(x, p["ln1"], cfg)
        x = x + gqa_attention(h, p["attn"], cfg, window=cfg.window)
        h = apply_norm(x, p["ln2"], cfg)
        y, aux_l = moe_ffn(h, p["moe"], cfg, cfg.act)
        return x + y, aux_l

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        h = apply_norm(x, p["ln1"], cfg)
        a, cache = gqa_decode(h, p["attn"], cfg, cache, window=cfg.window)
        x = x + a
        h = apply_norm(x, p["ln2"], cfg)
        y, _ = moe_ffn(h, p["moe"], cfg, cfg.act, capacity_factor=2.0)
        return x + y, cache


# --------------------------------------------------------------- mla_moe

class MlaMoe:
    @staticmethod
    def shapes(cfg, dtype):
        return {
            "ln1": norm_shapes(cfg, jnp.float32),
            "attn": mla_shapes(cfg, dtype),
            "ln2": norm_shapes(cfg, jnp.float32),
            "moe": moe_shapes(cfg, dtype),
        }

    @staticmethod
    def forward(x, p, cfg, aux):
        h = apply_norm(x, p["ln1"], cfg)
        x = x + mla_attention(h, p["attn"], cfg)
        h = apply_norm(x, p["ln2"], cfg)
        y, aux_l = moe_ffn(h, p["moe"], cfg, cfg.act)
        return x + y, aux_l

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        h = apply_norm(x, p["ln1"], cfg)
        a, cache = mla_decode(h, p["attn"], cfg, cache)
        x = x + a
        h = apply_norm(x, p["ln2"], cfg)
        y, _ = moe_ffn(h, p["moe"], cfg, cfg.act, capacity_factor=2.0)
        return x + y, cache

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        return {"c_kv": _zeros((B, T, cfg.kv_lora_rank), dtype),
                "k_rope": _zeros((B, T, cfg.qk_rope_dim), dtype),
                "pos": jnp.zeros((), jnp.int32)}


# ------------------------------------------------------------- mla_dense

class MlaDense(MlaMoe):
    """DeepSeek prologue layer: MLA attention + dense MLP."""

    @staticmethod
    def shapes(cfg, dtype):
        return {
            "ln1": norm_shapes(cfg, jnp.float32),
            "attn": mla_shapes(cfg, dtype),
            "ln2": norm_shapes(cfg, jnp.float32),
            "mlp": mlp_shapes(cfg, cfg.d_ff, dtype),
        }

    @staticmethod
    def forward(x, p, cfg, aux):
        h = apply_norm(x, p["ln1"], cfg)
        x = x + mla_attention(h, p["attn"], cfg)
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        h = apply_norm(x, p["ln1"], cfg)
        a, cache = mla_decode(h, p["attn"], cfg, cache)
        x = x + a
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), cache


# ----------------------------------------------------------------- hybrid

class Hybrid:
    """Hymba: attention and mamba heads in parallel on the same input,
    outputs normalized and averaged; then MLP."""

    @staticmethod
    def shapes(cfg, dtype):
        return {
            "ln1": norm_shapes(cfg, jnp.float32),
            "attn": gqa_shapes(cfg, dtype),
            "mamba": mamba_shapes(cfg, dtype),
            "na": norm_shapes(cfg, jnp.float32),
            "nm": norm_shapes(cfg, jnp.float32),
            "ln2": norm_shapes(cfg, jnp.float32),
            "mlp": mlp_shapes(cfg, cfg.d_ff, dtype),
        }

    @staticmethod
    def forward(x, p, cfg, aux):
        h = apply_norm(x, p["ln1"], cfg)
        a = gqa_attention(h, p["attn"], cfg, window=cfg.window)
        m = mamba(h, p["mamba"], cfg)
        mix = 0.5 * (apply_norm(a, p["na"], cfg) + apply_norm(m, p["nm"], cfg))
        x = x + mix
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        h = apply_norm(x, p["ln1"], cfg)
        a, ac = gqa_decode(h, p["attn"], cfg, cache["attn"], window=cfg.window)
        m, mc = mamba_decode(h, p["mamba"], cfg, cache["mamba"])
        mix = 0.5 * (apply_norm(a, p["na"], cfg) + apply_norm(m, p["nm"], cfg))
        x = x + mix
        h = apply_norm(x, p["ln2"], cfg)
        return x + glu_mlp(h, p["mlp"], cfg.act), {"attn": ac, "mamba": mc}

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        Tc = min(T, cfg.window) if cfg.window else T
        Di = cfg.ssm_expand * cfg.d_model
        return {
            "attn": AttnMlp.init_cache(cfg, B, T, dtype),
            "mamba": {"h": _zeros((B, Di, cfg.ssm_state), jnp.float32),
                      "conv": _zeros((B, cfg.ssm_conv - 1, Di), dtype)},
        }


# ------------------------------------------------------------------ xLSTM

class MLstm:
    @staticmethod
    def shapes(cfg, dtype):
        return {"ln1": norm_shapes(cfg, jnp.float32),
                "cell": mlstm_shapes(cfg, dtype)}

    @staticmethod
    def forward(x, p, cfg, aux):
        return x + mlstm(apply_norm(x, p["ln1"], cfg), p["cell"], cfg), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        y, cache = mlstm_decode(apply_norm(x, p["ln1"], cfg), p["cell"], cfg,
                                cache)
        return x + y, cache

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        H = cfg.n_heads
        hd = cfg.mlstm_pf * cfg.d_model // H
        return {"C": _zeros((B, H, hd, hd), jnp.float32),
                "n": _zeros((B, H, hd), jnp.float32),
                "m": _zeros((B, H), jnp.float32)}


class SLstm:
    @staticmethod
    def shapes(cfg, dtype):
        return {"ln1": norm_shapes(cfg, jnp.float32),
                "cell": slstm_shapes(cfg, dtype)}

    @staticmethod
    def forward(x, p, cfg, aux):
        return x + slstm(apply_norm(x, p["ln1"], cfg), p["cell"], cfg), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        y, cache = slstm_decode(apply_norm(x, p["ln1"], cfg), p["cell"], cfg,
                                cache)
        return x + y, cache

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        H = cfg.slstm_heads
        dh = cfg.d_model // H
        z = (B, H, dh)
        return {"c": _zeros(z, jnp.float32), "n": _zeros(z, jnp.float32),
                "h": _zeros(z, jnp.float32), "m": _zeros((B, H), jnp.float32)}


# ---------------------------------------------------------- cross_attn_mlp

class CrossAttnMlp:
    """Llama-3.2-vision cross-attention layer: gated cross-attn to image
    embeddings + MLP (self-attn free, per the HF architecture)."""

    @staticmethod
    def shapes(cfg, dtype):
        return {
            "ln1": norm_shapes(cfg, jnp.float32),
            "xattn": cross_attn_shapes(cfg, dtype),
            "ln2": norm_shapes(cfg, jnp.float32),
            "mlp": mlp_shapes(cfg, cfg.d_ff, dtype),
            "mlp_gate": Spec((1,), jnp.float32, (None,)),
        }

    @staticmethod
    def forward(x, p, cfg, aux):
        img = aux["image_embed"]          # (B, I, D)
        h = apply_norm(x, p["ln1"], cfg)
        x = x + cross_attention(h, img, p["xattn"], cfg)
        h = apply_norm(x, p["ln2"], cfg)
        y = glu_mlp(h, p["mlp"], cfg.act)
        return x + y * jnp.tanh(p["mlp_gate"]).astype(y.dtype), 0.0

    @staticmethod
    def decode(x, p, cfg, cache, aux):
        # image KV is static during decode; cache holds projected k/v
        out, _ = CrossAttnMlp.forward(x, p, cfg, aux)
        return out, cache

    @staticmethod
    def init_cache(cfg, B, T, dtype):
        return {"pos": jnp.zeros((), jnp.int32)}


BLOCKS = {
    "attn_mlp": AttnMlp,
    "attn_moe": AttnMoe,
    "mla_moe": MlaMoe,
    "mla_dense": MlaDense,
    "hybrid": Hybrid,
    "mlstm": MLstm,
    "slstm": SLstm,
    "cross_attn_mlp": CrossAttnMlp,
}
Block = BLOCKS  # alias
