"""Model configuration + the stage/pattern abstraction.

A model is: embedding -> [prologue blocks] -> (pattern of stages) x n_units
-> final norm -> lm head.  Each stage is a homogeneous run of one block type
scanned with stacked params; heterogeneous stacks (xLSTM's mLSTM/sLSTM mix,
llama-vision's interleaved cross-attn) are patterns with several stages per
unit.  The roofline harness scales ``n_units`` (depth-delta method), so every
config must keep per-unit structure fixed.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "StageSpec"]


@dataclasses.dataclass(frozen=True)
class StageSpec:
    block: str      # attn_mlp | attn_moe | mla_moe | hybrid | mlstm | slstm | cross_attn_mlp
    layers: int     # layers of this block per pattern unit


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | audio | hybrid | ssm | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[StageSpec, ...]  # one unit
    n_units: int
    prologue: tuple[StageSpec, ...] = ()   # fixed depth (e.g. deepseek dense L0)

    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int | None = None           # sliding-window attention (tokens)
    global_attn_every: int = 0          # hymba: every k-th layer full attn
    norm_type: str = "rms"              # rms | ln
    act: str = "silu"                   # silu | gelu
    glu: bool = True                    # gated MLP (False = plain 2-matrix)
    parallel_block: bool = False        # command-r: attn + mlp in parallel
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.01

    # SSM / mamba (hymba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                    # 0 -> d_model // 16

    # xLSTM
    mlstm_pf: int = 2                   # up-projection factor
    slstm_heads: int = 4

    # VLM
    n_image_tokens: int = 0
    # audio (musicgen): frontend stub feeds embeddings directly
    inputs_embeds: bool = False
    n_codebooks: int = 0

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        per_unit = sum(s.layers for s in self.pattern)
        return sum(s.layers for s in self.prologue) + per_unit * self.n_units

    @property
    def sub_quadratic(self) -> bool:
        """Can this config serve 500k-token contexts? (window/SSM only)"""
        blocks = {s.block for s in self.pattern}
        if blocks <= {"mlstm", "slstm"}:
            return True
        if "hybrid" in blocks:
            return True
        return self.window is not None

    def scaled(self, n_units: int) -> "ModelConfig":
        """Depth-scaled copy (roofline delta method)."""
        return dataclasses.replace(self, n_units=n_units)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D)."""
        from .model import param_shapes  # local import to avoid cycle
        import numpy as np
        shapes = param_shapes(self)
        total = 0
        for leaf in __import__("jax").tree.leaves(shapes):
            total += int(np.prod(leaf.shape))
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        # subtract inactive expert params
        per_expert = 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(s.layers for s in self.pattern
                           if s.block in ("attn_moe", "mla_moe")) * self.n_units
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive
