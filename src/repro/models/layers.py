"""Shared layers: norms, rotary embeddings, GLU MLPs, logical sharding axes.

Every parameter is annotated with *logical* axis names (a tuple parallel to
its shape).  launch/mesh.py maps logical names -> physical mesh axes; models
never mention "data"/"model" directly, which is what makes the sharding
hillclimb a pure config change.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Spec", "rms_norm", "layer_norm", "rope", "glu_mlp",
           "mlp_shapes", "norm_shapes", "shard", "cross_entropy"]


class Spec(jax.ShapeDtypeStruct):
    """ShapeDtypeStruct + logical axis names."""

    def __init__(self, shape, dtype, axes):
        super().__init__(shape, dtype)
        assert len(axes) == len(shape), (shape, axes)
        self.axes = tuple(axes)


def shard(x: jnp.ndarray, axes: tuple):
    """Logical sharding constraint on activations; resolved by the launcher
    via jax.sharding use_mesh context (no-op without a mesh)."""
    from repro.launch.sharding import constraint  # late import (no jax dep cycle)
    return constraint(x, axes)


# ---------------------------------------------------------------------- norms

def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, cfg):
    if cfg.norm_type == "ln":
        return layer_norm(x, p, cfg.norm_eps)
    return rms_norm(x, p, cfg.norm_eps)


def norm_shapes(cfg, dtype):
    return Spec((cfg.d_model,), dtype, ("embed",))


# ----------------------------------------------------------------------- rope

def rope(x, positions, theta: float, rotary_dim: int | None = None):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    half = rd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / rd))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rd].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if rd < hd:
        out = jnp.concatenate([out, x[..., rd:]], axis=-1)
    return out


# ------------------------------------------------------------------------ mlp

def glu_mlp(x, p, act: str):
    """Gated MLP w2(act(x@w1) * (x@w3)), or plain w2(act(x@w1)) when the
    config has no gate branch (musicgen)."""
    h = x @ p["w1"]
    a = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    h = a * (x @ p["w3"]) if "w3" in p else a
    h = shard(h, ("batch", "seq", "mlp"))
    return h @ p["w2"]


def mlp_shapes(cfg, d_ff: int, dtype, prefix="layers"):
    D, F = cfg.d_model, d_ff
    p = {
        "w1": Spec((D, F), dtype, ("embed", "mlp")),
        "w2": Spec((F, D), dtype, ("mlp", "embed")),
    }
    if getattr(cfg, "glu", True):
        p["w3"] = Spec((D, F), dtype, ("embed", "mlp"))
    return p


# ----------------------------------------------------------------------- loss

def cross_entropy(logits, labels, softcap: float = 0.0):
    """Mean token NLL in f32.  logits (B, S, V); labels (B, S) int."""
    lg = logits.astype(jnp.float32)
    if softcap:
        lg = jnp.tanh(lg / softcap) * softcap
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)
