"""State-space + recurrent layers: Mamba (selective SSM, for Hymba's hybrid
heads), and xLSTM's mLSTM / sLSTM cells.

TPU adaptation: the selective scan uses jax.lax.associative_scan (log-depth,
vectorized) rather than a sequential loop — the TPU-native formulation of
Mamba's recurrence.  mLSTM trains in its parallel (attention-like) form and
decodes with the O(1) matrix-memory recurrence; sLSTM is inherently
sequential (lax.scan over time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Spec, shard

__all__ = ["mamba_shapes", "mamba", "mamba_decode",
           "mlstm_shapes", "mlstm", "mlstm_decode",
           "slstm_shapes", "slstm", "slstm_decode"]


# ---------------------------------------------------------------------- mamba

def _dt_rank(cfg):
    return cfg.dt_rank or max(1, cfg.d_model // 16)


def mamba_shapes(cfg, dtype):
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    N = cfg.ssm_state
    R = _dt_rank(cfg)
    K = cfg.ssm_conv
    return {
        "in_proj": Spec((D, 2 * Di), dtype, ("embed", "mlp")),
        "conv_w": Spec((K, Di), dtype, ("conv", "mlp")),
        "conv_b": Spec((Di,), dtype, ("mlp",)),
        "x_proj": Spec((Di, R + 2 * N), dtype, ("mlp", "lora")),
        "dt_proj": Spec((R, Di), dtype, ("lora", "mlp")),
        "dt_bias": Spec((Di,), jnp.float32, ("mlp",)),
        "A_log": Spec((Di, N), jnp.float32, ("mlp", "state")),
        "Dskip": Spec((Di,), jnp.float32, ("mlp",)),
        "out_proj": Spec((Di, D), dtype, ("mlp", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,Di); w (K,Di)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssm_scan(dA, dBx):
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t along axis 1.
    dA, dBx: (B, S, Di, N) f32."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return h


MAMBA_CHUNK = 512   # seq chunk bounding the (B,chunk,Di,N) working set
UNROLL_CHUNKS = False   # metering builds (see attention.UNROLL_CHUNKS)


def mamba(x, p, cfg):
    """x (B,S,D) -> (B,S,D).  Long sequences run chunked: the (S,Di,N)
    transition tensor is only ever materialized one chunk at a time, with the
    hidden state carried across chunks (TPU-native analogue of the fused
    selective-scan kernel)."""
    B, S, D = x.shape
    N = cfg.ssm_state
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    xi = shard(xi, ("batch", "seq", "mlp"))
    R = _dt_rank(cfg)
    proj = xi @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :R] @ p["dt_proj"] + p["dt_bias"])  # (B,S,Di)
    Bm = proj[..., R: R + N].astype(jnp.float32)                       # (B,S,N)
    Cm = proj[..., R + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                           # (Di,N)
    dtf = dt.astype(jnp.float32)
    xif = xi.astype(jnp.float32)

    if S <= MAMBA_CHUNK:
        dA = jnp.exp(dtf[..., None] * A)                  # (B,S,Di,N)
        dBx = (dtf * xif)[..., None] * Bm[:, :, None, :]
        h = _ssm_scan(dA, dBx)                            # (B,S,Di,N)
        y = jnp.einsum("bsdn,bsn->bsd", h, Cm)
    else:
        ck = MAMBA_CHUNK
        assert S % ck == 0, (S, ck)
        nch = S // ck
        Di = dtf.shape[-1]

        def chop(a):  # (B,S,...) -> (nch,B,ck,...)
            return a.reshape((B, nch, ck) + a.shape[2:]).swapaxes(0, 1)

        def body(h_prev, xs):
            dtc, xic, Bc, Cc = xs
            dA = jnp.exp(dtc[..., None] * A)              # (B,ck,Di,N)
            dBx = (dtc * xic)[..., None] * Bc[:, :, None, :]
            h_loc = _ssm_scan(dA, dBx)
            # inject carried state: h_t += (prod_{j<=t} dA_j) h_prev
            P = jnp.exp(jnp.cumsum(dtc[..., None] * A, axis=1))
            h = h_loc + P * h_prev[:, None]
            yc = jnp.einsum("bsdn,bsn->bsd", h, Cc)
            return h[:, -1], yc

        h0 = jnp.zeros((B, Di, N), jnp.float32)
        xs = (chop(dtf), chop(xif), chop(Bm), chop(Cm))
        if UNROLL_CHUNKS:
            h, ys_l = h0, []
            for ci in range(nch):
                h, yc = body(h, jax.tree.map(lambda a: a[ci], xs))
                ys_l.append(yc)
            ys = jnp.stack(ys_l)
        else:
            _, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, Di)
    y = y + p["Dskip"] * xif
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode(x, p, cfg, cache):
    """One step. cache: h (B,Di,N) f32, conv (B,K-1,Di)."""
    B = x.shape[0]
    N = cfg.ssm_state
    xz = x @ p["in_proj"]                   # (B,1,2Di)
    xi, z = jnp.split(xz[:, 0], 2, axis=-1)  # (B,Di)
    K = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"], xi[:, None, :]], axis=1)  # (B,K,Di)
    xi = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"])
    R = _dt_rank(cfg)
    proj = xi @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :R] @ p["dt_proj"] + p["dt_bias"])
    Bm = proj[..., R: R + N].astype(jnp.float32)
    Cm = proj[..., R + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)       # (B,Di,N)
    h = dA * cache["h"] + (dt * xi.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + p["Dskip"] * xi.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))[:, None, :]
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------- mLSTM

def mlstm_shapes(cfg, dtype):
    D = cfg.d_model
    Di = cfg.mlstm_pf * D
    H = cfg.n_heads
    return {
        "up": Spec((D, 2 * Di), dtype, ("embed", "mlp")),
        "wq": Spec((Di, Di), dtype, ("mlp", "heads")),
        "wk": Spec((Di, Di), dtype, ("mlp", "heads")),
        "wv": Spec((Di, Di), dtype, ("mlp", "heads")),
        "wi": Spec((Di, H), dtype, ("mlp", "heads")),
        "wf": Spec((Di, H), dtype, ("mlp", "heads")),
        "out_norm": Spec((Di,), jnp.float32, ("mlp",)),
        "down": Spec((Di, D), dtype, ("mlp", "embed")),
    }


def _mlstm_parallel(q, k, v, logi, logf):
    """Stabilized parallel mLSTM.  q,k,v (B,H,S,hd); logi/logf (B,H,S) f32."""
    B, H, S, hd = q.shape
    F = jnp.cumsum(logf, axis=-1)                       # (B,H,S)
    # D[t,s] = F_t - F_s + i_s  for s<=t
    Dmat = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    Dmat = jnp.where(tri, Dmat, -jnp.inf)
    m = jnp.max(Dmat, axis=-1, keepdims=True)           # (B,H,S,1)
    w = jnp.exp(Dmat - m)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / jnp.sqrt(hd)
    Cw = scores * w
    n = jnp.maximum(jnp.abs(jnp.sum(Cw, axis=-1, keepdims=True)),
                    jnp.exp(-m))
    hout = jnp.einsum("bhst,bhtd->bhsd", (Cw / n).astype(v.dtype), v)
    return hout


MLSTM_CHUNK = 256   # chunkwise form above this sequence length


def _mlstm_chunkwise(q, k, v, logi, logf, ck: int):
    """Chunkwise-recurrent mLSTM: within-chunk parallel (ck x ck), matrix
    state (C, n, m) carried across chunks — O(S*ck) memory, matches the
    parallel form and the O(1) decode recurrence exactly.
    q,k,v (B,H,S,hd); logi/logf (B,H,S) f32."""
    B, H, S, hd = q.shape
    assert S % ck == 0, (S, ck)
    nch = S // ck
    scale = 1.0 / jnp.sqrt(hd)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)

    def chop(a):  # (B,H,S,...) -> (nch,B,H,ck,...)
        return a.reshape((B, H, nch, ck) + a.shape[3:]).transpose(
            (2, 0, 1, 3) + tuple(range(4, a.ndim + 1)))

    tri = jnp.tril(jnp.ones((ck, ck), bool))

    def body(carry, xs):
        C_p, n_p, m_p = carry
        qc, kc, vc, ic, fc = xs                       # (B,H,ck,*)
        b = jnp.cumsum(fc, axis=-1)                   # (B,H,ck)
        g = b[..., -1:]                               # total chunk forget
        # intra weights D[t,s] = b_t - b_s + i_s (s<=t)
        Dm = b[..., :, None] - b[..., None, :] + ic[..., None, :]
        Dm = jnp.where(tri, Dm, -jnp.inf)
        m_intra = jnp.max(Dm, axis=-1)                # (B,H,ck)
        m_inter = b + m_p[..., None]
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(Dm - m_t[..., None])
        s_qk = jnp.einsum("bhtd,bhsd->bhts", qc, kc)
        num = jnp.einsum("bhts,bhsd->bhtd", s_qk * w, vc)
        den = jnp.sum(s_qk * w, axis=-1)
        inter_w = jnp.exp(b + m_p[..., None] - m_t)   # (B,H,ck)
        num = num + inter_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qc, C_p)
        den = den + inter_w * jnp.einsum("bhtd,bhd->bht", qc, n_p)
        hloc = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update
        m_n = jnp.maximum((g + m_p[..., None])[..., 0],
                          jnp.max(g - b + ic, axis=-1))
        sw = jnp.exp(g - b + ic - m_n[..., None])     # (B,H,ck)
        C_n = jnp.exp(g[..., 0] + m_p - m_n)[..., None, None] * C_p + \
            jnp.einsum("bhs,bhsd,bhsv->bhdv", sw, kc, vc)
        n_n = jnp.exp(g[..., 0] + m_p - m_n)[..., None] * n_p + \
            jnp.einsum("bhs,bhsd->bhd", sw, kc)
        return (C_n, n_n, m_n), hloc

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (chop(qf), chop(kf), chop(vf), chop(logi), chop(logf))
    if UNROLL_CHUNKS:
        carry, hs_l = (C0, n0, m0), []
        for ci in range(nch):
            carry, hc = body(carry, jax.tree.map(lambda a: a[ci], xs))
            hs_l.append(hc)
        hs = jnp.stack(hs_l)
    else:
        _, hs = jax.lax.scan(jax.checkpoint(body), (C0, n0, m0), xs)
    # (nch,B,H,ck,hd) -> (B,H,S,hd)
    return hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd).astype(v.dtype)


def mlstm(x, p, cfg):
    B, S, D = x.shape
    H = cfg.n_heads
    Di = cfg.mlstm_pf * D
    hd = Di // H
    up = x @ p["up"]
    hin, z = jnp.split(up, 2, axis=-1)                  # (B,S,Di)
    q = (hin @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (hin @ p["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (hin @ p["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    logi = (hin @ p["wi"]).transpose(0, 2, 1).astype(jnp.float32)   # (B,H,S)
    logf = jax.nn.log_sigmoid((hin @ p["wf"]).transpose(0, 2, 1).astype(jnp.float32))
    if S > MLSTM_CHUNK:
        hout = _mlstm_chunkwise(q, k, v, logi, logf, MLSTM_CHUNK)
    else:
        hout = _mlstm_parallel(q, k, v, logi, logf)
    hout = hout.transpose(0, 2, 1, 3).reshape(B, S, Di)
    from .layers import rms_norm
    hout = rms_norm(hout, p["out_norm"], cfg.norm_eps)
    y = hout * jax.nn.silu(z)
    return y @ p["down"]


def mlstm_decode(x, p, cfg, cache):
    """O(1) recurrent step.  cache: C (B,H,hd,hd) f32, n (B,H,hd) f32,
    m (B,H) f32."""
    B = x.shape[0]
    H = cfg.n_heads
    Di = cfg.mlstm_pf * cfg.d_model
    hd = Di // H
    up = x[:, 0] @ p["up"]
    hin, z = jnp.split(up, 2, axis=-1)                  # (B,Di)
    q = (hin @ p["wq"]).reshape(B, H, hd)
    k = (hin @ p["wk"]).reshape(B, H, hd)
    v = (hin @ p["wv"]).reshape(B, H, hd)
    logi = (hin @ p["wi"]).astype(jnp.float32)          # (B,H)
    logf = jax.nn.log_sigmoid((hin @ p["wf"]).astype(jnp.float32))
    m_new = jnp.maximum(logf + cache["m"], logi)
    fs = jnp.exp(logf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    kf = k.astype(jnp.float32) / jnp.sqrt(hd)
    C = fs[..., None] * cache["C"] + is_[..., None] * \
        (kf[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n = fs * cache["n"] + is_ * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhdv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))[..., None]
    hout = (num / den).reshape(B, Di)
    from .layers import rms_norm
    hout = rms_norm(hout, p["out_norm"], cfg.norm_eps)
    y = (hout * jax.nn.silu(z))[:, None, :].astype(x.dtype)
    return y @ p["down"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------- sLSTM

def slstm_shapes(cfg, dtype):
    D = cfg.d_model
    H = cfg.slstm_heads
    dh = D // H
    return {
        "W": Spec((D, 4 * D), dtype, ("embed", "mlp")),
        "R": Spec((H, dh, 4 * dh), dtype, ("heads", "qk", "v")),
        "bias": Spec((4 * D,), jnp.float32, ("mlp",)),
        "out_norm": Spec((D,), jnp.float32, ("embed",)),
        "down": Spec((D, D), dtype, ("embed", "embed")),
    }


def _slstm_step(p, cfg, carry, wx):
    """carry: (c, n, h, m) each (B,H,dh) / m (B,H).  wx: (B,4D) precomputed."""
    c, n, h, m = carry
    B = wx.shape[0]
    H = cfg.slstm_heads
    dh = cfg.d_model // H
    rec = jnp.einsum("bhd,hdk->bhk", h.astype(p["R"].dtype), p["R"])  # (B,H,4dh)
    gates = wx.reshape(B, H, 4 * dh) + rec + p["bias"].reshape(H, 4 * dh)
    gi, gf, gz, go = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    # per-head scalar-ish gating (keep per-unit gates; stabilizer per unit)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + m[..., None], gi)
    i_ = jnp.exp(gi - m_new)
    f_ = jnp.exp(logf + m[..., None] - m_new)
    c_new = f_ * c + i_ * jnp.tanh(gz)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    m_out = jnp.max(m_new, axis=-1)     # collapse stabilizer per head
    return (c_new, n_new, h_new, m_out), h_new


def slstm(x, p, cfg):
    """x (B,S,D): sequential scan over time (inherent to sLSTM)."""
    B, S, D = x.shape
    H = cfg.slstm_heads
    dh = D // H
    wx = x @ p["W"]                                      # (B,S,4D)
    zeros = jnp.zeros((B, H, dh), jnp.float32)
    carry = (zeros, zeros, zeros, jnp.zeros((B, H), jnp.float32))

    def step(carry, wxt):
        return _slstm_step(p, cfg, carry, wxt)

    _, hs = jax.lax.scan(step, carry, wx.swapaxes(0, 1))  # (S,B,H,dh)
    hs = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    from .layers import rms_norm
    hs = rms_norm(hs, p["out_norm"], cfg.norm_eps)
    return hs @ p["down"]


def slstm_decode(x, p, cfg, cache):
    B = x.shape[0]
    wx = (x[:, 0] @ p["W"])
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    carry, h = _slstm_step(p, cfg, carry, wx)
    c, n, hh, m = carry
    D = cfg.d_model
    from .layers import rms_norm
    hs = rms_norm(h.reshape(B, D).astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = (hs @ p["down"])[:, None, :]
    return out, {"c": c, "n": n, "h": hh, "m": m}
