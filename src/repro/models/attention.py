"""Attention variants: GQA (with RoPE / bias / sliding window), MLA
(DeepSeek-V2 latent compression), and gated cross-attention (Llama-3.2
vision).  Each has a train-time (full-sequence) form and a decode form over
a KV cache.

The XLA path here is what the dry-run lowers; a fused Pallas flash kernel is
a TODO hook (kernels are only written for the paper's hot spots — attention
is already near-roofline under XLA on TPU for these shapes, see DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Spec, rope, shard

__all__ = ["gqa_shapes", "gqa_attention", "gqa_decode",
           "mla_shapes", "mla_attention", "mla_decode",
           "cross_attn_shapes", "cross_attention"]

NEG_INF = -1e30


FLASH_THRESHOLD = 2048   # S*T above threshold^2 -> chunked online-softmax
FLASH_KV_CHUNK = 512
UNROLL_CHUNKS = False    # metering builds: python-loop the chunk scan so
                         # cost_analysis counts every chunk exactly


def _sdpa_dense(q, k, v, mask):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    q = q.reshape(B, S, KV, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k) / jnp.sqrt(hd).astype(q.dtype)
    scores = scores.astype(jnp.float32) + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, v.shape[-1])


def _sdpa_chunked(q, k, v, window):
    """Flash-style causal attention: scan over KV chunks with online softmax.
    Never materializes (S, T) scores — memory O(S * chunk).  Assumes
    self-attention with S == T (train/prefill)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    g = H // KV
    ck = min(FLASH_KV_CHUNK, T)
    n_chunks = T // ck
    assert T % ck == 0, (T, ck)
    qr = q.reshape(B, S, KV, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, ck, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, ck, KV, vd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S)[:, None]

    m0 = jnp.full((B, KV, g, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, g, S), jnp.float32)
    a0 = jnp.zeros((B, S, KV, g, vd), jnp.float32)

    def body(carry, inputs):
        m, l, acc, ci = carry[0], carry[1], carry[2], carry[3]
        kch, vch = inputs
        s = jnp.einsum("bskgh,btkh->bkgst", qr, kch).astype(jnp.float32) * scale
        kpos = ci * ck + jnp.arange(ck)[None, :]
        ok = kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard -inf - -inf
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), vch
                        ).astype(jnp.float32)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l, acc, ci + 1), None

    # checkpoint the chunk step: backward recomputes per-chunk scores/probs
    # instead of stacking them across chunks (true flash backward).
    if UNROLL_CHUNKS:
        carry = (m0, l0, a0, jnp.int32(0))
        for ci in range(n_chunks):
            carry, _ = body(carry, (kc[ci], vc[ci]))
        m, l, acc, _ = carry
    else:
        (m, l, acc, _), _ = jax.lax.scan(
            jax.checkpoint(body), (m0, l0, a0, jnp.int32(0)), (kc, vc))
    lt = jnp.maximum(l.transpose(0, 3, 1, 2)[..., None], 1e-30)
    out = (acc / lt).astype(v.dtype)
    return out.reshape(B, S, H, vd)


def _sdpa(q, k, v, mask, window=None, chunked=None):
    """q (B,S,H,hd), k (B,T,KV,hd), v (B,T,KV,vd); mask (S,T) additive or
    None for chunked causal.  Chunked path auto-selected for long self-attn."""
    S, T = q.shape[1], k.shape[1]
    if chunked is None:
        chunked = (S == T and S * T > FLASH_THRESHOLD ** 2)
    if chunked and S == T:
        return _sdpa_chunked(q, k, v, window)
    return _sdpa_dense(q, k, v, mask)


def causal_mask(S: int, T: int, window: int | None = None):
    """(S, T) additive mask; queries at positions T-S..T-1."""
    qpos = jnp.arange(T - S, T)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------------------------------ GQA

def gqa_shapes(cfg, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": Spec((D, H * hd), dtype, ("embed", "heads")),
        "wk": Spec((D, KV * hd), dtype, ("embed", "kv_heads")),
        "wv": Spec((D, KV * hd), dtype, ("embed", "kv_heads")),
        "wo": Spec((H * hd, D), dtype, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Spec((H * hd,), dtype, ("heads",))
        p["bk"] = Spec((KV * hd,), dtype, ("kv_heads",))
        p["bv"] = Spec((KV * hd,), dtype, ("kv_heads",))
    return p


def _qkv(x, p, cfg):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd),
            v.reshape(B, S, KV, hd))


def gqa_attention(x, p, cfg, positions=None, window=None):
    """Full-sequence causal attention. x (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _qkv(x, p, cfg)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, ("batch", "seq", "heads", None))
    k = shard(k, ("batch", "seq", "kv_heads", None))
    if S * S > FLASH_THRESHOLD ** 2:
        out = _sdpa(q, k, v, None, window=window, chunked=True)
    else:
        out = _sdpa(q, k, v, causal_mask(S, S, window), window=window)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"]


def gqa_decode(x, p, cfg, cache, window=None):
    """One-token decode. x (B,1,D); cache dict with k/v (B,T,KV,hd) ring or
    linear buffer and pos () int32.  Returns (out, new_cache)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    pos = cache["pos"]
    q, k, v = _qkv(x, p, cfg)
    posb = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    slot = ((pos % T) if window is not None
            else jnp.minimum(pos, T - 1)).astype(jnp.int32)
    z = jnp.int32(0)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (z, slot, z, z))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (z, slot, z, z))
    kpos = jnp.arange(T)
    if window is not None:
        # ring buffer: valid entries are the last min(pos+1, T) writes
        age = pos - ((pos - kpos) % T)      # absolute position of each slot
        ok = (age >= 0) & (age >= pos - (window - 1)) & (age <= pos)
    else:
        ok = kpos <= pos
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    out = _sdpa(q, ck, cv, mask)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, {"k": ck, "v": cv, "pos": pos + 1}


# ------------------------------------------------------------------------ MLA

def mla_shapes(cfg, dtype):
    """DeepSeek-V2 multi-head latent attention (no q-lora in the Lite cfg)."""
    D, H = cfg.d_model, cfg.n_heads
    nope, rpe, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    return {
        "wq": Spec((D, H * (nope + rpe)), dtype, ("embed", "heads")),
        "wkv_a": Spec((D, r + rpe), dtype, ("embed", "lora")),
        "kv_norm": Spec((r,), jnp.float32, ("lora",)),
        "wkv_b": Spec((r, H * (nope + vd)), dtype, ("lora", "heads")),
        "wo": Spec((H * vd, D), dtype, ("heads", "embed")),
    }


def mla_attention(x, p, cfg, positions=None):
    from .layers import rms_norm
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rpe, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q = (x @ p["wq"]).reshape(B, S, H, nope + rpe)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]                              # (B,S,r+rpe)
    c_kv = rms_norm(kv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., None, r:], positions, cfg.rope_theta)  # (B,S,1,rpe)
    kvb = (c_kv @ p["wkv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, rpe))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    if S * S > FLASH_THRESHOLD ** 2:
        out = _sdpa(q_full, k_full, v, None, chunked=True)   # H == KV here
    else:
        out = _sdpa(q_full, k_full, v, causal_mask(S, S))
    out = out.reshape(B, S, H * vd)
    return out @ p["wo"]


def mla_decode(x, p, cfg, cache):
    """Decode with the *compressed* cache: (c_kv (B,T,r), k_rope (B,T,rpe)).
    This is MLA's payoff — cache bytes ~ r+rpe per token instead of
    2*H*hd."""
    from .layers import rms_norm
    B = x.shape[0]
    H = cfg.n_heads
    nope, rpe, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    T = cache["c_kv"].shape[1]
    pos = cache["pos"]
    posb = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q = (x @ p["wq"]).reshape(B, 1, H, nope + rpe)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, posb, cfg.rope_theta)
    kv = x @ p["wkv_a"]
    c_new = rms_norm(kv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope_new = rope(kv[..., None, r:], posb, cfg.rope_theta)[:, :, 0, :]
    slot = jnp.minimum(pos, T - 1).astype(jnp.int32)
    z = jnp.int32(0)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (z, slot, z))
    kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (z, slot, z))
    # absorbed attention: score = q_nope . (c @ Wb_k) + q_rope . k_rope
    wkv_b = p["wkv_b"].reshape(r, H, nope + vd)
    wb_k, wb_v = wkv_b[..., :nope], wkv_b[..., nope:]
    q_lat = jnp.einsum("bohn,rhn->bohr", q_nope, wb_k)      # (B,1,H,r)
    s_lat = jnp.einsum("bohr,btr->bhot", q_lat, c_kv)
    s_rope = jnp.einsum("bohp,btp->bhot", q_rope, kr)
    scale = 1.0 / jnp.sqrt(nope + rpe).astype(jnp.float32)
    scores = (s_lat + s_rope).astype(jnp.float32) * scale
    ok = jnp.arange(T) <= pos
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhot,btr->bohr", probs, c_kv)       # (B,1,H,r)
    out = jnp.einsum("bohr,rhv->bohv", o_lat, wb_v)
    out = out.reshape(B, 1, H * vd) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": kr, "pos": pos + 1}


# ----------------------------------------------------------------- cross-attn

def cross_attn_shapes(cfg, dtype):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": Spec((D, H * hd), dtype, ("embed", "heads")),
        "wk": Spec((D, KV * hd), dtype, ("embed", "kv_heads")),
        "wv": Spec((D, KV * hd), dtype, ("embed", "kv_heads")),
        "wo": Spec((H * hd, D), dtype, ("heads", "embed")),
        "gate": Spec((1,), jnp.float32, (None,)),
    }


def cross_attention(x, kv_src, p, cfg):
    """Gated cross-attention (Llama-3.2 vision).  kv_src (B, I, D) image
    embeddings; output is tanh-gated (zero-init -> identity at init)."""
    B, S, D = x.shape
    I = kv_src.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (kv_src @ p["wk"]).reshape(B, I, KV, hd)
    v = (kv_src @ p["wv"]).reshape(B, I, KV, hd)
    mask = jnp.zeros((S, I), jnp.float32)
    out = _sdpa(q, k, v, mask)
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out * jnp.tanh(p["gate"]).astype(out.dtype)
