"""Model assembly: embedding -> staged block stack (scan over layers) ->
norm -> LM head.  One code path serves all 10 assigned architectures via
ModelConfig.pattern.

Scan-over-layers keeps compile time flat in depth (critical for the 512-dev
dry-run); heterogeneous stacks execute as RLE-merged runs of homogeneous
scans sliced out of per-stage stacked params, preserving the exact interleave
(e.g. xLSTM's 7 mLSTM : 1 sLSTM).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .blocks import BLOCKS
from .config import ModelConfig
from .layers import Spec, apply_norm, cross_entropy, norm_shapes, shard

__all__ = ["param_shapes", "init_params", "forward", "loss_fn",
           "decode_step", "init_caches", "execution_runs"]


def _dtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack_shapes(shapes, L):
    return jax.tree.map(
        lambda s: Spec((L,) + s.shape, s.dtype, ("layers",) + s.axes), shapes)


def _stage_key(kind: str, si: int, block: str) -> str:
    return f"{kind}{si}_{block}"


def param_shapes(cfg: ModelConfig):
    dt = _dtype(cfg)
    D, V = cfg.d_model, cfg.vocab
    p = {}
    if not cfg.inputs_embeds:
        p["embed"] = Spec((V, D), dt, ("vocab", "embed"))
    stages = {}
    for si, st in enumerate(cfg.prologue):
        stages[_stage_key("pro", si, st.block)] = _stack_shapes(
            BLOCKS[st.block].shapes(cfg, dt), st.layers)
    for si, st in enumerate(cfg.pattern):
        stages[_stage_key("s", si, st.block)] = _stack_shapes(
            BLOCKS[st.block].shapes(cfg, dt), st.layers * cfg.n_units)
    p["stages"] = stages
    p["final_norm"] = norm_shapes(cfg, jnp.float32)
    if not cfg.tie_embeddings:
        p["lm_head"] = Spec((D, V), dt, ("embed", "vocab"))
    return p


def init_params(cfg: ModelConfig, rng):
    """Real initialization (smoke tests / small trains), decided by path:
    norms -> ones, gates/biases -> zeros, matrices -> trunc-normal 0.02."""
    shapes = param_shapes(cfg)
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    rngs = jax.random.split(rng, len(paths_and_leaves))

    def name_of(path):
        return "/".join(str(getattr(k, "key", k)) for k in path).lower()

    def one(r, path, s):
        nm = name_of(path)
        if any(t in nm for t in ("norm", "ln1", "ln2", "/na", "/nm")):
            return jnp.ones(s.shape, s.dtype)
        if "gate" in nm:
            return jnp.zeros(s.shape, s.dtype)
        if "a_log" in nm:  # mamba: A in [-N..-1]
            n = s.shape[-1]
            return jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), s.shape
            ).astype(s.dtype)
        if "dskip" in nm:
            return jnp.ones(s.shape, s.dtype)
        if len(s.shape) >= 2:
            return (jax.random.normal(r, s.shape, jnp.float32) * 0.02
                    ).astype(s.dtype)
        return jnp.zeros(s.shape, s.dtype)  # biases

    leaves = [one(r, p, s) for r, (p, s) in zip(rngs, paths_and_leaves)]
    return jax.tree.unflatten(treedef, leaves)


def execution_runs(cfg: ModelConfig):
    """Ordered (stage_key, offset, count, block) runs, RLE-merged."""
    raw = []
    for si, st in enumerate(cfg.prologue):
        raw.append([_stage_key("pro", si, st.block), 0, st.layers, st.block])
    for u in range(cfg.n_units):
        for si, st in enumerate(cfg.pattern):
            raw.append([_stage_key("s", si, st.block), u * st.layers,
                        st.layers, st.block])
    merged = []
    for r in raw:
        if merged and merged[-1][0] == r[0] and \
                merged[-1][1] + merged[-1][2] == r[1]:
            merged[-1][2] += r[2]
        else:
            merged.append(list(r))
    return [tuple(m) for m in merged]


def _slice_stage(stage_params, off, cnt):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, off, off + cnt,
                                                       axis=0), stage_params)


def _remat_wrap(fn, remat: str | None):
    if remat in (None, "none"):
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if remat == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(remat)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, aux=None,
            remat: str | None = "full", last_only: bool = False,
            unroll: bool = False, scan_param_fsdp: bool = False):
    """Returns (logits (B,S,V), aux_loss ()).  tokens (B,S) int32 or
    embeds (B,S,D).  last_only: project only the final position (serving
    prefill — avoids the (B,S,V) logits tensor).  unroll: python loop over
    layers instead of lax.scan (metering builds: cost_analysis counts scan
    bodies once, unrolled layers are counted exactly)."""
    aux = aux or {}
    if cfg.inputs_embeds:
        x = embeds.astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = shard(x, ("batch", "seq", "embed"))
    aux_total = jnp.zeros((), jnp.float32)

    spec_tree = param_shapes(cfg) if scan_param_fsdp else None
    for key, off, cnt, block in execution_runs(cfg):
        blk = BLOCKS[block]
        sp = _slice_stage(params["stages"][key], off, cnt)
        sspec = spec_tree["stages"][key] if spec_tree else None

        def step(x, p_layer, _blk=blk, _ss=sspec):
            if _ss is not None:
                from repro.launch.sharding import param_constraint
                p_layer = jax.tree.map(
                    lambda a, sp_: param_constraint(a, sp_.axes[1:]),
                    p_layer, _ss)
            y, a = _blk.forward(x, p_layer, cfg, aux)
            y = shard(y, ("batch", "seq", "embed"))
            return y, jnp.asarray(a, jnp.float32)

        if unroll:
            step = _remat_wrap(step, remat)
            for j in range(cnt):
                pl = jax.tree.map(lambda a: a[j], sp)
                x, a = step(x, pl)
                aux_total = aux_total + a
        elif remat == "nested" and cnt >= 4:
            # two-level sqrt(L) checkpointing: outer groups + per-layer,
            # peak residency ~ (G + cnt/G) block inputs instead of cnt
            G = 1
            for g in range(int(cnt ** 0.5), 0, -1):
                if cnt % g == 0:
                    G = g
                    break
            inner = cnt // G
            sp2 = jax.tree.map(
                lambda a: a.reshape((G, inner) + a.shape[1:]), sp)
            layer_step = jax.checkpoint(step)

            def group_fn(x, gp):
                x, auxs = jax.lax.scan(lambda c, q: layer_step(c, q), x, gp)
                return x, jnp.sum(auxs)

            x, auxg = jax.lax.scan(
                lambda c, q: jax.checkpoint(group_fn)(c, q), x, sp2)
            aux_total = aux_total + jnp.sum(auxg)
        else:
            step = _remat_wrap(step, remat)
            x, auxs = jax.lax.scan(lambda c, p: step(c, p), x, sp)
            aux_total = aux_total + jnp.sum(auxs)

    if last_only:
        x = x[:, -1:, :]
    x = apply_norm(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = shard(logits, ("batch", "seq", "vocab"))
    return logits, aux_total


def loss_fn(params, cfg: ModelConfig, batch, remat: str | None = "full",
            unroll: bool = False, scan_param_fsdp: bool = False):
    logits, aux = forward(params, cfg,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          aux={k: v for k, v in batch.items()
                               if k in ("image_embed",)},
                          remat=remat, unroll=unroll,
                          scan_param_fsdp=scan_param_fsdp)
    nll = cross_entropy(logits, batch["labels"], cfg.logit_softcap)
    return nll + aux, {"nll": nll, "aux": aux}


# ------------------------------------------------------------------- decode

def init_caches(cfg: ModelConfig, B: int, T: int):
    """Stacked per-stage caches for one-token decode with context length T."""
    dt = _dtype(cfg)
    caches = {}
    for si, st in enumerate(cfg.prologue):
        key = _stage_key("pro", si, st.block)
        one = BLOCKS[st.block].init_cache(cfg, B, T, dt)
        caches[key] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (st.layers,) + a.shape).copy()
            if hasattr(a, "shape") else a, one)
    for si, st in enumerate(cfg.pattern):
        key = _stage_key("s", si, st.block)
        L = st.layers * cfg.n_units
        one = BLOCKS[st.block].init_cache(cfg, B, T, dt)
        caches[key] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
    return caches


def decode_step(params, cfg: ModelConfig, caches, tokens=None, embeds=None,
                aux=None, unroll: bool = False):
    """One-token decode.  tokens (B,1) int32 / embeds (B,1,D).
    Returns (logits (B,1,V), new_caches)."""
    aux = aux or {}
    if cfg.inputs_embeds:
        x = embeds.astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    new_caches = {k: None for k in caches}

    for key, off, cnt, block in execution_runs(cfg):
        blk = BLOCKS[block]
        sp = _slice_stage(params["stages"][key], off, cnt)
        sc = _slice_stage(caches[key], off, cnt)

        def step(x, pc, _blk=blk):
            p_layer, c_layer = pc
            y, c_new = _blk.decode(x, p_layer, cfg, c_layer, aux)
            return y, c_new

        if unroll:
            couts = []
            for j in range(cnt):
                pl = jax.tree.map(lambda a: a[j], sp)
                cl = jax.tree.map(lambda a: a[j], sc)
                x, c_new = step(x, (pl, cl))
                couts.append(c_new)
            c_out = jax.tree.map(lambda *xs: jnp.stack(xs), *couts)
        else:
            x, c_out = jax.lax.scan(step, x, (sp, sc))
        if new_caches[key] is None:
            new_caches[key] = c_out
        else:
            new_caches[key] = jax.tree.map(
                lambda full, part: jnp.concatenate([full, part], axis=0),
                new_caches[key], c_out)

    x = apply_norm(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_caches
