"""Mixture-of-experts FFN: top-k routing, capacity-bucketed dispatch, batched
expert GEMMs, optional shared experts (DeepSeekMoE), load-balance aux loss.

Dispatch is scatter-based (linear in tokens), not the quadratic GShard
dispatch-einsum: tokens are ranked within their expert via a one-hot cumsum,
scattered into an (E, C, D) buffer (overflow dropped at capacity C =
ceil(T*K/E)*capacity_factor), processed by one batched einsum per weight —
the MXU-friendly TPU formulation (MegaBlocks block-sparse is a GPU-ism;
DESIGN.md §2) — and combined back with their gates.

Sharding: experts live on the "experts" logical axis (the model mesh axis);
with batch-sharded activations GSPMD turns dispatch/combine into all-to-all —
the collective the MoE roofline cells track.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Spec, glu_mlp, mlp_shapes, shard

__all__ = ["moe_shapes", "moe_ffn"]


def moe_shapes(cfg, dtype):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": Spec((D, E), jnp.float32, ("embed", "experts")),
        "w1": Spec((E, D, Fe), dtype, ("experts", "embed", "mlp")),
        "w3": Spec((E, D, Fe), dtype, ("experts", "embed", "mlp")),
        "w2": Spec((E, Fe, D), dtype, ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_shapes(cfg, cfg.moe_d_ff * cfg.n_shared_experts,
                                 dtype)
    return p


GROUP_TOKENS = 1024   # dispatch-group size (bounds per-group capacity)


def moe_ffn(x, p, cfg, act: str, capacity_factor: float = 1.25):
    """x (B,S,D) -> ((B,S,D), aux_loss f32).

    Tokens are split into GROUP_TOKENS-sized groups along the (sharded)
    batch dim; dispatch is a vmapped per-group scatter into an
    (E, C_group, D) buffer — batch-parallel for GSPMD, so the only cross-
    device movement is the batch->expert resharding before the expert
    einsums (the EP all-to-all)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    tg = min(GROUP_TOKENS, T)
    G = T // tg
    xg = x.reshape(G, tg, D)

    logits = (xg.astype(jnp.float32) @ p["router"])           # (G,t,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                  # (G,t,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    one_hot_k = jax.nn.one_hot(idx, E, dtype=jnp.float32)     # (G,t,K,E)
    frac_tokens = jnp.mean(jnp.sum(one_hot_k, axis=2), axis=(0, 1)) / K
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef

    # rank within (group, expert) over the t*K assignment slots
    flat_e = idx.reshape(G, tg * K)                           # (G,tK)
    flat_g = gate_vals.reshape(G, tg * K)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (G,tK,E)
    pos = jnp.cumsum(oh, axis=1) - oh
    pos_in_e = jnp.sum(pos * oh, axis=-1)                     # (G,tK)
    C = int(max(K, -(-tg * K // E) * capacity_factor))
    C = -(-C // 8) * 8                                        # lane-align
    keep = pos_in_e < C
    dest = jnp.where(keep, flat_e * C + pos_in_e, E * C)      # overflow sink

    tok = jnp.arange(tg * K, dtype=jnp.int32) // K

    def scatter_group(xb, destb):
        buf = jnp.zeros((E * C + 1, D), x.dtype)
        return buf.at[destb].add(xb[tok])

    buf = jax.vmap(scatter_group)(xg, dest)                   # (G,E*C+1,D)
    eb = buf[:, : E * C].reshape(G, E, C, D)
    eb = shard(eb, ("batch", "experts", None, "embed"))

    h1 = jnp.einsum("gecd,edf->gecf", eb, p["w1"])
    h3 = jnp.einsum("gecd,edf->gecf", eb, p["w3"])
    hact = (jax.nn.silu(h1) if act == "silu" else jax.nn.gelu(h1)) * h3
    hact = shard(hact, ("batch", "experts", None, "mlp"))
    out = jnp.einsum("gecf,efd->gecd", hact, p["w2"])         # (G,E,C,D)

    # combine: per-group gather of each kept assignment's output row
    out_flat = jnp.concatenate([out.reshape(G, E * C, D),
                                jnp.zeros((G, 1, D), out.dtype)], axis=1)
    rows = jnp.take_along_axis(out_flat, dest[..., None], axis=1)  # (G,tK,D)
    w = (flat_g * keep).astype(out.dtype)[..., None]
    y = jnp.sum((rows * w).reshape(G, tg, K, D), axis=2).reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + glu_mlp(x, p["shared"], act)
    return y, aux
