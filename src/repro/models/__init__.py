"""Composable model stack for the assigned architectures."""

from .config import ModelConfig, StageSpec
from .model import (param_shapes, init_params, forward, loss_fn, decode_step,
                    init_caches, execution_runs)

__all__ = ["ModelConfig", "StageSpec", "param_shapes", "init_params",
           "forward", "loss_fn", "decode_step", "init_caches",
           "execution_runs"]
