"""End-to-end observability plane (see README.md in this package).

One :class:`Obs` bundle per serving stack: a labeled
:class:`MetricsRegistry` every layer reports into (collectors replace
the scattered ``stats()`` dicts at snapshot time), a sampling
:class:`StageTracer` timing the read-path stages through pre-bound
handles, and an :class:`EventLog` of maintenance decisions with their
CBA cost/benefit estimates.  ``Obs.snapshot()`` is the one call that
yields the whole fleet's metrics; exporters render it as JSON,
Prometheus text, or the per-tick stage timeline.
"""

from __future__ import annotations

import dataclasses

from .export import parse_prometheus, to_json, to_prometheus
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       publish_stats)
from .trace import (CausalTracer, NullCausalTracer, Span, TraceContext,
                    CRITICAL_STAGES, NULL_CTRACE, SPAN_NAMES)
from .tracer import (EventLog, NullTracer, StageHandle, StageTracer,
                     NULL_HANDLE, NULL_TRACER)

__all__ = ["CausalTracer", "Counter", "EventLog", "Gauge", "Histogram",
           "MetricsRegistry", "NullCausalTracer", "NullTracer", "Obs",
           "ObsConfig", "Span", "StageHandle", "StageTracer", "TraceContext",
           "CRITICAL_STAGES", "NULL_CTRACE", "NULL_HANDLE", "NULL_TRACER",
           "SPAN_NAMES", "parse_prometheus", "publish_stats", "to_json",
           "to_prometheus"]

# canonical read-path stage names (the §3-style decomposition the serve
# bench reports); layers pre-bind handles for exactly these
READ_STAGES = ("admission", "coalesce", "cache_probe", "filter_probe",
               "dispatch", "compute", "resolve", "value_fetch")


@dataclasses.dataclass
class ObsConfig:
    enabled: bool = True
    # time stages on every Nth server tick (1 = every tick); unsampled
    # ticks cost one attribute read per stage call
    sample_every: int = 4
    timeline_ticks: int = 512    # per-tick stage rows kept in the ring
    events_cap: int = 1024       # maintenance events kept
    # causal tracing: trace every Nth *request* end to end (0 disables;
    # unsampled requests cost one integer decrement at admission and one
    # identity test per downstream span site)
    trace_sample_every: int = 64
    trace_ring: int = 4096       # spans kept for export/describe_trace


class Obs:
    """The per-stack observability bundle: registry + tracer + causal
    tracer + events."""

    def __init__(self, cfg: ObsConfig | None = None) -> None:
        self.cfg = cfg if cfg is not None else ObsConfig()
        self.registry = MetricsRegistry()
        self.tracer = StageTracer(self.registry,
                                  sample_every=self.cfg.sample_every,
                                  timeline_ticks=self.cfg.timeline_ticks)
        self.ctrace = (CausalTracer(self.registry,
                                    sample_every=self.cfg.trace_sample_every,
                                    ring=self.cfg.trace_ring)
                       if self.cfg.trace_sample_every > 0 else NULL_CTRACE)
        self.events = EventLog(self.cfg.events_cap)
        # maintenance events correlate to the tick + causal trace they
        # ran under (satellite of the causal-tracing plane)
        self.events.stamp = self._stamp
        self.registry.register_collector("obs_self", self._collect)

    def _stamp(self) -> dict:
        return {"tick": self.tracer.ticks_seen,
                "trace_id": self.ctrace.active_tid()}

    def _collect(self, reg: MetricsRegistry) -> None:
        reg.counter("obs_events_total").observe_total(self.events.total)
        reg.counter("obs_ticks_seen_total").observe_total(
            self.tracer.ticks_seen)
        reg.counter("obs_sampled_ticks_total").observe_total(
            self.tracer.sampled_ticks)
        reg.counter("obs_traced_requests_total").observe_total(
            self.ctrace.traced_requests
            if self.ctrace is not NULL_CTRACE else 0)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def to_json(self) -> str:
        return to_json(self.snapshot())

    def to_prometheus(self) -> str:
        return to_prometheus(self.snapshot())

    def timeline(self) -> list[dict]:
        return self.tracer.timeline()

    def trace_events(self) -> dict:
        """Chrome trace-event / Perfetto JSON of the causal span ring."""
        return self.ctrace.to_trace_events()

    def describe_trace(self, tid: int) -> str:
        return self.ctrace.describe_trace(tid)
