"""End-to-end observability plane (see README.md in this package).

One :class:`Obs` bundle per serving stack: a labeled
:class:`MetricsRegistry` every layer reports into (collectors replace
the scattered ``stats()`` dicts at snapshot time), a sampling
:class:`StageTracer` timing the read-path stages through pre-bound
handles, and an :class:`EventLog` of maintenance decisions with their
CBA cost/benefit estimates.  ``Obs.snapshot()`` is the one call that
yields the whole fleet's metrics; exporters render it as JSON,
Prometheus text, or the per-tick stage timeline.
"""

from __future__ import annotations

import dataclasses

from .export import parse_prometheus, to_json, to_prometheus
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       publish_stats)
from .tracer import (EventLog, NullTracer, StageHandle, StageTracer,
                     NULL_HANDLE, NULL_TRACER)

__all__ = ["Counter", "EventLog", "Gauge", "Histogram", "MetricsRegistry",
           "NullTracer", "Obs", "ObsConfig", "StageHandle", "StageTracer",
           "NULL_HANDLE", "NULL_TRACER", "parse_prometheus", "publish_stats",
           "to_json", "to_prometheus"]

# canonical read-path stage names (the §3-style decomposition the serve
# bench reports); layers pre-bind handles for exactly these
READ_STAGES = ("admission", "coalesce", "cache_probe", "dispatch",
               "compute", "resolve", "value_fetch")


@dataclasses.dataclass
class ObsConfig:
    enabled: bool = True
    # time stages on every Nth server tick (1 = every tick); unsampled
    # ticks cost one attribute read per stage call
    sample_every: int = 4
    timeline_ticks: int = 512    # per-tick stage rows kept in the ring
    events_cap: int = 1024       # maintenance events kept


class Obs:
    """The per-stack observability bundle: registry + tracer + events."""

    def __init__(self, cfg: ObsConfig | None = None) -> None:
        self.cfg = cfg if cfg is not None else ObsConfig()
        self.registry = MetricsRegistry()
        self.tracer = StageTracer(self.registry,
                                  sample_every=self.cfg.sample_every,
                                  timeline_ticks=self.cfg.timeline_ticks)
        self.events = EventLog(self.cfg.events_cap)
        self.registry.register_collector("obs_self", self._collect)

    def _collect(self, reg: MetricsRegistry) -> None:
        reg.counter("obs_events_total").observe_total(self.events.total)
        reg.counter("obs_ticks_seen_total").observe_total(
            self.tracer.ticks_seen)
        reg.counter("obs_sampled_ticks_total").observe_total(
            self.tracer.sampled_ticks)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def to_json(self) -> str:
        return to_json(self.snapshot())

    def to_prometheus(self) -> str:
        return to_prometheus(self.snapshot())

    def timeline(self) -> list[dict]:
        return self.tracer.timeline()
