"""Snapshot exporters: JSON, Prometheus text format, and a parser for
round-trip tests.

Both exporters consume the plain-dict shape :meth:`MetricsRegistry
.snapshot` returns (or a registry, which is snapshotted for you), so a
snapshot taken once can be rendered every way without re-collecting.
"""

from __future__ import annotations

import json

from .registry import Histogram, MetricsRegistry

__all__ = ["to_json", "to_prometheus", "parse_prometheus"]


def _snap(reg_or_snap) -> dict:
    if isinstance(reg_or_snap, MetricsRegistry):
        return reg_or_snap.snapshot()
    return reg_or_snap


def to_json(reg_or_snap) -> str:
    """Machine-readable snapshot; ``json.loads`` round-trips it exactly
    (every value is already a plain float/int/str/list/dict)."""
    return json.dumps(_snap(reg_or_snap), sort_keys=True)


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k]).replace("\\", r"\\").replace('"', r"\"") \
            .replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}"


def _fmt_value(v: float) -> str:
    return repr(float(v))


def to_prometheus(reg_or_snap) -> str:
    """Prometheus text exposition format.  Histograms expand into
    ``_bucket`` (cumulative, ``le`` label), ``_sum``, ``_count``, and a
    non-standard ``_max`` gauge."""
    snap = _snap(reg_or_snap)
    lines: list[str] = []
    for name in sorted(snap):
        fam = snap[name]
        kind = fam["kind"]
        lines.append(f"# TYPE {name} {kind}")
        for s in fam["samples"]:
            labels = s["labels"]
            if kind == "histogram":
                v = s["value"]
                cum = 0
                for bound, n in zip(Histogram.BOUNDS, v["buckets"]):
                    cum += n
                    lb = _fmt_labels({**labels, "le": repr(float(bound))})
                    lines.append(f"{name}_bucket{lb} {cum}")
                cum += v["buckets"][-1]
                lb = _fmt_labels({**labels, "le": "+Inf"})
                lines.append(f"{name}_bucket{lb} {cum}")
                lines.append(
                    f"{name}_sum{_fmt_labels(labels)} {_fmt_value(v['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(labels)} {v['count']}")
                lines.append(
                    f"{name}_max{_fmt_labels(labels)} {_fmt_value(v['max'])}")
            else:
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(s['value'])}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Parse exposition text back to ``{(name, ((k, v), ...)): value}``
    — the inverse used by the round-trip tests.  Histogram series come
    back under their expanded names (``_sum``/``_count``/``_bucket``)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # name{label="v",...} value   |   name value
        if "{" in line:
            name, rest = line.split("{", 1)
            label_str, value_str = rest.rsplit("}", 1)
            labels = []
            # split on commas not inside quotes (values are escaped)
            depth_q = False
            cur = ""
            parts = []
            for ch in label_str:
                if ch == '"':
                    depth_q = not depth_q
                if ch == "," and not depth_q:
                    parts.append(cur)
                    cur = ""
                else:
                    cur += ch
            if cur:
                parts.append(cur)
            for p in parts:
                k, v = p.split("=", 1)
                v = v.strip()[1:-1]
                v = v.replace(r"\n", "\n").replace(r"\"", '"') \
                    .replace(r"\\", "\\")
                labels.append((k.strip(), v))
            key = (name.strip(), tuple(sorted(labels)))
        else:
            name, value_str = line.rsplit(None, 1)
            key = (name.strip(), ())
        out[key] = float(value_str)
    return out
