"""StageTracer — sampling stage timer for the serving hot path.

The paper's §3 analysis works because lookup latency is decomposed into
stages; this tracer does the same for the serving read path (admission,
coalesce, cache probe, dispatch, device compute, resolve, value fetch)
at a cost low enough to leave on in production:

* **pre-bound handles** — each stage is resolved to a :class:`StageHandle`
  once at server construction.  Per batch the hot path does
  ``t0 = h.begin(); ...; h.end(t0)``: no dict lookup, no string
  formatting, no allocation.
* **tick sampling** — ``begin_tick`` arms the handles on every
  ``sample_every``-th tick only; an unarmed ``begin()`` returns 0.0 and
  ``end(0.0)`` is a no-op, so the unsampled cost is one attribute read
  and a float compare.
* **timeline** — sampled ticks append one per-stage-microseconds row to
  a bounded ring, the raw material for a paper-style stage-breakdown
  plot over time.

Obs-off code paths hold :data:`NULL_HANDLE` / :data:`NULL_TRACER`
(null-object singletons) so instrumented call sites never branch on
"is obs enabled".
"""

from __future__ import annotations

import time
from collections import deque

__all__ = ["EventLog", "NullTracer", "StageHandle", "StageTracer",
           "NULL_HANDLE", "NULL_TRACER"]

_now = time.perf_counter


class StageHandle:
    """Pre-bound timer for one stage.  ``begin`` returns a start stamp
    (0.0 when the tracer is not sampling this tick — ``end`` then
    no-ops), so cross-tick spans survive the sampling state changing
    between begin and end."""

    __slots__ = ("_tracer", "name", "hist", "count", "total_us", "tick_us")

    def __init__(self, tracer: "StageTracer", name: str, hist) -> None:
        self._tracer = tracer
        self.name = name
        self.hist = hist
        self.count = 0          # sampled observations
        self.total_us = 0.0     # sampled microseconds
        self.tick_us = 0.0      # accumulator drained by end_tick

    def begin(self) -> float:
        return _now() if self._tracer._on else 0.0

    def end(self, t0: float) -> None:
        if t0:
            dt = (_now() - t0) * 1e6
            self.count += 1
            self.total_us += dt
            self.tick_us += dt
            self.hist.observe(dt)


class StageTracer:
    def __init__(self, registry, sample_every: int = 4,
                 timeline_ticks: int = 512,
                 family: str = "server_stage_us") -> None:
        self._registry = registry
        self._family = family
        self.sample_every = max(int(sample_every), 1)
        self._on = False
        self._n = 0
        self._stages: dict[str, StageHandle] = {}
        self._timeline: deque = deque(maxlen=int(timeline_ticks))
        self.ticks_seen = 0
        self.sampled_ticks = 0

    def stage(self, name: str) -> StageHandle:
        """Pre-bind a handle for ``name`` (get-or-create).  Call once at
        construction time, never per batch."""
        h = self._stages.get(name)
        if h is None:
            hist = self._registry.histogram(self._family, stage=name)
            h = self._stages[name] = StageHandle(self, name, hist)
        return h

    def begin_tick(self) -> int:
        """Arm (or disarm) the handles for this tick; returns the tick
        index to hand back to :meth:`end_tick`."""
        self._on = self._n % self.sample_every == 0
        self._n += 1
        self.ticks_seen += 1
        if self._on:
            self.sampled_ticks += 1
        return self.ticks_seen - 1

    def end_tick(self, tick: int) -> None:
        if not self._on:
            return
        row = {"tick": int(tick)}
        nonzero = False
        for name, h in self._stages.items():
            if h.tick_us:
                row[name] = round(h.tick_us, 3)
                h.tick_us = 0.0
                nonzero = True
        if nonzero:
            self._timeline.append(row)

    def timeline(self) -> list[dict]:
        """Sampled per-tick stage breakdown rows, oldest first."""
        return list(self._timeline)


class _NullHandle:
    """Obs-off stand-in: same interface, zero state, no branches at the
    call site."""

    __slots__ = ()

    def begin(self) -> float:
        return 0.0

    def end(self, t0: float) -> None:
        pass


class NullTracer:
    __slots__ = ()
    _on = False

    def stage(self, name: str) -> _NullHandle:
        return NULL_HANDLE

    def begin_tick(self) -> int:
        return 0

    def end_tick(self, tick: int) -> None:
        pass

    def timeline(self) -> list:
        return []


NULL_HANDLE = _NullHandle()
NULL_TRACER = NullTracer()


class EventLog:
    """Bounded log of maintenance-plane events (learn / GC / checkpoint),
    each carrying the CBA cost/benefit estimates that drove the decision
    — the paper's §4.4 inputs, made observable."""

    def __init__(self, cap: int = 1024) -> None:
        self._events: deque = deque(maxlen=int(cap))
        self.total = 0
        # optional ambient-context hook (set by Obs): a callable
        # returning fields merged under every entry — the serving stack
        # stamps `tick` and `trace_id` so a GC/learn/checkpoint decision
        # correlates with the causal spans of the tick it ran in
        self.stamp = None

    def log(self, kind: str, **fields) -> None:
        if self.stamp is None:
            self._events.append({"kind": kind, **fields})
        else:
            self._events.append({"kind": kind, **self.stamp(), **fields})
        self.total += 1

    def tail(self, n: int | None = None) -> list[dict]:
        ev = list(self._events)
        return ev if n is None else ev[-n:]

    def __len__(self) -> int:
        return len(self._events)
