"""MetricsRegistry — the unified, labeled metric store for the whole
stack (the tentpole of the observability plane).

One registry holds every counter/gauge/histogram the layered ``stats()``
dicts used to scatter: instruments are keyed by (family name, sorted
label tuple), created on first touch, and a single :meth:`snapshot`
yields the consistent fleet view the exporters (obs/export.py) render.

Two write disciplines coexist:

* **push** — hot-path code holds a pre-bound instrument (no dict lookup
  or string formatting per batch: ``reg.counter(...)`` once at attach
  time, ``.inc()`` per event).
* **collect** — layers that already maintain their own counters register
  a collector callback; ``snapshot()`` runs the collectors first, so the
  registry never needs the layers to push on their hot paths at all.
  Collectors are *keyed*: a store reopening at the same path (same
  labels) replaces its stale predecessor instead of double-reporting.

Counter semantics across epoch events (memtable roll, compaction, store
reopen) come from :meth:`Counter.observe_total`: collectors report their
layer's *cumulative* value, and a reported value below the previous one
is treated as a source restart (the new source starts its own cumulative
count from zero), so registry counters stay monotonic across reopens.
"""

from __future__ import annotations

import bisect

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "publish_stats"]


class Counter:
    """Monotonic counter.  ``inc`` for push-style sources;
    ``observe_total`` for collectors that report a cumulative value."""

    kind = "counter"
    __slots__ = ("value", "_last_total")

    def __init__(self) -> None:
        self.value = 0.0
        self._last_total = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def observe_total(self, cur: float) -> None:
        """Fold a source's cumulative total into this counter.  A value
        below the previous observation means the source restarted (store
        reopen: the new instance counts from zero), so the whole new
        total is fresh progress — the registry counter never decreases."""
        cur = float(cur)
        if cur >= self._last_total:
            self.value += cur - self._last_total
        else:
            self.value += cur
        self._last_total = cur


class Gauge:
    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log2-bucketed latency histogram (microseconds): bounds 1, 2, 4,
    ... 2^20 us (~1 s) plus +inf, so one fixed layout covers cache-probe
    nanoseconds through maintenance stalls without configuration."""

    kind = "histogram"
    __slots__ = ("sum", "count", "max", "buckets", "exemplars")
    BOUNDS = tuple(float(1 << i) for i in range(21))

    def __init__(self) -> None:
        self.sum = 0.0
        self.count = 0
        self.max = 0.0
        self.buckets = [0] * (len(self.BOUNDS) + 1)
        # bucket index -> {"trace_id": int, "value": float}; latest trace
        # exemplar per bucket (a fat-tail bucket links to a concrete
        # trace a human can pull up with describe_trace)
        self.exemplars: dict[int, dict] = {}

    def observe(self, x: float) -> None:
        x = float(x)
        self.sum += x
        self.count += 1
        if x > self.max:
            self.max = x
        self.buckets[bisect.bisect_left(self.BOUNDS, x)] += 1

    def annotate(self, x: float, trace_id: int) -> None:
        """Attach a trace exemplar to the bucket ``x`` falls in (does
        not count as an observation — the causal tracer annotates the
        same families the StageTracer populates)."""
        x = float(x)
        self.exemplars[bisect.bisect_left(self.BOUNDS, x)] = {
            "trace_id": int(trace_id), "value": x}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    def __init__(self) -> None:
        # family name -> {"kind": str, "samples": {label_tuple: instrument}}
        self._families: dict[str, dict] = {}
        # collector key -> callback(reg); keyed so a reopened source
        # REPLACES its stale predecessor (same key) instead of leaving an
        # orphan collector double-reporting final values forever
        self._collectors: dict = {}

    # ------------------------------------------------------------ instruments
    @staticmethod
    def _label_key(labels: dict) -> tuple:
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def _get(self, kind: str, name: str, labels: dict):
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = {"kind": kind, "samples": {}}
        elif fam["kind"] != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam['kind']}, "
                f"requested {kind}")
        key = self._label_key(labels)
        inst = fam["samples"].get(key)
        if inst is None:
            inst = fam["samples"][key] = _KINDS[kind]()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # ------------------------------------------------------------- collectors
    def register_collector(self, key, fn) -> None:
        """Register (or replace — same key wins latest) a snapshot-time
        callback ``fn(registry)``.  Layers report through collectors so
        their hot paths never touch the registry."""
        self._collectors[key] = fn

    def unregister_collector(self, key) -> None:
        """Drop a collector (a detaching source); its already-folded
        counter values stay in the registry."""
        self._collectors.pop(key, None)

    def collect(self) -> None:
        for fn in list(self._collectors.values()):
            fn(self)

    # --------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """Run the collectors, then return every family as plain JSON
        types: ``{name: {"kind": ..., "samples": [{"labels": {...},
        "value": ...}, ...]}}`` — one call, the whole fleet, stable
        ordering."""
        self.collect()
        out: dict = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for key in sorted(fam["samples"]):
                inst = fam["samples"][key]
                if fam["kind"] == "histogram":
                    value = {"sum": float(inst.sum), "count": int(inst.count),
                             "max": float(inst.max),
                             "buckets": [int(b) for b in inst.buckets]}
                    if inst.exemplars:
                        value["exemplars"] = {
                            str(i): {"trace_id": int(e["trace_id"]),
                                     "value": float(e["value"])}
                            for i, e in sorted(inst.exemplars.items())}
                else:
                    value = float(inst.value)
                samples.append({"labels": dict(key), "value": value})
            out[name] = {"kind": fam["kind"], "samples": samples}
        return out

    def delta(self, prev: dict, cur: dict | None = None) -> dict:
        """Rolling-rate view between two snapshots (the self-tuning
        controller's per-interval observation vector in one call).

        ``prev`` is an earlier :meth:`snapshot`; ``cur`` defaults to a
        fresh one.  Same shape as a snapshot, but values are per-window:

        * counters — ``cur - prev``, with the same restart rule as
          :meth:`Counter.observe_total`: a current value *below* the
          previous one means the source restarted, so the whole current
          value is fresh progress for the window.
        * gauges — the current value (point-in-time by definition).
        * histograms — per-bucket count deltas plus sum/count deltas
          (restart rule keyed on ``count``); ``max`` is the current max
          (no windowed max is recoverable from two cumulative
          snapshots).  Exemplars are dropped — they are not rates.

        Samples new in ``cur`` count from zero; samples only in ``prev``
        (a detached source) are omitted.
        """
        if cur is None:
            cur = self.snapshot()

        def _index(snap_fam) -> dict:
            return {self._label_key(s["labels"]): s["value"]
                    for s in snap_fam["samples"]}

        out: dict = {}
        for name in sorted(cur):
            fam = cur[name]
            kind = fam["kind"]
            prev_by = _index(prev[name]) if name in prev \
                and prev[name]["kind"] == kind else {}
            samples = []
            for s in fam["samples"]:
                key = self._label_key(s["labels"])
                cv, pv = s["value"], prev_by.get(key)
                if kind == "counter":
                    if pv is None or cv < pv:      # new or restarted
                        value = float(cv)
                    else:
                        value = float(cv) - float(pv)
                elif kind == "gauge":
                    value = float(cv)
                else:
                    if pv is None or cv["count"] < pv["count"]:
                        value = {"sum": float(cv["sum"]),
                                 "count": int(cv["count"]),
                                 "max": float(cv["max"]),
                                 "buckets": [int(b) for b in cv["buckets"]]}
                    else:
                        value = {"sum": float(cv["sum"]) - float(pv["sum"]),
                                 "count": int(cv["count"]) - int(pv["count"]),
                                 "max": float(cv["max"]),
                                 "buckets": [int(a) - int(b) for a, b in
                                             zip(cv["buckets"],
                                                 pv["buckets"])]}
                samples.append({"labels": dict(key), "value": value})
            out[name] = {"kind": kind, "samples": samples}
        return out


def publish_stats(reg: MetricsRegistry, prefix: str, stats: dict,
                  labels: dict | None = None, skip=()) -> None:
    """Flatten a layer's ``stats()`` dict into labeled gauges.

    Naming/label conventions (obs/README.md):
    * numbers (and bools, as 0/1) -> gauge ``<prefix>_<key>``
    * str-keyed sub-dicts recurse with the key joined into the name
      (``auto_gc: {runs: 3}`` -> ``store_auto_gc_runs``)
    * int-keyed sub-dicts become a ``key=`` label per entry
      (``level_models_persisted: {2: 7}`` -> label ``key="2"``)
    * numeric lists become one sample per element, labeled ``index=``
      (the coordinator's ``per_shard_us`` -> ``index="0"`` ...)
    * strings, Nones, and non-numeric list elements are skipped
    """
    lb = dict(labels or {})
    for k in stats:
        if k in skip:
            continue
        _publish_value(reg, f"{prefix}_{k}", stats[k], lb)


def _publish_value(reg, name, v, lb) -> None:
    if isinstance(v, bool):
        reg.gauge(name, **lb).set(1.0 if v else 0.0)
    elif isinstance(v, (int, float)):
        reg.gauge(name, **lb).set(float(v))
    elif isinstance(v, dict):
        for kk, vv in v.items():
            if isinstance(kk, int):
                _publish_value(reg, name, vv, {**lb, "key": str(kk)})
            else:
                _publish_value(reg, f"{name}_{kk}", vv, lb)
    elif isinstance(v, (list, tuple)):
        for i, vv in enumerate(v):
            if isinstance(vv, (bool, int, float)):
                _publish_value(reg, name, vv, {**lb, "index": str(i)})
    elif v is None or isinstance(v, str):
        pass
    else:
        # numpy scalars and the like: publish anything float()-able
        try:
            reg.gauge(name, **lb).set(float(v))
        except (TypeError, ValueError):
            pass
