"""CausalTracer — sampled per-request causal tracing for the serving stack.

The paper's whole argument (§3) is a latency decomposition: knowing
*where* a lookup spends its time is what justifies learning.  The
:class:`~repro.obs.tracer.StageTracer` answers that in aggregate; this
module answers it **per request** — "why was *this* request's p99 4 ms"
— after the request fans into a coalesced batch, per-shard probes,
IOPool threads, and a group-commit fsync.

Design (mirrors the StageTracer's sampling discipline):

* **countdown sampling** — :meth:`CausalTracer.admit` traces one request
  every ``sample_every`` admissions.  The unsampled cost is one integer
  decrement; every downstream call site receives ``None`` and the
  null-check is a single identity test (HOTSYNC-clean, no string
  formatting, no allocation).
* **span graph, not a span stack** — spans carry explicit ``parent``
  and ``links`` (flow) edges so fan-in (N requests → 1 batch, M WAL
  appends → 1 commit group) and fan-out (1 batch → per-shard probes,
  1 batch → an IOPool task) are first-class.
* **cross-thread handoff** — a span begun on the tick loop may be ended
  inside an IOPool worker or the WAL committer thread
  (``end_span(..., retrack=True)`` re-stamps the track); the bounded
  ring is appended under a lock at begin, and each span is mutated by
  exactly one finisher, so spans never tear under out-of-order
  completion.
* **critical-path extraction** — batch-level spans credit their wall
  time to every member request's segment table; at completion the
  dominant segment labels a ``server_critical_path_us`` observation and
  the per-segment times annotate the matching ``server_stage_us``
  buckets as exemplars (fat tail bucket → concrete trace id).
* **export** — :meth:`to_trace_events` renders Chrome trace-event /
  Perfetto JSON ("X" complete events plus "s"/"f" flow arrows);
  :meth:`describe_trace` renders a human tree view.

``NULL_CTRACE`` is the obs-off null object: every method no-ops or
returns ``None`` so instrumented call sites never branch on "is tracing
enabled".
"""

from __future__ import annotations

import threading
import time
from collections import deque

__all__ = ["CausalTracer", "NullCausalTracer", "Span", "TraceContext",
           "CRITICAL_STAGES", "NULL_CTRACE", "SPAN_NAMES"]

_now = time.perf_counter

# Canonical span names (the causal-graph vocabulary; see the "Causal
# tracing" section of README.md — the OBSDRIFT lint rule checks every
# begin_span() literal against this tuple and the README table).
SPAN_NAMES = (
    "request",          # root: admission → completion of one request
    "queue_wait",       # admission → the batcher picks the request up
    "batch",            # fan-in: the coalesced batch (links from members)
    "dispatch",         # host overlay probe + async device enqueue
    "shard_probe",      # fan-out: one shard's overlay probe
    "device_compute",   # dispatch → retire (device latency to hide)
    "io_task",          # the ValueFetch body on an IOPool worker
    "value_fetch",      # the exposed wait joining the ValueFetch
    "write_apply",      # fan-in: apply one coalesced write batch
    "wal_append",       # WAL enqueue → durable (group-commit latency)
    "wal_commit",       # committer thread: one write+flush+fsync group
    "wal_sync",         # the tick loop's durability barrier
    "maintenance",      # a maintenance bubble (learn / GC / checkpoint)
)

# Critical-path segment labels: each request accumulates µs per segment;
# the dominant one labels its server_critical_path_us observation.
CRITICAL_STAGES = ("queue_wait", "dispatch", "device_compute",
                   "value_fetch", "wal_fsync")

# segment → server_stage_us stage whose buckets get the trace exemplar
_EXEMPLAR_STAGES = (("dispatch", "dispatch"),
                    ("device_compute", "compute"),
                    ("value_fetch", "value_fetch"))


class Span:
    """One node of the causal graph.  ``parent`` / ``links`` are span
    ids (ints) so a span survives its relatives' eviction from the ring;
    ``track`` is the thread name it is drawn on; ``ctxs`` are the
    member :class:`TraceContext`\\ s whose critical-path segment tables
    this span credits when ended with a ``stage``."""

    __slots__ = ("sid", "tid", "name", "parent", "t0", "t1", "track",
                 "links", "args", "ctxs")

    def __init__(self, sid: int, tid: int, name: str, parent: int,
                 t0: float, track: str, links, args, ctxs) -> None:
        self.sid = sid
        self.tid = tid
        self.name = name
        self.parent = parent
        self.t0 = t0
        self.t1 = 0.0
        self.track = track
        self.links = links
        self.args = args
        self.ctxs = ctxs

    @property
    def dur_us(self) -> float:
        return (self.t1 - self.t0) * 1e6 if self.t1 else 0.0


class TraceContext:
    """Per-sampled-request handle minted at admission: the trace id, the
    root span, the open queue-wait span, and the critical-path segment
    table (stage → µs) batch-level spans credit into."""

    __slots__ = ("tid", "root", "queue_span", "segments")

    def __init__(self, tid: int, root=None, queue_span=None) -> None:
        self.tid = tid
        self.root = root
        self.queue_span = queue_span
        self.segments: dict = {}


class CausalTracer:
    """Sampled causal tracing over a bounded span ring.

    Thread model: sids/tids are allocated and spans appended to the ring
    under ``_lock`` (begin may race between the tick loop, IOPool
    workers, and the WAL committer); each span is *ended* by exactly one
    caller, so end-side mutation is lock-free.  Segment crediting for a
    request happens before its completion barrier (the pipelined
    server's ``wal_sync`` / ``ValueFetch.wait``), so ``complete`` reads
    a quiesced table.
    """

    def __init__(self, registry, sample_every: int = 64,
                 ring: int = 4096) -> None:
        self.sample_every = max(int(sample_every), 1)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=int(ring))
        self._sid = 0
        self._tid = 0
        self._countdown = 0          # 0 → trace the next admit
        self._cur_write: Span | None = None
        self._cur_maint: Span | None = None
        self.traced_requests = 0
        self.completed_requests = 0
        # pre-bound histogram handles (never per-request dict lookups on
        # family/label resolution)
        self._crit = {s: registry.histogram("server_critical_path_us",
                                            stage=s)
                      for s in CRITICAL_STAGES}
        self._ex = {seg: registry.histogram("server_stage_us", stage=st)
                    for seg, st in _EXEMPLAR_STAGES}

    # ------------------------------------------------------------ spans

    def _new_span(self, name: str, tid: int, parent: int, ctxs,
                  links=(), t0: float = 0.0, args=None) -> Span:
        with self._lock:
            self._sid += 1
            sp = Span(self._sid, tid, name, parent,
                      t0 if t0 else _now(),
                      threading.current_thread().name,
                      list(links), args or {}, ctxs)
            self._ring.append(sp)
        return sp

    def admit(self, tick: int = -1) -> TraceContext | None:
        """Mint a trace for this request, or ``None`` (the common case).
        Opens the root ``request`` span and its ``queue_wait`` child."""
        if self._countdown:
            self._countdown -= 1
            return None
        self._countdown = self.sample_every - 1
        with self._lock:
            self._tid += 1
            tid = self._tid
        self.traced_requests += 1
        ctx = TraceContext(tid)
        ctx.root = self._new_span("request", tid, 0, (ctx,),
                                  args={"tick": int(tick)})
        ctx.queue_span = self._new_span("queue_wait", tid,
                                        ctx.root.sid, (ctx,))
        return ctx

    def join_batch(self, requests, kind: str = "batch") -> Span | None:
        """Fan-in: N admitted requests coalesce into one batch.  Ends
        every member's ``queue_wait`` span (crediting the segment) and
        opens a batch span flow-linked from each member's root.  Returns
        ``None`` when no member is traced."""
        ctxs = tuple(r.trace for r in requests if r.trace is not None)
        if not ctxs:
            return None
        now = _now()
        links = []
        for c in ctxs:
            q = c.queue_span
            if q is not None and not q.t1:
                q.t1 = now
                c.segments["queue_wait"] = (
                    c.segments.get("queue_wait", 0.0) + (now - q.t0) * 1e6)
            links.append(c.root.sid)
        name = "batch" if kind == "batch" else "write_apply"
        sp = self._new_span(name, ctxs[0].tid, ctxs[0].root.sid, ctxs,
                            links=links, t0=now,
                            args={"n_requests": len(requests)})
        return sp

    def begin_span(self, name: str, parent: Span | None,
                   link: Span | None = None, **args) -> Span | None:
        """Open a child of ``parent`` (a Span); ``None`` parent means the
        request is unsampled and the whole call is one identity test.
        ``link`` adds a flow arrow from another span (fan-out edges)."""
        if parent is None:
            return None
        links = (link.sid,) if link is not None else ()
        return self._new_span(name, parent.tid, parent.sid, parent.ctxs,
                              links=links, args=args)

    def end_span(self, span: Span | None, stage: str | None = None,
                 retrack: bool = False) -> None:
        """Close ``span`` (None-safe).  ``stage`` credits the span's
        duration to every member request's critical-path segment table;
        ``retrack=True`` re-stamps the track for spans ended on a
        different thread than they began on (IOPool / WAL committer)."""
        if span is None:
            return
        now = _now()
        span.t1 = now
        if retrack:
            span.track = threading.current_thread().name
        if stage is not None:
            us = (now - span.t0) * 1e6
            for c in span.ctxs:
                c.segments[stage] = c.segments.get(stage, 0.0) + us

    def complete(self, ctx: TraceContext | None,
                 tick: int = -1) -> None:
        """The request is done: close the root span, extract the
        critical path (dominant segment labels the
        ``server_critical_path_us`` observation), and attach the trace
        id as an exemplar to the matching ``server_stage_us`` buckets."""
        if ctx is None:
            return
        root = ctx.root
        if not root.t1:
            root.t1 = _now()
        if tick >= 0:
            root.args["done_tick"] = int(tick)
        self.completed_requests += 1
        segs = ctx.segments
        total_us = root.dur_us
        if segs:
            dominant = max(segs, key=segs.__getitem__)
            root.args["critical"] = dominant
            h = self._crit.get(dominant)
            if h is not None:
                h.observe(total_us)
                h.annotate(total_us, ctx.tid)
            for seg, eh in self._ex.items():
                us = segs.get(seg)
                if us:
                    eh.annotate(us, ctx.tid)
        else:
            self._crit["queue_wait"].observe(total_us)

    # ------------------------------------------------- write / WAL path

    def set_write(self, span: Span | None) -> None:
        """Arm (or with ``None``, disarm) the ambient write span: WAL
        appends issued while armed parent under it.  Tick-loop writes are
        serial, so a plain attribute is enough."""
        self._cur_write = span

    def wal_append(self) -> Span | None:
        """Called by the WAL writer inside ``append``: one attribute
        read when no traced write is in flight."""
        w = self._cur_write
        if w is None:
            return None
        return self._new_span("wal_append", w.tid, w.sid, w.ctxs)

    def wal_commit(self, appends, t0: float) -> None:
        """Called on the committer thread after the group's fsync:
        fan-in M ``wal_append`` spans → one ``wal_commit`` span.  Ends
        each append span at durability (crediting the ``wal_fsync``
        segment) and draws flow arrows append → commit."""
        spans = [s for s in appends if s is not None]
        if not spans:
            return
        first = spans[0]
        sp = self._new_span("wal_commit", first.tid, first.sid, (),
                            links=[s.sid for s in spans], t0=t0,
                            args={"group": len(spans)})
        sp.t1 = _now()
        sp.track = threading.current_thread().name
        for s in spans:
            self.end_span(s, stage="wal_fsync")

    # ------------------------------------------------------ maintenance

    def begin_maintenance(self, tick: int = -1, kind: str = "bubble"):
        """Open a maintenance root span (its own trace id — bubbles are
        not on any request's path) and expose it via :meth:`active_tid`
        so EventLog entries logged inside correlate to it."""
        with self._lock:
            self._tid += 1
            tid = self._tid
        sp = self._new_span("maintenance", tid, 0, (),
                            args={"tick": int(tick), "kind": kind})
        self._cur_maint = sp
        return sp

    def end_maintenance(self, span: Span | None) -> None:
        self._cur_maint = None
        self.end_span(span)

    def active_tid(self) -> int:
        """Trace id EventLog entries should be stamped with (0 when no
        maintenance span is open — events outside bubbles are unlinked)."""
        m = self._cur_maint
        return m.tid if m is not None else 0

    # ----------------------------------------------------------- export

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._ring)

    def get_trace(self, tid: int) -> list[Span]:
        """All ring spans of trace ``tid`` plus cross-trace spans that
        flow-link from them (e.g. the wal_commit group of an append)."""
        spans = self.spans()
        mine = [s for s in spans if s.tid == tid]
        sids = {s.sid for s in mine}
        extra = [s for s in spans
                 if s.tid != tid and any(l in sids for l in s.links)]
        return sorted(mine + extra, key=lambda s: (s.t0, s.sid))

    def to_trace_events(self) -> dict:
        """Chrome trace-event / Perfetto JSON: "X" complete events on
        per-thread tracks plus "s"/"f" flow arrows for every link edge.
        Timestamps are µs relative to the earliest span."""
        spans = [s for s in self.spans() if s.t1]
        if not spans:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        by_sid = {s.sid: s for s in spans}
        origin = min(s.t0 for s in spans)
        tids: dict = {}      # track name → chrome tid

        def us(t: float) -> float:
            return round((t - origin) * 1e6, 3)

        def track(name: str) -> int:
            return tids.setdefault(name, len(tids) + 1)

        events = []
        flow = 0
        for s in sorted(spans, key=lambda x: (x.t0, x.sid)):
            args = {"trace": s.tid, "sid": s.sid}
            if s.parent:
                args["parent"] = s.parent
            args.update(s.args)
            events.append({"ph": "X", "name": s.name, "cat": "serve",
                           "ts": us(s.t0), "dur": round(s.dur_us, 3),
                           "pid": 1, "tid": track(s.track), "args": args})
            for src_sid in s.links:
                src = by_sid.get(src_sid)
                if src is None or not src.t1:
                    continue        # source evicted from the ring
                flow += 1
                # arrow departs when the source ends, lands no earlier
                # than it departed and no later than the dest interval
                ts_s = us(min(src.t1, s.t1))
                ts_f = max(ts_s, us(s.t0))
                events.append({"ph": "s", "id": flow, "name": "causal",
                               "cat": "flow", "ts": ts_s, "pid": 1,
                               "tid": track(src.track)})
                events.append({"ph": "f", "bp": "e", "id": flow,
                               "name": "causal", "cat": "flow",
                               "ts": ts_f, "pid": 1,
                               "tid": track(s.track)})
        events.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "X" else 1))
        meta = [{"ph": "M", "name": "thread_name", "pid": 1, "tid": n,
                 "args": {"name": t}} for t, n in sorted(
                     tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def describe_trace(self, tid: int) -> str:
        """Human tree view of one trace (children indented under their
        parent; cross-trace fan-ins shown with a ``~>`` marker)."""
        spans = self.get_trace(tid)
        if not spans:
            return f"trace {tid}: no spans in ring"
        by_parent: dict = {}
        sids = {s.sid for s in spans}
        roots = []
        for s in spans:
            if s.parent in sids:
                by_parent.setdefault(s.parent, []).append(s)
            else:
                roots.append(s)
        out = [f"trace {tid}:"]

        def emit(s: Span, depth: int) -> None:
            mark = "~>" if s.tid != tid else "--"
            extra = ""
            if s.links:
                extra += f" links={list(s.links)}"
            if s.args:
                kv = ", ".join(f"{k}={v}" for k, v in s.args.items())
                extra += f" [{kv}]"
            out.append(f"  {'  ' * depth}{mark} {s.name} "
                       f"{s.dur_us:9.1f}us  sid={s.sid} "
                       f"@{s.track}{extra}")
            for c in sorted(by_parent.get(s.sid, ()),
                            key=lambda x: (x.t0, x.sid)):
                emit(c, depth + 1)

        for r in sorted(roots, key=lambda x: (x.t0, x.sid)):
            emit(r, 0)
        return "\n".join(out)


class NullCausalTracer:
    """Tracing-off null object: one method call, no state, no branches
    at the call site."""

    __slots__ = ()
    sample_every = 0

    def admit(self, tick: int = -1):
        return None

    def join_batch(self, requests, kind: str = "batch"):
        return None

    def begin_span(self, name, parent, link=None, **args):
        return None

    def end_span(self, span, stage=None, retrack=False) -> None:
        pass

    def complete(self, ctx, tick: int = -1) -> None:
        pass

    def set_write(self, span) -> None:
        pass

    def wal_append(self):
        return None

    def wal_commit(self, appends, t0: float) -> None:
        pass

    def begin_maintenance(self, tick: int = -1, kind: str = "bubble"):
        return None

    def end_maintenance(self, span) -> None:
        pass

    def active_tid(self) -> int:
        return 0

    def spans(self) -> list:
        return []

    def get_trace(self, tid: int) -> list:
        return []

    def to_trace_events(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def describe_trace(self, tid: int) -> str:
        return f"trace {tid}: tracing disabled"


NULL_CTRACE = NullCausalTracer()
