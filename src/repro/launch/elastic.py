"""Elastic re-meshing + straggler mitigation (design + runnable simulation).

At 1000+ nodes the failure domain is the host.  The design:

  1. Checkpoints are mesh-shape-agnostic (logical shards, checkpoint/ckpt.py)
     — restoring onto a different mesh is just a different device_put layout.
  2. On host failure the controller rebuilds the mesh with the `data` axis
     shrunk to the largest feasible size (model axis is kept — TP groups are
     intra-host domains), then resumes from the last committed step.
  3. Data assignment is a pure function of (step, host, n_hosts)
     (data/pipeline.py), so re-meshing needs no loader state: survivors
     recompute the failed hosts' shards.
  4. Stragglers: because any host can compute any shard, the controller can
     reassign the slowest host's shard to an idle "hot spare" at a step
     boundary (work-stealing); gradient math is unchanged since assignments
     are deterministic per step.

``shrink_plan`` and ``ElasticController`` implement 2-3 as a runnable
simulation driven by the tests; on real hardware the same logic runs in the
job controller with device health from the fleet scheduler.
"""

from __future__ import annotations

import dataclasses

__all__ = ["shrink_plan", "ElasticController"]


def shrink_plan(n_data: int, n_failed: int) -> int:
    """Largest data-parallel width <= n_data - n_failed that divides the
    global batch cleanly (powers of two here)."""
    target = n_data - n_failed
    width = 1
    while width * 2 <= target:
        width *= 2
    return width


@dataclasses.dataclass
class HostState:
    alive: bool = True
    slow: bool = False


class ElasticController:
    """Step-boundary membership + work assignment (simulation)."""

    def __init__(self, n_hosts: int) -> None:
        self.hosts = [HostState() for _ in range(n_hosts)]
        self.events: list = []

    @property
    def alive(self) -> list[int]:
        return [i for i, h in enumerate(self.hosts) if h.alive]

    def fail(self, host: int, step: int) -> None:
        self.hosts[host].alive = False
        self.events.append(("fail", host, step))

    def mark_slow(self, host: int, step: int) -> None:
        self.hosts[host].slow = True
        self.events.append(("slow", host, step))

    def assignment(self, step: int) -> dict[int, list[int]]:
        """shard index -> host, rerouting shards of dead/slow hosts to the
        healthy ones round-robin (work stealing)."""
        healthy = [i for i, h in enumerate(self.hosts)
                   if h.alive and not h.slow]
        if not healthy:
            healthy = self.alive
        n_shards = shrink_plan(len(self.hosts),
                               len(self.hosts) - len(self.alive))
        out: dict[int, list[int]] = {h: [] for h in healthy}
        for s in range(n_shards):
            out[healthy[s % len(healthy)]].append(s)
        return out
