"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh, rules)`` returns sharded specs for the train
or serve step of each (architecture x input-shape) cell, including decode KV
caches (batch over (pod,data); cache context over the model axis =
split-KV decode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.models import init_caches, param_shapes
from repro.models.config import ModelConfig
from .mesh import batch_axes
from .sharding import ShardingRules, logical_to_spec

__all__ = ["input_specs", "cache_specs", "batch_sds"]


def _sds(mesh, shape, dtype, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _bspec(mesh, gb: int) -> P:
    """Batch partition over (pod, data) restricted to axes whose product
    divides the global batch (long_500k has gb=1 -> replicated)."""
    axes = []
    prod = 1
    for a in batch_axes(mesh):
        if gb % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return P(tuple(axes)) if axes else P()


def batch_sds(cfg: ModelConfig, shape: ShapeSpec, mesh, rules: ShardingRules):
    """Training/prefill batch specs."""
    GB, S, D = shape.global_batch, shape.seq_len, cfg.d_model
    bspec = _bspec(mesh, GB)
    batch = {}
    if cfg.inputs_embeds:
        batch["embeds"] = _sds(mesh, (GB, S, D), jnp.bfloat16, bspec)
    else:
        batch["tokens"] = _sds(mesh, (GB, S), jnp.int32, bspec)
    batch["labels"] = _sds(mesh, (GB, S), jnp.int32, bspec)
    if cfg.n_image_tokens:
        batch["image_embed"] = _sds(mesh, (GB, cfg.n_image_tokens, D),
                                    jnp.bfloat16, bspec)
    return batch


def _cache_axes_for(path_leaf_shape, batch_first=True):
    """Logical axes for a cache leaf: batch, cache context dim on axis 1 when
    it is the long one."""
    nd = len(path_leaf_shape)
    if nd == 0:
        return ()
    axes = ["batch"] + [None] * (nd - 1)
    return tuple(axes)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules: ShardingRules):
    """Sharded specs for decode caches: evaluate init_caches abstractly and
    attach shardings: batch dim -> (pod,data); the context (T) dim of
    attention caches -> model axis (split-KV decode)."""
    GB, T = shape.global_batch, shape.seq_len

    caches = jax.eval_shape(lambda: init_caches(cfg, GB, T))
    model_size = mesh.shape.get("model", 1)

    def to_spec(leaf):
        # leaf shapes are (L, ...) stacked; find dims:
        shp = leaf.shape
        parts = [None] * len(shp)
        bs = _bspec(mesh, GB)
        if len(shp) >= 2 and shp[1] == GB and len(bs) and bs[0]:
            parts[1] = bs[0]
        # context dim: a dim equal to T or the window size, shard over model
        for i in range(2, len(shp)):
            d = shp[i]
            if d >= 256 and d % model_size == 0 and d in (
                    T, min(T, cfg.window or T)):
                parts[i] = "model"
                break
        return _sds(mesh, shp, leaf.dtype, P(*parts))

    return jax.tree.map(to_spec, caches)


def decode_batch_sds(cfg: ModelConfig, shape: ShapeSpec, mesh):
    GB, D = shape.global_batch, cfg.d_model
    bspec = _bspec(mesh, GB)
    batch = {}
    if cfg.inputs_embeds:
        batch["embeds"] = _sds(mesh, (GB, 1, D), jnp.bfloat16, bspec)
    else:
        batch["tokens"] = _sds(mesh, (GB, 1), jnp.int32, bspec)
    if cfg.n_image_tokens:
        batch["image_embed"] = _sds(mesh, (GB, cfg.n_image_tokens, D),
                                    jnp.bfloat16, bspec)
    return batch


def param_specs_sharded(cfg: ModelConfig, mesh, rules: ShardingRules):
    shapes = param_shapes(cfg)

    def one(s):
        spec = logical_to_spec(rules, s.axes, shape=s.shape, mesh=mesh)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, shapes)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                rules: ShardingRules):
    """All step inputs for one cell: (params, extras...) per step kind."""
    params = param_specs_sharded(cfg, mesh, rules)
    if shape.kind in ("train", "prefill"):
        return params, batch_sds(cfg, shape, mesh, rules)
    return params, cache_specs(cfg, shape, mesh, rules), \
        decode_batch_sds(cfg, shape, mesh)
