"""Training launcher (end-to-end driver).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 50 --seq 128 --batch 8 [--smoke]

Runs the real Trainer (data pipeline -> jit train step -> async checkpoints)
on whatever devices exist; on the CPU container use --smoke for the reduced
config.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataConfig, TokenDataset, synthetic_tokens
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.launch.steps import TrainConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab)
    tokens = synthetic_tokens(args.seq * args.batch * (args.steps + 4) + 1,
                              cfg.vocab)
    ds = TokenDataset(tokens, dcfg)
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    tr = Trainer(cfg, TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                                    train=TrainConfig(remat="none")), ds)
    out = tr.run()
    first, last = out["losses"][0], out["losses"][-1]
    print(f"step {first[0]}: loss {first[1]:.4f}  ->  "
          f"step {last[0]}: loss {last[1]:.4f}")


if __name__ == "__main__":
    main()
