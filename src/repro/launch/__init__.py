"""Launchers: mesh, sharding rules, dry-run, roofline, train/serve drivers."""
