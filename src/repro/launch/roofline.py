"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_bf16
  memory     = HLO_bytes_per_device / hbm_bw
  collective = collective_bytes_per_device / link_bw   (ICI; DCI for "pod")

cost_analysis() counts while bodies ONCE (verified), so per-layer costs are
recovered with the depth-delta method: compile the config at n_units=1 and
n_units=2; the delta is the exact per-unit cost, and

  total(U) = cost(u2) + (U - 2) * (cost(u2) - cost(u1))

Collective bytes come from the trip-count-aware HLO walk (hlo_parse.py) on
the FULL config, so no extrapolation is needed there.

Also reports MODEL_FLOPS (6*N*D train / 2*N*D prefill / 2*N_active*B decode)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import json
import pathlib

from .mesh import HW

__all__ = ["analyze_cell", "load_cells", "report", "model_flops"]


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step for the whole job."""
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the cache too but the
    # parameter term is the canonical model-flops convention
    return 2.0 * n_active * shape.global_batch


def _extrapolated(full: dict, u1: dict | None, u2: dict | None, key: str,
                  n_units: int) -> float:
    """Depth-delta extrapolation for a cost_analysis metric."""
    base = full.get("cost", {}).get(key)
    if u1 is None or u2 is None or "cost" not in u1 or "cost" not in u2:
        return float(base) if base is not None else 0.0
    c1 = float(u1["cost"].get(key, 0.0))
    c2 = float(u2["cost"].get(key, 0.0))
    per_unit = c2 - c1
    return c2 + (n_units - 2) * per_unit


def analyze_cell(full: dict, u1: dict | None = None, u2: dict | None = None):
    """Returns the roofline record for one cell."""
    if "skipped" in full:
        return {"arch": full["arch"], "shape": full["shape"],
                "mesh": full.get("mesh"), "skipped": full["skipped"]}
    if "error" in full:
        return {"arch": full["arch"], "shape": full["shape"],
                "mesh": full.get("mesh"), "error": full["error"][-300:]}
    from repro.configs.base import get_config
    arch, shape = full["arch"], full["shape"]
    if arch == "bourbon_kv":
        n_units = 1   # no layer scan: full-build counts are already exact
    else:
        cfg = get_config(arch)
        n_units = cfg.n_units

    flops_dev = _extrapolated(full, u1, u2, "flops", n_units)
    bytes_dev = _extrapolated(full, u1, u2, "bytes accessed", n_units)
    metered = bool(u1 and u2 and "cost" in u1 and "cost" in u2)
    coll = full.get("collectives", {})
    coll_bytes_dev = float(sum(coll.values()))
    multi = full.get("mesh") == "2x16x16"
    link_bw = HW.DCI_BW if multi else HW.ICI_BW

    t_compute = flops_dev / HW.PEAK_BF16_FLOPS
    t_memory = bytes_dev / HW.HBM_BW
    t_coll = coll_bytes_dev / link_bw

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    n_dev = full.get("n_devices", 256)
    mf = model_flops(arch, shape) if arch != "bourbon_kv" else 0.0
    mf_dev = mf / n_dev
    t_ideal = mf_dev / HW.PEAK_BF16_FLOPS
    return {
        "arch": arch, "shape": shape, "mesh": full.get("mesh"),
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_bytes_dev,
        "collective_detail": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": mf,
        "useful_ratio": (mf_dev / flops_dev) if (flops_dev and mf) else 0.0,
        "roofline_fraction": (t_ideal / bound) if (bound and mf) else 0.0,
        "memory_peak_gib": full["memory"]["peak_bytes"] / 2**30,
        "fits_hbm": full["memory"]["peak_bytes"] <= HW.HBM_BYTES,
        "compile_s": full.get("compile_s"),
        "metered": metered,   # False -> scan-counted (terms underestimated)
    }


def load_cells(out_dir: str = "experiments/dryrun", mesh_tag: str = "single"):
    out = pathlib.Path(out_dir)
    cells = {}
    for p in sorted(out.glob(f"*__{mesh_tag}.json")):
        full = json.loads(p.read_text())
        stem = p.stem.replace(f"__{mesh_tag}", "")
        u1p = out / f"{stem}__{mesh_tag}__u1.json"
        u2p = out / f"{stem}__{mesh_tag}__u2.json"
        u1 = json.loads(u1p.read_text()) if u1p.exists() else None
        u2 = json.loads(u2p.read_text()) if u2p.exists() else None
        cells[stem] = analyze_cell(full, u1, u2)
    return cells


def report(out_dir: str = "experiments/dryrun", mesh_tag: str = "single"):
    cells = load_cells(out_dir, mesh_tag)
    cols = ["arch", "shape", "dominant", "t_compute_s", "t_memory_s",
            "t_collective_s", "useful_ratio", "roofline_fraction",
            "memory_peak_gib", "fits_hbm"]
    lines = ["\t".join(cols)]
    for key in sorted(cells):
        c = cells[key]
        if "skipped" in c:
            lines.append(f"{c['arch']}\t{c['shape']}\tSKIP: {c['skipped'][:60]}")
            continue
        if "error" in c:
            lines.append(f"{c['arch']}\t{c['shape']}\tERROR")
            continue
        lines.append("\t".join([
            c["arch"], c["shape"], c["dominant"],
            f"{c['t_compute_s']:.4g}", f"{c['t_memory_s']:.4g}",
            f"{c['t_collective_s']:.4g}", f"{c['useful_ratio']:.3f}",
            f"{c['roofline_fraction']:.3f}", f"{c['memory_peak_gib']:.1f}",
            str(c["fits_hbm"]),
        ]))
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(report(args.out_dir, args.mesh))
