"""Logical-to-physical sharding rules.

Models annotate params/activations with logical axis names; a ShardingRules
table maps them to mesh axes.  Changing the table (not the model) is the
sharding lever used by the §Perf hillclimb.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P, NamedSharding

__all__ = ["ShardingRules", "DEFAULT_RULES", "rules_ctx", "constraint",
           "logical_to_spec", "param_sharding"]

# logical axis -> mesh axis (or None = replicated).  "batch" maps to the
# combined (pod, data) axes; "embed"/"heads"/"mlp"/"vocab"/"experts" are the
# tensor/FSDP dims.
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,           # activations: replicated along model by default
    "embed_fsdp": ("pod", "data"),  # params+opt: FSDP over pod x data (ZeRO-3)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    "qk": None, "v": None, "state": None, "conv": None, "lora": None,
    "image": None,
}


class ShardingRules(dict):
    def spec(self, axes: tuple) -> P:
        parts = []
        for a in axes:
            m = self.get(a)
            parts.append(m)
        return P(*parts)


_tls = threading.local()


def current_rules():
    return getattr(_tls, "rules", None), getattr(_tls, "mesh_axes", None)


@contextlib.contextmanager
def rules_ctx(rules: ShardingRules | None, mesh=None):
    old = (getattr(_tls, "rules", None), getattr(_tls, "mesh_axes", None))
    _tls.rules = rules
    if mesh is not None:
        _tls.mesh_axes = dict(zip(mesh.axis_names, mesh.shape.values())) \
            if hasattr(mesh, "axis_names") else None
    elif rules is None:
        _tls.mesh_axes = None
    try:
        yield
    finally:
        _tls.rules, _tls.mesh_axes = old


def _filter_spec(spec: P, mesh_axes: dict | None, shape=None) -> P:
    """Drop mesh axes not present in the current mesh, duplicates (first
    occurrence wins), and axes that do not divide the corresponding dim."""
    if mesh_axes is None:
        return spec
    used: set = set()
    parts = []
    for i, part in enumerate(spec):
        flat = part if isinstance(part, tuple) else (part,)
        keep = tuple(a for a in flat if a in mesh_axes and a not in used)
        if shape is not None and keep:
            sz = 1
            for a in keep:
                sz *= mesh_axes[a]
            if sz and shape[i] % sz != 0:
                keep = ()
        used.update(keep)
        parts.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return P(*parts)


def constraint(x, axes: tuple):
    """Activation sharding constraint by logical axes (no-op without rules
    or outside a mesh context)."""
    rules, mesh_axes = current_rules()
    if rules is None:
        return x
    spec = _filter_spec(rules.spec(axes), mesh_axes, x.shape)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def param_constraint(x, axes: tuple):
    """Parameter-rule (embed -> embed_fsdp) sharding constraint; used inside
    the layer scan to pin per-layer param slices to their FSDP layout so XLA
    gathers them per-iteration instead of hoisting a whole-stack all-gather
    out of the loop (a ~params/TP-sized resident buffer otherwise)."""
    rules, mesh_axes = current_rules()
    if rules is None or len(axes) != x.ndim:
        return x
    parts = []
    for a in axes:
        key = "embed_fsdp" if a == "embed" else a
        parts.append(rules.get(key))
    spec = _filter_spec(P(*parts), mesh_axes, x.shape)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def logical_to_spec(rules: ShardingRules, axes: tuple,
                    param: bool = True, shape: tuple | None = None,
                    mesh=None) -> P:
    """Resolve logical axes -> PartitionSpec in one shape-aware pass.

    A mesh axis is assigned only if (a) it exists in the mesh, (b) it is not
    already used by an earlier dim, and (c) it divides the dim.  A later
    logical axis can therefore pick up a mesh axis an earlier one could not
    use (e.g. mixtral's 8 experts skip "model"; the per-expert mlp dim takes
    it instead)."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.shape.values())) if mesh \
        else None
    used: set = set()
    parts = []
    for i, a in enumerate(axes):
        key = "embed_fsdp" if (param and a == "embed") else a
        cand = rules.get(key)
        flat = cand if isinstance(cand, tuple) else (cand,)
        keep = []
        for ax in flat:
            if not ax or ax in used:
                continue
            if mesh_axes is not None:
                if ax not in mesh_axes:
                    continue
                sz = mesh_axes[ax]
                dim = shape[i] if shape is not None else None
                cur = 1
                for k in keep:
                    cur *= mesh_axes[k]
                if dim is not None and dim % (cur * sz) != 0:
                    continue
            keep.append(ax)
        for ax in keep:
            used.add(ax)
        parts.append(tuple(keep) if len(keep) > 1 else
                     (keep[0] if keep else None))
    return P(*parts)


def param_sharding(mesh, rules: ShardingRules, spec_tree):
    """ShapeDtypeStruct tree (with .axes) -> tree with NamedSharding attached."""
    def one(s):
        axes = getattr(s, "axes", None)
        if axes is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                        sharding=NamedSharding(mesh, P()))
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, logical_to_spec(
                rules, axes, shape=s.shape, mesh=mesh)))
    return jax.tree.map(one, spec_tree)
