"""Step builders: train_step (fwd+bwd+AdamW, remat, microbatching) and
serve_step (one-token decode over caches).  These are what the dry-run
lowers and what the real launchers run.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import decode_step, loss_fn
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, adamw_state_shapes
from .sharding import ShardingRules, rules_ctx

__all__ = ["TrainConfig", "build_train_step", "build_serve_step",
           "opt_state_specs"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: str = "full"          # none | dots | dots_no_batch | full
    microbatch: int = 1          # gradient-accumulation steps
    unroll: bool = False         # metering builds (roofline)
    scan_param_fsdp: bool = False  # per-layer FSDP gather inside the scan
    grad_accum_dtype: str = "float32"   # bf16 halves the accumulation buffer
    optim: AdamWConfig = AdamWConfig()


def opt_state_specs(cfg: ModelConfig, mesh, rules: ShardingRules,
                    tcfg: TrainConfig):
    from .inputs import param_specs_sharded
    from repro.models import param_shapes
    pspecs = param_shapes(cfg)
    state_shapes = adamw_state_shapes(pspecs, tcfg.optim)
    # reuse param sharding resolution on the mirrored axes
    from jax.sharding import NamedSharding, PartitionSpec as P
    from .sharding import logical_to_spec

    def one(s):
        axes = getattr(s, "axes", None)
        if axes is None or len(axes) != len(s.shape):
            return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                        sharding=NamedSharding(mesh, P()))
        spec = logical_to_spec(rules, axes, shape=s.shape, mesh=mesh)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, state_shapes)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                     rules: ShardingRules | None = None, mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Microbatching scans over accumulation chunks."""

    def compute_grads(params, batch):
        def loss(p):
            l, m = loss_fn(p, cfg, batch, remat=tcfg.remat,
                           unroll=tcfg.unroll,
                           scan_param_fsdp=tcfg.scan_param_fsdp)
            return l, m
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        return l, grads, metrics

    def train_step(params, opt_state, batch):
        with rules_ctx(rules, mesh):
            if tcfg.microbatch > 1:
                mb = tcfg.microbatch
                split = jax.tree.map(
                    lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                    batch)
                acc_dt = {"float32": jnp.float32,
                          "bfloat16": jnp.bfloat16}[tcfg.grad_accum_dtype]
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dt), params)

                def body(acc, chunk):
                    loss_acc, g_acc = acc
                    l, g, _ = compute_grads(params, chunk)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), g_acc, g)
                    return (loss_acc + l, g_acc), None

                (l, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zeros), split)
                l = l / mb
                grads = jax.tree.map(lambda g: g / mb, grads)
            else:
                l, grads, _ = compute_grads(params, batch)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 tcfg.optim)
            return params, opt_state, {"loss": l, **om}

    return train_step


def build_serve_step(cfg: ModelConfig, rules: ShardingRules | None = None,
                     mesh=None, unroll: bool = False):
    """serve_step(params, caches, batch) -> (logits, caches): one new token
    against a pre-filled KV/state cache (the decode_* and long_* cells)."""

    def serve_step(params, caches, batch):
        with rules_ctx(rules, mesh):
            logits, caches = decode_step(
                params, cfg, caches,
                tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                aux={k: v for k, v in batch.items() if k == "image_embed"},
                unroll=unroll)
            return logits, caches

    return serve_step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, rng):
    from repro.models import init_params
    params = init_params(cfg, rng)
    return params, adamw_init(params, tcfg.optim)
