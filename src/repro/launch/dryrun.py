import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  512 placeholder host devices back the production
# mesh; smoke tests / benches never import this module and see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh and record memory / cost / collective stats.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k [--multi-pod] [--units N] [--remat full] ...
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep (subprocesses)

Results are cached as JSON under experiments/dryrun/.
"""

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             units: int | None = None, remat: str = "full",
             microbatch: int = 0, rule_overrides: dict | None = None,
             flash_kv_chunk: int | None = None,
             metering: bool = False, scan_param_fsdp: bool = False,
             grad_accum_dtype: str = "float32") -> dict:
    import jax
    import repro  # noqa: F401  (x64 etc.)
    from repro.configs.base import SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import DEFAULT_RULES, ShardingRules
    from repro.launch.inputs import input_specs
    from repro.launch.steps import (TrainConfig, build_serve_step,
                                    build_train_step, opt_state_specs)
    from repro.launch.hlo_parse import collective_breakdown
    from repro.models import forward
    from repro.models.layers import shard as shard_act

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    res = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "units": units, "remat": remat, "microbatch": microbatch}
    if not ok:
        res["skipped"] = why
        return res
    if units is not None:
        cfg = cfg.scaled(units)
    if flash_kv_chunk is not None:
        import repro.models.attention as att
        att.FLASH_KV_CHUNK = flash_kv_chunk
    if metering:
        # metering build: unrolled layers AND unrolled (real-size) chunk
        # loops, so cost_analysis — which counts each while body ONCE — is
        # exact for both flops and bytes.  memory_analysis of metering
        # builds is ignored; the full (scanned) build provides memory.
        # Remaining undercount: sLSTM's per-timestep scan (documented).
        import repro.models.attention as att
        import repro.models.ssm as ssm_mod
        att.UNROLL_CHUNKS = True
        ssm_mod.UNROLL_CHUNKS = True
        microbatch = 1
        res["metering"] = True

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(DEFAULT_RULES)
    if rule_overrides:
        rules.update(rule_overrides)
    res["rules"] = {k: v for k, v in rules.items()}
    if microbatch == 0:  # auto: one sequence per data shard per microstep
        data_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        microbatch = max(1, shape.global_batch // data_shards) \
            if (shape.kind == "train" and cfg.d_model >= 2048) else 1
        res["microbatch"] = microbatch
    tcfg = TrainConfig(remat=remat, microbatch=microbatch, unroll=metering,
                       scan_param_fsdp=scan_param_fsdp,
                       grad_accum_dtype=grad_accum_dtype)
    res["scan_param_fsdp"] = scan_param_fsdp
    res["grad_accum_dtype"] = grad_accum_dtype

    # function-local on purpose: jaxcompat imports jax, and this
    # module's --all parent must never pay jax init (see header)
    from repro.core.jaxcompat import set_mesh
    with set_mesh(mesh):
        if shape.kind == "train":
            step = build_train_step(cfg, tcfg, rules, mesh)
            pspec, bspec = input_specs(cfg, shape, mesh, rules)
            ospec = opt_state_specs(cfg, mesh, rules, tcfg)
            fn = jax.jit(step, donate_argnums=(0, 1))
            args = (pspec, ospec, bspec)
        elif shape.kind == "prefill":
            pspec, bspec = input_specs(cfg, shape, mesh, rules)
            from repro.launch.sharding import rules_ctx

            def prefill(params, batch):
                with rules_ctx(rules, mesh):
                    # serving prefill: logits for the last position only
                    from repro.models.model import (_dtype, apply_norm,
                                                    execution_runs)
                    logits, _ = forward(
                        params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        aux={k: v for k, v in batch.items()
                             if k == "image_embed"},
                        remat="none", last_only=True, unroll=metering)
                    return logits
            fn = jax.jit(prefill)
            args = (pspec, bspec)
        else:  # decode
            step = build_serve_step(cfg, rules, mesh, unroll=metering)
            pspec, cspec, bspec = input_specs(cfg, shape, mesh, rules)
            fn = jax.jit(step, donate_argnums=(1,))
            args = (pspec, cspec, bspec)

        lowered = fn.lower(*args)
        res["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        res["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed")}
        hlo = compiled.as_text()
        res["hlo_chars"] = len(hlo)
        if os.environ.get("DRYRUN_DUMP_HLO"):
            pathlib.Path(os.environ["DRYRUN_DUMP_HLO"]).write_text(hlo)
        res["collectives"] = collective_breakdown(hlo)
        res["n_devices"] = mesh.size
    return res


def run_store_cell(*, multi_pod: bool = False, n_keys: int = 1 << 30,
                   probe_batch: int = 1 << 20, seg_search: str = "bisect",
                   combine: str = "reduce_scatter") -> dict:
    """Dry-run the distributed Bourbon store (the paper's own workload):
    range-partitioned snapshot over every mesh device, one batched GET."""
    import jax
    import jax.numpy as jnp
    import repro  # noqa: F401
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import (DistStoreConfig, build_dist_get,
                                        dist_state_specs)
    from repro.launch.hlo_parse import collective_breakdown
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = DistStoreConfig(n_keys=n_keys, probe_batch=probe_batch)
    res = {"arch": "bourbon_kv", "shape": f"get_{probe_batch}",
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_keys": n_keys, "probe_batch": probe_batch,
           "seg_search": seg_search, "combine": combine}
    t0 = time.time()
    # function-local on purpose: jaxcompat imports jax, and this
    # module's --all parent must never pay jax init (see header)
    from repro.core.jaxcompat import set_mesh
    with set_mesh(mesh):
        specs = dist_state_specs(mesh, cfg)
        probes = jax.ShapeDtypeStruct(
            (probe_batch,), jnp.int64,
            sharding=NamedSharding(mesh, P(tuple(mesh.axis_names))))
        fn = build_dist_get(mesh, cfg, seg_search=seg_search,
                            combine=combine)
        lowered = fn.lower(specs, probes)
        res["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes)}
        ca = compiled.cost_analysis() or {}
        res["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed")}
        res["collectives"] = collective_breakdown(compiled.as_text())
        res["n_devices"] = mesh.size
    return res


def _cache_path(out_dir, arch, shape, mesh_tag, suffix=""):
    return pathlib.Path(out_dir) / f"{arch}__{shape}__{mesh_tag}{suffix}.json"


def sweep(out_dir: str, multi_pod: bool, with_depth_variants: bool,
          jobs: list | None = None):
    """Run every cell in a subprocess (isolates compile memory), cache JSON."""
    from repro.configs.base import ARCHS, SHAPES
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    todo = jobs or [(a, s) for a in ARCHS for s in SHAPES]
    for arch, shape in todo:
        variants = [("", None)]
        if with_depth_variants:
            variants += [("__u1", 1), ("__u2", 2)]
        for suffix, units in variants:
            path = _cache_path(out, arch, shape, mesh_tag, suffix)
            if path.exists():
                print(f"[cached] {path.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", str(path)]
            if multi_pod:
                cmd.append("--multi-pod")
            if units is not None:
                cmd += ["--units", str(units), "--metering"]
            print(f"[run] {' '.join(cmd[3:])}", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            if r.returncode != 0:
                err = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "units": units, "error": r.stderr[-4000:]}
                path.write_text(json.dumps(err, indent=1))
                print(f"  FAILED ({time.time()-t0:.0f}s): "
                      f"{r.stderr.strip().splitlines()[-1] if r.stderr else '?'}")
            else:
                print(f"  ok ({time.time()-t0:.0f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--units", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="0 = auto (one seq per data shard for >=2B trains)")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=mesh_axis override, e.g. seq=model")
    ap.add_argument("--flash-kv-chunk", type=int, default=None)
    ap.add_argument("--metering", action="store_true")
    ap.add_argument("--scan-param-fsdp", action="store_true")
    ap.add_argument("--grad-accum-dtype", default="float32")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--store", action="store_true",
                    help="dry-run the distributed bourbon_kv store cell")
    ap.add_argument("--store-seg-search", default="bisect")
    ap.add_argument("--store-combine", default="reduce_scatter")
    ap.add_argument("--depth-variants", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    if args.store:
        res = run_store_cell(multi_pod=args.multi_pod,
                             seg_search=args.store_seg_search,
                             combine=args.store_combine)
        js = json.dumps(res, indent=1, default=str)
        print(js)
        if args.out:
            pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
            pathlib.Path(args.out).write_text(js)
        return
    if args.all:
        sweep(args.out_dir, args.multi_pod, args.depth_variants)
        return

    overrides = {}
    for r in args.rule:
        k, _, v = r.partition("=")
        overrides[k] = None if v in ("", "none", "None") else (
            tuple(v.split("+")) if "+" in v else v)
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   units=args.units, remat=args.remat,
                   microbatch=args.microbatch, rule_overrides=overrides or None,
                   flash_kv_chunk=args.flash_kv_chunk,
                   metering=args.metering,
                   scan_param_fsdp=args.scan_param_fsdp,
                   grad_accum_dtype=args.grad_accum_dtype)
    js = json.dumps(res, indent=1, default=str)
    print(js)
    if args.out:
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.out).write_text(js)


if __name__ == "__main__":
    main()
