"""HLO text analysis: collective bytes with while-loop trip-count awareness.

``compiled.cost_analysis()`` counts while bodies ONCE and reports per-device
numbers (verified empirically — see EXPERIMENTS.md §Dry-run notes), so the
collective-bytes term must be derived by walking the HLO text ourselves:

  1. split the module into computations,
  2. per computation, sum output bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute ops (+ nested calls),
  3. for while ops, extract the trip count from the condition computation's
     compare-against-constant and multiply the body's bytes.

Shape parsing covers the dtypes our programs emit.
"""

from __future__ import annotations

import re

__all__ = ["collective_bytes", "parse_hlo_computations", "collective_breakdown"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[64,128]' or tuple '(bf16[2], f32[3,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_computations(hlo: str):
    """Split module text into {name: [line, ...]} computations.

    Computation headers look like ``%name (args) -> shape {`` (optionally
    prefixed by ENTRY); instruction lines always contain `` = `` before any
    ``->``, headers never do."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        ls = line.strip()
        is_header = (ls.endswith("{") and "->" in ls and
                     "=" not in ls.split("->", 1)[0])
        if is_header:
            m2 = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", ls)
            if m2:
                cur = m2.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if ls == "}" or ls.startswith("}"):
                cur = None
            else:
                comps[cur].append(ls)
    return comps


def _line_called_computations(line: str):
    """Names referenced via to_apply/condition/body/branch_computations/calls."""
    out = []
    for key in ("to_apply=", "condition=", "body=", "calls="):
        m = re.search(re.escape(key) + r"%?([\w\.\-]+)", line)
        if m:
            out.append((key.rstrip("="), m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


def _trip_count(cond_lines: list[str]) -> int:
    """Extract trip count from a while condition.

    Canonical counted loops compare the induction variable against a scalar
    constant (XLA often wraps the compare in a fused computation, so the
    constant may be the only usable signal in the condition itself).
    Primary: compare(iv, constant(N)) with direction LT/NE -> N.
    Fallback: the max scalar integer constant in the condition.  Falls back
    to 1 when no constant exists."""
    consts = {}
    for ls in cond_lines:
        m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s+"
                     r"constant\((\-?\d+)\)", ls)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ls in cond_lines:
        if "compare(" not in ls:
            continue
        m = re.search(r"compare\(([^)]*)\)", ls)
        dirn = re.search(r"direction=(\w+)", ls)
        if not m:
            continue
        args = [a.strip().split(" ")[-1].lstrip("%") for a in
                m.group(1).split(",")]
        nums = [consts[a] for a in args if a in consts]
        if nums:
            n = max(nums)
            if dirn and dirn.group(1) in ("LT", "NE"):
                return max(n, 1)
            return max(n + 1, 1)
    if consts:   # fused compare: the bound constant still lives here
        return max(max(consts.values()), 1)
    return 1


def collective_bytes(hlo: str) -> int:
    """Total collective payload bytes per device, trip-count weighted."""
    return sum(collective_breakdown(hlo).values())


def collective_breakdown(hlo: str) -> dict[str, int]:
    comps = parse_hlo_computations(hlo)

    memo: dict[str, dict[str, int]] = {}

    def comp_bytes(name: str, depth=0) -> dict[str, int]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 50:
            return {}
        total: dict[str, int] = {}
        memo[name] = total  # provisional (cycles)
        for ls in comps[name]:
            opm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*([^=]*?)\s*"
                           r"(all-gather|all-reduce|reduce-scatter|"
                           r"all-to-all|collective-permute)", ls)
            if opm and "start" not in ls.split("(")[0].split()[-1]:
                kind = opm.group(2)
                shape = opm.group(1)
                b = _shape_bytes(shape)
                total[kind] = total.get(kind, 0) + b
            # async start forms: 'all-gather-start', counted via shape too
            opm2 = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*?)\s*"
                            r"(all-gather-start|all-reduce-start|"
                            r"collective-permute-start)", ls)
            if opm2:
                kind = opm2.group(2).replace("-start", "")
                total[kind] = total.get(kind, 0) + _shape_bytes(opm2.group(1))
            calls = _line_called_computations(ls)
            if "while(" in ls:
                body = next((n for k, n in calls if k == "body"), None)
                cond = next((n for k, n in calls if k == "condition"), None)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    for k2, v in comp_bytes(body, depth + 1).items():
                        total[k2] = total.get(k2, 0) + v * trips
            else:
                for _, callee in calls:
                    for k2, v in comp_bytes(callee, depth + 1).items():
                        total[k2] = total.get(k2, 0) + v
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: sum every computation once
        agg: dict[str, int] = {}
        for name in comps:
            for k, v in comp_bytes(name).items():
                agg[k] = agg.get(k, 0) + v
        return agg
    return comp_bytes(entry)
