"""Production mesh construction.

A function, not a module-level constant — importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod
slice); multi-pod: (pod=2, data=16, model=16) = 512 chips, with the "pod"
axis crossing DCI.
"""

from __future__ import annotations

from repro.core.jaxcompat import make_mesh

__all__ = ["make_production_mesh", "batch_axes", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_type="Auto")


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class HW:
    """TPU v5e hardware constants for the roofline (per chip)."""
    PEAK_BF16_FLOPS = 197e12     # FLOP/s
    HBM_BW = 819e9               # B/s
    ICI_BW = 50e9                # B/s per link (within pod)
    DCI_BW = 25e9                # B/s effective (cross-pod, conservative)
    HBM_BYTES = 16 * 2**30       # 16 GiB per chip
