"""Serving launcher: batched requests through the engine + Bourbon session
store.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 12
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=4, max_seq=64))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 10)
                              ).astype(np.int32)
        eng.submit(Request(rid=1000 + i, prompt=prompt,
                           max_new=args.max_new))
    eng.run_until_drained()
    st = eng.sessions.stats()
    print(f"served {args.requests} requests in {eng.steps} engine steps; "
          f"session-store model-path fraction: {st['model_path_frac']:.2f}")


if __name__ == "__main__":
    main()
