"""ShardedStore — the durable, range-partitioned cluster plane.

One lifecycle ties the three layers together (the multi-layer refactor of
the old demo plane, which rebuilt transient in-memory arrays on every
process start):

* **storage** — every range partition is a full :class:`BourbonStore`
  backed by its own ``shard-<i>/`` directory (WAL, MANIFEST, sstables
  with persisted PLR models, value log).  Killing the process loses
  nothing: each shard recovers independently through the engine's normal
  protocol, and the topology itself (shard count + split keys) lives in
  an atomically-written ``SHARDS.json`` next to the shard directories.
* **snapshot** — the distributed GET runs against stacked per-shard
  snapshots derived from the shards' *durable* sstables (newest-seq-wins
  merge, tombstones dropped), not from a side copy of the data.
  :func:`load_shard_snapshot` builds the same snapshot straight from a
  shard directory with nothing but ``storage.sstable_io`` — no store
  open, no WAL replay — which is what the ``dist_recovery`` benchmark
  times against a full rebuild.
* **epoch** — the device state is versioned by each shard's structural
  epoch (its tree's flush/compaction event count).  Writes land in
  per-shard memtables (host overlay on reads); when a memtable rolls
  into a new snapshot the owning shard's row is rebuilt and the global
  ``state_epoch`` bumps, so the ``shard_map`` GET always sees a
  consistent immutable "level" per shard, exactly the paper's read-path
  contract (§4.3 applied cluster-wide).

GETs check the owning shard's memtable first (newest data wins,
tombstones shadow), then answer the rest through
``core.distributed.build_dist_get`` when a mesh with one device per
shard is available, or through the same ``dist_get_local`` shard kernel
looped on the host otherwise — both paths share the masked-ownership
semantics, so results are identical.
"""

from __future__ import annotations

import dataclasses
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cba import CBAConfig, MaintenanceConfig
from repro.core.clock import CostModel
from repro.core.distributed import (DistStoreConfig, build_dist_get,
                                    build_dist_state_from_shards,
                                    dist_get_local, next_pow2)
from repro.core.engine import EngineConfig
from repro.core.filters import FilterConfig, build_level_filter
from repro.core.jaxcompat import make_mesh, set_mesh
from repro.core.lsm import LSMConfig
from repro.core.plr import greedy_plr_np
from repro.core.store import BourbonStore, StoreConfig
from repro.io import ValueFetch, wait_all
from repro.kernels.ref import bloom_probe_stack_ref
from repro.obs import NULL_CTRACE, NULL_HANDLE, publish_stats
from repro.storage.format import fsync_dir, sst_path
from repro.storage.manifest import read_manifest
from repro.storage.sstable_io import load_sstable

__all__ = ["ShardedConfig", "ShardedStore", "ShardPendingBatch",
           "load_shard_snapshot", "merge_live"]

TOPOLOGY = "SHARDS.json"
_PAD_PROBE = -(1 << 62)


@partial(jax.jit, static_argnums=(2, 3))
def _local_get_all_shards(state: dict, probes: jnp.ndarray,
                          n_shards: int, delta: int, maybe=None):
    """Host-fallback GET as ONE compiled program: every shard's
    `dist_get_local` kernel plus the owner-exclusive where-merge, fused.
    Running this eagerly (the old path) paid per-op dispatch overhead for
    hundreds of tiny ops and blocked the host for the whole walk; jitted,
    the call is a single async enqueue — which is what lets the sharded
    store's dispatch half return before the device finishes.  ``maybe``
    (an (S, B) bool mask the caller's filter probe produced) prunes each
    shard's descent to the probes its bloom filter admits."""
    n = probes.shape[0]
    found = jnp.zeros(n, bool)
    vptr = jnp.full(n, -1, jnp.int64)
    for s in range(n_shards):
        shard = {k: v[s: s + 1] for k, v in state.items()}
        h, vv = dist_get_local(shard, probes, delta,
                               maybe=None if maybe is None else maybe[s])
        vptr = jnp.where(h, vv, vptr)
        found = found | h
    return found, vptr


@partial(jax.jit, static_argnums=(3,))
def _shard_filter_probe(fbits: jnp.ndarray, fnw: jnp.ndarray,
                        probes: jnp.ndarray, k_hashes: int) -> jnp.ndarray:
    """(S, B) maybe-mask over every shard's bloom row — one async call."""
    return bloom_probe_stack_ref(fbits, fnw, probes, k_hashes)


@dataclasses.dataclass
class ShardedConfig:
    """Topology of a sharded store — fixed at creation and persisted, so
    a reopen routes every key exactly as the writer did."""
    n_shards: int = 2
    # n_shards-1 ascending split keys; shard i owns [splits[i-1], splits[i])
    boundaries: tuple | None = None
    key_lo: int = 0               # uniform-split fallback domain
    key_hi: int = 1 << 62
    delta: int = 8                # dist-plane PLR error bound

    def splits(self) -> tuple:
        if self.boundaries is not None:
            b = tuple(int(x) for x in self.boundaries)
            if (len(b) != self.n_shards - 1
                    or any(x >= y for x, y in zip(b, b[1:]))):
                raise ValueError(
                    f"boundaries must be {self.n_shards - 1} strictly "
                    f"ascending split keys, got {b}")
            return b
        span = self.key_hi - self.key_lo
        return tuple(self.key_lo + span * (i + 1) // self.n_shards
                     for i in range(self.n_shards - 1))


def _store_cfg_to_dict(cfg: StoreConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d.pop("storage_dir", None)   # assigned per shard directory
    return d


def _store_cfg_from_dict(d: dict) -> StoreConfig:
    d = dict(d)
    nested = {"lsm": LSMConfig, "engine": EngineConfig, "cba": CBAConfig,
              "costs": CostModel, "maintenance": MaintenanceConfig,
              "filters": FilterConfig}
    for key, cls in nested.items():
        if key in d:   # topologies persisted before a field existed
            d[key] = cls(**d[key])
    return StoreConfig(**d)


def merge_live(tables) -> tuple[np.ndarray, np.ndarray]:
    """Newest-seq-wins merge of a shard's live sstables into one sorted
    (keys, vptrs) snapshot, shadowed versions and tombstones dropped —
    the immutable "level" the distributed read path serves."""
    if not tables:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    keys = np.concatenate([t.keys for t in tables])
    seqs = np.concatenate([t.seqs for t in tables])
    vptrs = np.concatenate([t.vptrs for t in tables])
    order = np.lexsort((seqs, keys))
    k, v = keys[order], vptrs[order]
    last = np.r_[k[1:] != k[:-1], True]   # newest version of each key
    k, v = k[last], v[last]
    live = v >= 0
    return np.ascontiguousarray(k[live]), np.ascontiguousarray(v[live])


def load_shard_snapshot(shard_dir: str,
                        verify: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """Shard snapshot straight from disk: MANIFEST replay names the live
    sstables, ``sstable_io`` mmaps them, and the merge yields the same
    (keys, vptrs) arrays a live store's tree would.  Read-only — no lock,
    no WAL replay (unflushed records are the memtable's business), no
    garbage sweep — so it is safe to point at a directory mid-crash."""
    got = read_manifest(shard_dir)
    if got is None:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    state, _ = got
    tables = [load_sstable(sst_path(shard_dir, fid), verify=verify)
              for fid in sorted(state.live)]
    return merge_live(tables)


@dataclasses.dataclass
class ShardPendingBatch:
    """Dispatch half of a distributed GET, pinned to ONE epoch-versioned
    device state.  The memtable overlay is already answered host-side;
    ``f_dev``/``v_dev`` are device futures for the snapshot path (JAX
    async dispatch — nothing blocked yet).  ``epochs`` records the exact
    per-shard epoch vector the batch is answered under: every key in the
    batch resolves against that one snapshot, which is the
    snapshot-consistency invariant the pipelined server asserts."""
    probes: np.ndarray             # (B,) int64
    owner: np.ndarray              # (B,) int32 owning shard per key
    found: np.ndarray              # (B,) bool, memtable hits prefilled
    vptr: np.ndarray               # (B,) int64, memtable hits prefilled
    miss: np.ndarray               # (B,) bool — answered by the snapshot
    n_miss: int
    f_dev: object                  # device (pad,) bool future, or None
    v_dev: object                  # device (pad,) int64 future, or None
    epochs: tuple                  # pinned per-shard epoch vector
    state_epoch: int               # device-state generation at dispatch
    with_values: bool
    resolved: bool = False
    # causal-tracing span the batch was dispatched under (the server's
    # "dispatch" span); None for the unsampled many
    trace: object = None


class ShardedStore:
    """Range-partitioned Bourbon store: durable shards + shard_map GETs."""

    def __init__(self, path: str, splits: tuple, shards: list,
                 delta: int, mesh) -> None:
        self.path = path
        self.shards = shards
        self.delta = delta
        self._splits = np.asarray(splits, np.int64)
        self._mesh = mesh
        self._get_fn = None
        self._snaps = [None] * len(shards)
        self._snap_models = [None] * len(shards)
        self._snap_filters = [None] * len(shards)
        self._snap_epochs = [-1] * len(shards)
        self._state = None
        self._state_epochs = None
        self.state_epoch = 0          # bumps whenever the device state refreshes
        self.n_gets = 0
        # observability (repro.obs) — attach_obs wires these; null objects
        # keep the resolve hot path branch-free when obs is off
        self._obs = None
        self._vf = NULL_HANDLE
        self._fp = NULL_HANDLE
        self._ct = NULL_CTRACE
        # host I/O plane (repro.io) — attach_io wires it; None keeps every
        # path on the original inline code
        self._io = None
        self._vf_hidden_us = 0.0     # fetch time overlapped away
        self._vf_exposed_us = 0.0    # fetch time the caller waited out

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def open(cls, path, scfg: ShardedConfig | None = None,
             store_cfg: StoreConfig | None = None,
             mesh="auto") -> "ShardedStore":
        """Open (or create) a sharded store rooted at ``path``.

        A fresh directory records the topology AND the per-shard store
        config in ``SHARDS.json`` (atomic write) and creates
        ``shard-<i>/`` per partition; an existing one reopens from its
        directories alone — the persisted config restores the store
        geometry, every shard recovers through the engine's normal
        protocol (WAL into memtable, sstables with their persisted file
        models, level models via the MANIFEST) — rejecting a mismatched
        shard count.  ``mesh="auto"`` builds an n_shards-device mesh for
        the shard_map GET when the host has enough devices, else the GET
        runs the same shard kernel host-side."""
        path = str(path)
        os.makedirs(path, exist_ok=True)
        topo_path = os.path.join(path, TOPOLOGY)
        if os.path.exists(topo_path):
            with open(topo_path) as f:
                topo = json.load(f)
            n_shards = topo["n_shards"]
            splits = tuple(topo["splits"])
            delta = topo["delta"]
            if scfg is not None:
                # the topology is fixed at creation: reject any mismatch
                # instead of silently routing by the persisted values
                if scfg.n_shards != n_shards:
                    raise ValueError(
                        f"store at {path!r} has {n_shards} shards; "
                        f"refusing to open with n_shards={scfg.n_shards}")
                if (scfg.boundaries is not None
                        and tuple(int(b) for b in scfg.boundaries) != splits):
                    raise ValueError(
                        f"store at {path!r} was partitioned at {splits}; "
                        f"refusing to open with different boundaries")
                if scfg.delta != delta:
                    raise ValueError(
                        f"store at {path!r} uses dist-plane delta={delta}; "
                        f"refusing to open with delta={scfg.delta}")
            if store_cfg is None:
                store_cfg = _store_cfg_from_dict(topo["store_cfg"])
        else:
            if os.path.exists(os.path.join(path, "shard-0")):
                # shard directories without their topology (lost or
                # never-durable SHARDS.json): re-creating with defaults
                # would silently orphan shards and re-route live keys
                raise RuntimeError(
                    f"{path!r} holds shard directories but no {TOPOLOGY}; "
                    f"refusing to re-create the topology over live data")
            scfg = scfg if scfg is not None else ShardedConfig()
            n_shards, delta = scfg.n_shards, scfg.delta
            splits = scfg.splits()
            store_cfg = store_cfg if store_cfg is not None else StoreConfig()
            tmp = topo_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"n_shards": n_shards, "splits": list(splits),
                           "delta": delta,
                           "store_cfg": _store_cfg_to_dict(store_cfg)}, f)
                if store_cfg.fsync:   # routing must survive power loss too
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, topo_path)
            if store_cfg.fsync:
                fsync_dir(path)
        shards: list[BourbonStore] = []
        try:
            for i in range(n_shards):
                shards.append(BourbonStore.open(
                    os.path.join(path, f"shard-{i}"), store_cfg))
        except BaseException:
            for st in shards:   # release the directory locks already taken
                st.close()
            raise
        if mesh == "auto":
            mesh = None
            if len(jax.devices()) >= n_shards:
                try:
                    mesh = make_mesh((n_shards,), ("shard",),
                                     axis_type="Explicit")
                except Exception:
                    mesh = None
        return cls(path, splits, shards, delta, mesh)

    def close(self) -> None:
        for st in self.shards:
            st.close()

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def uses_shard_map(self) -> bool:
        return self._mesh is not None

    # ----------------------------------------------------------------- write
    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Owning shard per key — total (out-of-range keys clamp to the
        first/last partition), so every key is always routable."""
        return np.searchsorted(self._splits, np.asarray(keys, np.int64),
                               side="right").astype(np.int32)

    def _fan_out_write(self, keys: np.ndarray, apply) -> None:
        """Route a write batch to its owning shards and run the per-shard
        slices — concurrently when an I/O pool is attached.  Shards are
        fully independent stores (own memtable, WAL, value log), and each
        key has exactly one owner, so concurrent per-shard application is
        order-free: results are identical to the sequential loop."""
        owner = self.shard_of(keys)
        work = []
        for i, st in enumerate(self.shards):
            mask = owner == i
            if mask.any():
                work.append((st, mask))
        if self._io is not None and len(work) > 1:
            wait_all([self._io.submit(apply, st, mask) for st, mask in work])
        else:
            for st, mask in work:
                apply(st, mask)

    def put_batch(self, keys: np.ndarray,
                  values: np.ndarray | None = None) -> None:
        keys = np.asarray(keys, np.int64)

        def apply(st, mask):
            st.put_batch(keys[mask], None if values is None else values[mask])

        self._fan_out_write(keys, apply)

    def delete_batch(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.int64)
        self._fan_out_write(keys,
                            lambda st, mask: st.delete_batch(keys[mask]))

    def wal_sync(self) -> None:
        """Fleet durability barrier: every shard's acknowledged WAL
        appends are on disk when this returns.  Under group commit each
        shard waits one coalesced fsync; with a pool the per-shard waits
        run concurrently, so the barrier costs ~one sync, not n_shards."""
        if self._io is not None and self.n_shards > 1:
            wait_all([self._io.submit(st.wal_sync) for st in self.shards])
        else:
            for st in self.shards:
                st.wal_sync()

    def flush_all(self) -> None:
        for st in self.shards:
            st.flush_all()

    def learn_all(self) -> int:
        return sum(st.learn_all() for st in self.shards)

    def drain_learning(self, max_us: float = 1e12) -> int:
        return sum(st.drain_learning(max_us) for st in self.shards)

    def gc_value_log(self, **kw) -> dict:
        out = {"segments_removed": 0, "bytes_reclaimed": 0,
               "entries_moved": 0}
        for st in self.shards:
            res = st.gc_value_log(**kw)
            for k in out:
                out[k] += res[k]
        return out

    # ----------------------------------------------------------- maintenance
    def set_maintenance_deferred(self, deferred: bool) -> None:
        """Hand the per-shard maintenance ticks to an external owner (the
        server's FleetMaintenanceCoordinator): deferred shards stop
        self-driving GC/checkpointing from their own write ticks and only
        do maintenance when :meth:`run_shard_maintenance` is called."""
        for st in self.shards:
            st.maintenance_deferred = deferred

    def run_shard_maintenance(self, shard_id: int,
                              budget_us: float | None = None) -> float:
        """One budget-bounded maintenance round on one shard; returns the
        virtual microseconds actually charged."""
        return self.shards[shard_id].run_maintenance(budget_us)

    def maintenance_us(self) -> float:
        """Total virtual time the fleet has spent on maintenance (value-log
        GC + MANIFEST checkpointing).  The server deltas this per tick to
        measure fleet stalls."""
        return sum(st.cba.gc_us + st.cba.checkpoint_us
                   for st in self.shards)

    # -------------------------------------------------------------- snapshot
    def shard_epochs(self) -> tuple:
        """Per-shard structural epoch (flush/compaction event count) — the
        same counter that versions the device state, exposed so the
        server's HotKeyCache can stamp entries with the epoch they were
        read under and lazily drop them when it moves."""
        return self._shard_epochs()

    def _shard_epochs(self) -> tuple:
        # one flush/compaction event = one structural change: the exact
        # moments a shard's memtable rolls into a new immutable snapshot
        return tuple(len(st.tree.events) for st in self.shards)

    def device_state(self) -> dict:
        """The stacked (n_shards, ...) device state.  Snapshots AND their
        fitted PLR models are cached per shard epoch, so a refresh merges
        and refits only the shards whose memtable actually rolled.  The
        restack/upload still copies every row (O(total records) bytes per
        refresh); updating only the changed device row is the next
        optimization if flush-heavy workloads make it show up."""
        epochs = self._shard_epochs()
        if self._state is None or epochs != self._state_epochs:
            fc = self.shards[0].cfg.filters
            bloom_k = self.shards[0].cfg.lsm.bloom_k
            for i, st in enumerate(self.shards):
                if self._snap_epochs[i] != epochs[i]:
                    self._snaps[i] = merge_live(list(st.tree.all_files()))
                    self._snap_models[i] = (
                        greedy_plr_np(self._snaps[i][0], delta=self.delta)
                        if self._snaps[i][0].shape[0] else None)
                    # per-shard bloom row, cached under the same epoch:
                    # the fused GET prunes shards that definitely lack
                    # the probe before any PLR work
                    self._snap_filters[i] = (
                        build_level_filter(self._snaps[i][0],
                                           fc.bits_per_key, bloom_k)
                        if fc.enabled and self._snaps[i][0].shape[0]
                        else None)
                    self._snap_epochs[i] = epochs[i]
            state_np = build_dist_state_from_shards(
                self._snaps, self.delta, models=self._snap_models,
                filters=self._snap_filters if fc.enabled else None)
            self._state = {k: jnp.asarray(v) for k, v in state_np.items()}
            self._state_epochs = epochs
            self.state_epoch += 1
        return self._state

    # ------------------------------------------------------------------ read
    def _dist_dispatch(self, probes: np.ndarray):
        """Launch the snapshot-path lookup on device and return the raw
        (found, vptr) futures WITHOUT materializing them — both the mesh
        shard_map call and the host-fallback per-shard kernel loop only
        enqueue work (the fallback's combine is jnp.where on device), so
        the caller overlaps admission of the next batch with this one's
        compute.  Mesh outputs are padded; slice ``[:n]`` at resolve."""
        state = self.device_state()
        n = probes.shape[0]
        if self._mesh is not None:
            if self._get_fn is None:
                cfg = DistStoreConfig(n_keys=0, probe_batch=0,
                                      delta=self.delta)
                # state layout pinned to what device_state() built: with
                # filters enabled it carries fbits/fnw rows the shard
                # kernel probes in-kernel before its descent
                self._get_fn = build_dist_get(
                    self._mesh, cfg, state_keys=tuple(sorted(state)),
                    k_hashes=self.shards[0].cfg.lsm.bloom_k)
            pad = next_pow2(max(n, 64))
            pad = -(-pad // self.n_shards) * self.n_shards
            buf = np.full(pad, _PAD_PROBE, np.int64)
            buf[:n] = probes
            with set_mesh(self._mesh):
                f, v = self._get_fn(state, jnp.asarray(buf))
            return f, v
        # host fallback: the same shard kernel, all shard rows fused into
        # one compiled program (each probe has exactly one owner, so the
        # where-merge is exact); padding the probe count to a power of two
        # keeps the trace cache small across varied batch sizes
        pad = next_pow2(max(n, 64))
        buf = np.full(pad, _PAD_PROBE, np.int64)
        buf[:n] = probes
        buf_dev = jnp.asarray(buf)
        maybe = None
        if "fbits" in state:
            # one batched stack-probe for every shard row, async like the
            # lookup itself; the handle is timed as its own read stage
            t0 = self._fp.begin()
            maybe = _shard_filter_probe(state["fbits"], state["fnw"],
                                        buf_dev,
                                        self.shards[0].cfg.lsm.bloom_k)
            self._fp.end(t0)
        return _local_get_all_shards(state, buf_dev,
                                     self.n_shards, self.delta, maybe)

    def dispatch_get(self, probes: np.ndarray, with_values: bool = False,
                     trace=None) -> ShardPendingBatch:
        """Non-blocking half of :meth:`get_batch`: memtable overlays are
        answered host-side, the snapshot path is launched on device, and
        the returned handle is pinned to the single epoch-versioned
        device state current at dispatch.  Resolve with
        :meth:`resolve_get`; multiple dispatched batches may be in flight
        at once and (absent interleaved writes) share one state epoch.
        ``trace`` is the caller's causal dispatch span (or None): each
        shard's overlay probe becomes a fan-out ``shard_probe`` child."""
        probes = np.asarray(probes, np.int64)
        B = probes.shape[0]
        owner = self.shard_of(probes)
        vptr = np.full(B, -1, np.int64)
        mt_hit = np.zeros(B, bool)
        for i, st in enumerate(self.shards):
            idx = np.nonzero(owner == i)[0]
            if idx.shape[0] == 0:
                continue
            ssp = self._ct.begin_span("shard_probe", trace, link=trace,
                                      shard=i, keys=int(idx.shape[0]))
            f, v = st.memtable.get_batch(probes[idx])
            mt_hit[idx[f]] = True
            vptr[idx[f]] = v[f]
            self._ct.end_span(ssp)
        miss = ~mt_hit
        n_miss = int(miss.sum())
        f_dev = v_dev = None
        if n_miss:
            f_dev, v_dev = self._dist_dispatch(probes[miss])
            epochs = self._state_epochs     # vector the state was built on
        else:
            epochs = self._shard_epochs()
        return ShardPendingBatch(probes, owner, mt_hit.copy(), vptr, miss,
                                 n_miss, f_dev, v_dev, tuple(epochs),
                                 self.state_epoch, with_values,
                                 trace=trace)

    def resolve_get_async(self, pb: ShardPendingBatch) -> ValueFetch:
        """Hand the batch's entire blocking half — the device→host sync,
        the overlay merge, and the per-shard value-log reads — to the I/O
        pool as ONE :class:`ValueFetch` task.  The caller gets the handle
        back immediately and can admit/dispatch its next batch while this
        one materializes on a worker; ``.wait()`` is the join.  Without a
        pool the task runs inside ``wait()``, reproducing the old
        synchronous resolve exactly.

        Determinism: the task is self-contained — it reads only the
        batch's own pinned handle (``pb``) and the immutable snapshot/
        value-log state the pipeline's barriers guarantee is quiescent
        while reads are in flight, and scatters into arrays owned by this
        batch.  Worker count and completion order cannot change any
        result bit (the CI determinism gate holds us to it)."""
        if pb.resolved:
            raise RuntimeError("ShardPendingBatch already resolved")
        pb.resolved = True
        B = pb.probes.shape[0]
        self.n_gets += B               # caller thread: no racing counters
        found, vptr = pb.found, pb.vptr
        vals = (np.zeros((B, self.shards[0].cfg.value_size), np.uint8)
                if pb.with_values else None)
        # the blocking half's causal span: begun here on the caller, ended
        # inside the task — which may run on an IOPool worker thread
        # (retrack re-stamps the track) or inline at wait()
        iosp = self._ct.begin_span("io_task", pb.trace, link=pb.trace,
                                   keys=B)
        ct = self._ct

        def task():
            if pb.f_dev is not None:
                f2 = np.asarray(pb.f_dev)[:pb.n_miss]
                v2 = np.asarray(pb.v_dev)[:pb.n_miss]
                found[pb.miss] = f2
                vptr[pb.miss] = np.where(f2, v2, -1)
            # located tombstones report not-found (in place: `found` IS
            # pb.found, so the returned result sees the update)
            np.logical_and(found, vptr >= 0, out=found)
            if vals is not None:
                for i, st in enumerate(self.shards):
                    sel = found & (pb.owner == i)
                    if sel.any():
                        vals[sel] = st.vlog.get_batch_np(vptr[sel])
            ct.end_span(iosp, retrack=True)

        result = (found, vals) if pb.with_values else (found, vptr)
        return ValueFetch(result, (task,), pool=self._io,
                          stage=self._vf, on_done=self._vf_overlap,
                          span=iosp)

    def _vf_overlap(self, hidden_us: float, exposed_us: float) -> None:
        self._vf_hidden_us += hidden_us
        self._vf_exposed_us += exposed_us

    def resolve_get(self, pb: ShardPendingBatch):
        """Blocking half: resolve and join the value fetch in one call."""
        return self.resolve_get_async(pb).wait()

    def get_batch(self, probes: np.ndarray, with_values: bool = False):
        """Batched GET: per-shard memtable overlay (newest data wins,
        tombstones shadow), then the snapshot path for the rest.  Returns
        (found, shard-local vptrs) or (found, values)."""
        return self.resolve_get(self.dispatch_get(probes, with_values))

    def range_query(self, start_keys: np.ndarray, length: int) -> np.ndarray:
        """Batched short scans across the partition map: each start key is
        answered by its owning shard, and a scan that runs off the end of
        a shard's key range continues into the next shard from its split
        boundary — so results are identical to a single unpartitioned
        store's.  Returns (B, length) keys, -1 padded.  (Delegates to the
        per-shard :meth:`BourbonStore.range_query`, which scans the
        flushed tree — flush before ranging over fresh writes.)"""
        start_keys = np.asarray(start_keys, np.int64)
        out = np.full((start_keys.shape[0], length), -1, np.int64)
        owner = self.shard_of(start_keys)
        for bi in range(start_keys.shape[0]):
            s = int(owner[bi])
            cur = int(start_keys[bi])
            got = 0
            while got < length:
                res = self.shards[s].range_query(
                    np.array([cur], np.int64), length - got)[0]
                valid = res[res >= 0]
                out[bi, got: got + valid.shape[0]] = valid
                got += int(valid.shape[0])
                if s == self.n_shards - 1:
                    break
                cur = int(self._splits[s])   # next shard's first owned key
                s += 1
        return out

    # -------------------------------------------------------------- io plane
    def attach_io(self, pool) -> None:
        """Join the fleet to one host I/O pool: value fetches resolve as
        overlappable :class:`ValueFetch` handles, per-shard writes and
        ``wal_sync`` barriers fan out concurrently, and each shard's own
        large-batch fetches chunk across the same workers."""
        self._io = pool
        for st in self.shards:
            st.attach_io(pool)

    def detach_io(self) -> None:
        self._io = None
        for st in self.shards:
            st.detach_io()

    # ------------------------------------------------------------------- obs
    def attach_obs(self, obs) -> None:
        """Join the fleet to one observability plane: every shard reports
        into the shared registry under its own ``shard=<i>`` label (so
        the per-shard breakdown survives aggregation), the distributed
        value-fetch is timed under the same ``value_fetch`` stage the
        single-store path uses, and a fleet-level collector publishes the
        cross-shard aggregates."""
        self._obs = obs
        self._vf = obs.tracer.stage("value_fetch")
        self._fp = obs.tracer.stage("filter_probe")
        self._ct = obs.ctrace
        for i, st in enumerate(self.shards):
            st.attach_obs(obs, labels={"shard": str(i)})
        obs.registry.register_collector(("fleet", self.path),
                                        self._collect_obs)

    def detach_obs(self) -> None:
        """Undo :meth:`attach_obs` fleet-wide (a fresh server with its
        own obs plane — or none — can then take over cleanly)."""
        if self._obs is not None:
            self._obs.registry.unregister_collector(("fleet", self.path))
        self._obs = None
        self._vf = NULL_HANDLE
        self._fp = NULL_HANDLE
        self._ct = NULL_CTRACE
        for st in self.shards:
            st.detach_obs()

    def _collect_obs(self, reg) -> None:
        reg.counter("fleet_gets_total").observe_total(self.n_gets)
        reg.gauge("fleet_state_epoch").set(self.state_epoch)
        # value-fetch overlap: fraction of total fetch time that ran
        # concurrently with other work instead of stalling the caller
        # (0.0 when inline; → 1.0 as the pool fully hides the fetch)
        c = reg.counter
        c("fleet_value_fetch_hidden_us_total").observe_total(
            self._vf_hidden_us)
        c("fleet_value_fetch_exposed_us_total").observe_total(
            self._vf_exposed_us)
        total_vf = self._vf_hidden_us + self._vf_exposed_us
        reg.gauge("fleet_value_fetch_overlap_ratio").set(
            self._vf_hidden_us / total_vf if total_vf else 0.0)
        for i, ep in enumerate(self._shard_epochs()):
            reg.gauge("fleet_shard_epoch", shard=str(i)).set(ep)
        # fleet aggregates; the per-shard dicts are already published by
        # each shard's own labeled collector — don't double-report them
        publish_stats(reg, "fleet", self.stats(),
                      skip=("shards", "per_shard"))

    # ----------------------------------------------------------------- stats
    def stats(self) -> dict:
        per = [st.stats() for st in self.shards]
        auto_gc = {"runs": 0, "segments_removed": 0, "bytes_reclaimed": 0,
                   "entries_moved": 0}
        for p in per:
            for k in auto_gc:
                auto_gc[k] += p.get("auto_gc", {}).get(k, 0)
        agg = {
            "n_shards": self.n_shards,
            "state_epoch": self.state_epoch,
            "uses_shard_map": self.uses_shard_map,
            "n_gets": self.n_gets,
            "n_records": sum(p["n_records"] for p in per),
            "n_files": sum(p["n_files"] for p in per),
            "files_learned": sum(p["files_learned"] for p in per),
            "models_recovered": sum(p.get("models_recovered", 0)
                                    for p in per),
            "level_models_recovered": sum(
                p.get("level_models_recovered", 0) for p in per),
            # fleet maintenance totals (previously dropped on the floor):
            # value-log GC reclamation and MANIFEST checkpoint counts
            # summed across shards, plus the virtual time they charged
            "vlog_segments_removed": sum(
                p.get("vlog_segments_removed", 0) for p in per),
            "vlog_disk_bytes": sum(p.get("vlog_disk_bytes", 0) for p in per),
            "auto_gc": auto_gc,
            "gc_us": sum(p.get("gc_us", 0.0) for p in per),
            "manifest_checkpoints": sum(
                p.get("manifest_checkpoints", 0) for p in per),
            "checkpoint_us": sum(st.cba.checkpoint_us for st in self.shards),
            "maintenance_us": self.maintenance_us(),
            # fleet WAL accounting: appends/commits is the group-commit
            # coalesce factor the write-heavy benchmark reports
            "wal": {
                "appends": sum(p.get("wal", {}).get("appends", 0)
                               for p in per),
                "fsyncs": sum(p.get("wal", {}).get("fsyncs", 0)
                              for p in per),
                "commits": sum(p.get("wal", {}).get("commits", 0)
                               for p in per),
            },
            # resolve overlap: hidden = resolve time spent while the
            # caller was off doing other work, exposed = time it actually
            # blocked in wait().  hidden/(hidden+exposed) is the overlap
            # ratio the threaded serving arm reports
            "value_fetch": {
                "hidden_us": self._vf_hidden_us,
                "exposed_us": self._vf_exposed_us,
            },
            "shards": per,
            # labeled per-shard breakdown: the aggregate sums above erase
            # which shard did the work; this keyed view preserves it (and
            # flattens into `key="shard-<i>"`-labeled gauges through the
            # obs registry)
            "per_shard": {
                f"shard-{i}": {
                    "n_records": p["n_records"],
                    "n_files": p["n_files"],
                    "files_learned": p["files_learned"],
                    "gc_us": p.get("gc_us", 0.0),
                    "checkpoint_us": self.shards[i].cba.checkpoint_us,
                    "maintenance_us": (self.shards[i].cba.gc_us
                                       + self.shards[i].cba.checkpoint_us),
                    "auto_gc": dict(p.get("auto_gc", {})),
                    "vlog_disk_bytes": p.get("vlog_disk_bytes", 0),
                    "vlog_segments_removed": p.get(
                        "vlog_segments_removed", 0),
                    "manifest_checkpoints": p.get("manifest_checkpoints", 0),
                    "epoch": len(self.shards[i].tree.events),
                }
                for i, p in enumerate(per)
            },
        }
        return agg
