"""Distributed plane rebuilt on the durable storage engine: a
:class:`ShardedStore` of range-partitioned :class:`~repro.core.store.
BourbonStore` shards, each owning its own ``shard-<i>/`` directory (WAL,
MANIFEST, sstables, value log), serving batched GETs through the
``shard_map`` read path against an epoch-versioned device snapshot."""

from .sharded import (ShardedConfig, ShardedStore, ShardPendingBatch,
                      load_shard_snapshot, merge_live)

__all__ = ["ShardedConfig", "ShardedStore", "ShardPendingBatch",
           "load_shard_snapshot", "merge_live"]
