"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552; RoPE, GQA, QKV bias (per HF config).  [hf:THUDM/glm-4-9b; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552,
    pattern=(StageSpec("attn_mlp", 1),), n_units=40,
    qkv_bias=True, rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        n_units=2, dtype="float32")
