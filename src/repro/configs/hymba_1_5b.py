"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per block,
sliding-window attention (1024) so 500k decode is O(window + state).
Meta-tokens from the paper are omitted (DESIGN.md).  [arXiv:2411.13676; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
    pattern=(StageSpec("hybrid", 1),), n_units=32,
    ssm_state=16, ssm_expand=2, window=1024,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=100, n_heads=5, n_kv_heads=5, d_ff=256, vocab=512,
        n_units=2, ssm_state=8, window=32, dtype="float32")
