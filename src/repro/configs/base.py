"""Config registry + the assignment's input-shape table.

Every architecture module exports CONFIG (exact public config) and
smoke_config() (reduced same-family config for CPU tests).  ``get_config``
resolves --arch ids.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config", "ShapeSpec",
           "cells"]

ARCHS = [
    "command-r-plus-104b",
    "qwen2.5-14b",
    "glm4-9b",
    "qwen2-0.5b",
    "mixtral-8x22b",
    "deepseek-v2-lite-16b",
    "musicgen-large",
    "hymba-1.5b",
    "xlstm-1.3b",
    "llama-3.2-vision-11b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _mod(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k dense KV out of scope "
                       "(DESIGN.md §Arch-applicability)")
    return True, ""


def cells():
    """All 40 (arch, shape) cells with applicability flags."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
