"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    pattern=(StageSpec("attn_moe", 1),), n_units=56,
    n_experts=8, top_k=2, moe_d_ff=16384,
    window=4096, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        n_units=2, n_experts=4, top_k=2, moe_d_ff=256, window=64,
        dtype="float32")
