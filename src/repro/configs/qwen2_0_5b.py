"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; GQA, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151936,
    pattern=(StageSpec("attn_mlp", 1),), n_units=24,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=112, n_heads=14, n_kv_heads=2, d_ff=256, vocab=512,
        n_units=2, dtype="float32")
