"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4H vocab=50304; xLSTM[7:1]
(7 mLSTM : 1 sLSTM per unit, 6 units), mLSTM projection factor 2, d_ff=0
(the cells carry their own up/down projections).  Recurrent O(1) decode
state => long_500k runs.  [arXiv:2405.04517; unverified]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    pattern=(StageSpec("mlstm", 7), StageSpec("slstm", 1)), n_units=6,
    mlstm_pf=2, slstm_heads=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=64, n_heads=4, n_kv_heads=4, vocab=256,
        pattern=(StageSpec("mlstm", 2), StageSpec("slstm", 1)), n_units=2,
        dtype="float32")
