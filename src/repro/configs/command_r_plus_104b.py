"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000; GQA, no-bias, parallel attn+FFN block, LayerNorm,
tied embeddings.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792, vocab=256000,
    pattern=(StageSpec("attn_mlp", 1),), n_units=64,
    norm_type="ln", parallel_block=True, tie_embeddings=True,
    rope_theta=75_000_000.0, qkv_bias=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        n_units=2, dtype="float32")
