"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; gated cross-attention image layers every 5th layer (8 total).
The vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, 1600, d_model).  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    pattern=(StageSpec("attn_mlp", 4), StageSpec("cross_attn_mlp", 1)),
    n_units=8,
    rope_theta=500_000.0, n_image_tokens=1600,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        pattern=(StageSpec("attn_mlp", 2), StageSpec("cross_attn_mlp", 1)),
        n_units=2, n_image_tokens=16, dtype="float32")
