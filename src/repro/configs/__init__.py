"""Per-architecture configs (exact public dims) + registry."""

from .base import ARCHS, SHAPES, get_config, get_smoke_config, cells

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config", "cells"]
