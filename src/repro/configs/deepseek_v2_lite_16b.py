"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(moe)=1408
vocab=102400; MLA kv_lora=512 (rope 64, nope 128, v 128); first layer dense
(d_ff 10944), then 26 MoE layers: 64 routed experts top-6 + 2 shared.
[arXiv:2405.04434; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944, vocab=102400,
    prologue=(StageSpec("mla_dense", 1),),
    pattern=(StageSpec("mla_moe", 1),), n_units=26,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        n_units=2, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=64,
        dtype="float32")
