"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias, RMSNorm, SwiGLU.  [hf:Qwen/Qwen2.5; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064,
    pattern=(StageSpec("attn_mlp", 1),), n_units=48,
    qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        n_units=2, dtype="float32")
