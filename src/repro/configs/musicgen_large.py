"""musicgen-large [audio] — 48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048;
decoder-only over EnCodec tokens.  The EnCodec frontend is a STUB:
input_specs() provides precomputed frame embeddings (4 codebooks summed
upstream); the head predicts one 2048-way codebook distribution.
[arXiv:2306.05284; hf]"""

import dataclasses
from repro.models import ModelConfig, StageSpec

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048,
    pattern=(StageSpec("attn_mlp", 1),), n_units=48,
    norm_type="ln", act="gelu", glu=False,
    inputs_embeds=True, n_codebooks=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, d_model=128, n_heads=8, n_kv_heads=8, d_ff=256, vocab=128,
        n_units=2, dtype="float32")
