"""Filter plane: per-level bloom filters in front of the PLR descent.

A negative GET in Bourbon still pays the full model-probe descent across
every level; a level filter answers "definitely absent here" before any
PLR work (PAPERS.md: Learned LSM-trees via learned bloom filters).  The
plane has two tiers:

* a **host screen** (``filter_maybe_np``) run by the store over the
  memtable-miss keys before the device batch is built — keys absent at
  every level never dispatch at all and resolve as misses with zero
  probes;
* a **device mask**: the same filters stacked into a padded ``(L, W)``
  array (``FilterState``, built by the engine) and probed for the whole
  batch by one Pallas kernel call ahead of the descent, pruning which
  levels the bounded search visits for the keys that do dispatch.

Filters are built host-side at flush/compaction time from
``bloom_build_np`` over *all* level keys including tombstones (a
tombstone must pass its filter so the engine finds it and reports the
delete — zero false negatives by construction).  Sizing is CBA-driven:
``MaintenanceScheduler.filter_bits_per_key`` trades the false-positive
cost (wasted model probes) against build time and memory, charged to the
virtual clock like learning jobs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bloom import (DEFAULT_BITS_PER_KEY, _hash2_np, bloom_build_np,
                    bloom_probe_hashed_np, bloom_probe_np, bloom_words)

__all__ = ["FilterConfig", "LevelFilter", "build_level_filter",
           "filter_maybe_np"]


@dataclasses.dataclass
class FilterConfig:
    """Knobs for the filter plane (``StoreConfig.filters``)."""

    enabled: bool = True
    bits_per_key: int = DEFAULT_BITS_PER_KEY   # base sizing; CBA may resize
    min_bits_per_key: int = 6                  # CBA search bounds
    max_bits_per_key: int = 16
    rebuild_delta_bpk: int = 2   # re-filter when CBA's pick drifts this far
    # post-screen remainders at or below this size are answered host-side
    # (numpy binary search over the sstable key arrays) instead of paying
    # the fixed device-dispatch cost — an absent sweep collapses to a
    # handful of bloom false positives, not a device round trip
    host_answer_max: int = 128


@dataclasses.dataclass
class LevelFilter:
    """One level's built filter (host copy; the engine stacks device rows)."""

    bits: np.ndarray        # (n_words,) uint64 packed filter words
    n_words: int            # build-time word count == the hash modulus / 64
    k_hashes: int
    bits_per_key: int
    n_keys: int
    epoch: int = -1         # persistence epoch; -1 = built but not stamped

    def maybe(self, probes: np.ndarray) -> np.ndarray:
        return bloom_probe_np(self.bits, probes, self.k_hashes,
                              n_words=self.n_words)


def build_level_filter(keys: np.ndarray, bits_per_key: int,
                       k_hashes: int) -> LevelFilter:
    """Build a filter over a level's full key set (tombstones included)."""
    keys = np.asarray(keys, np.int64)
    n_words = bloom_words(keys.shape[0], bits_per_key)
    bits = bloom_build_np(keys, n_words, k_hashes)
    return LevelFilter(bits=bits, n_words=n_words, k_hashes=k_hashes,
                       bits_per_key=bits_per_key, n_keys=int(keys.shape[0]))


def filter_maybe_np(filters: list[LevelFilter | None],
                    probes: np.ndarray) -> np.ndarray:
    """Host screen: (L, B) maybe-mask; a level without a filter is all-True.

    ``mask.any(axis=0) == False`` keys are definitely absent everywhere and
    can skip device dispatch entirely.
    """
    out = np.ones((len(filters), probes.shape[0]), bool)
    live = [(i, f) for i, f in enumerate(filters) if f is not None]
    if not live or probes.shape[0] == 0:
        return out
    # the double-hash bases are filter-independent: mix the batch once,
    # probe every level with the same (h1, h2)
    h1, h2 = _hash2_np(np.asarray(probes, np.int64))
    for i, f in live:
        out[i] = bloom_probe_hashed_np(f.bits, h1, h2, f.k_hashes,
                                       n_words=f.n_words)
    return out
