"""Cost-benefit analyzer (paper §4.4) + the learning executor.

Decides, per sstable file, whether learning is worthwhile:

    learn F  iff  B_model > C_model
    C_model = T_build(F) = learn_per_key * n_keys            (conservative:
              learning threads are assumed to interfere, §4.4.2)
    B_model = (T_nb - T_nm) * N_n  +  (T_pb - T_pm) * N_p

with T_wait (= max file build time, 2-competitive ski-rental argument) before
a file becomes a learning candidate, per-level statistics of files that lived
their full life, bootstrap always-learn mode until stats exist, and a max
priority queue on (B_model - C_model).

The learning executor is a discrete-event simulation over the store's virtual
clock with a configurable number of learner "threads" (slots); model fitting
itself (Greedy-PLR) runs for real on the host.

:class:`MaintenanceScheduler` extends the same discipline from "when to
learn" to "when to GC the value log" and "when to checkpoint the MANIFEST":
background work runs only when an explicit cost-benefit model says it pays
off, with the same T_wait ski-rental framing per sealed segment.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math

from .clock import CostModel
from .lsm import LSMTree
from .sstable import SSTable

__all__ = ["CBAConfig", "CostBenefitAnalyzer", "LevelStats",
           "LearningExecutor", "MaintenanceConfig", "MaintenanceScheduler"]


@dataclasses.dataclass
class CBAConfig:
    policy: str = "cba"            # cba | always | offline | never
    t_wait_us: float | None = None  # None -> max-file build time (paper: 50ms)
    min_stat_files: int = 5        # bootstrap: always-learn until this many
    short_lived_filter_us: float = 1000.0  # exclude very short-lived files
    learner_slots: int = 4


@dataclasses.dataclass
class LevelStats:
    """Stats of files at one level that lived their full life (§4.4.2)."""
    n_files: int = 0
    sum_neg: float = 0.0
    sum_pos: float = 0.0
    sum_size: float = 0.0

    def observe(self, t: SSTable) -> None:
        self.n_files += 1
        self.sum_neg += t.stats.n_neg
        self.sum_pos += t.stats.n_pos
        self.sum_size += t.n

    @property
    def avg_neg(self) -> float:
        return self.sum_neg / self.n_files if self.n_files else 0.0

    @property
    def avg_pos(self) -> float:
        return self.sum_pos / self.n_files if self.n_files else 0.0

    @property
    def avg_size(self) -> float:
        return self.sum_size / self.n_files if self.n_files else 1.0


class CostBenefitAnalyzer:
    def __init__(self, cfg: CBAConfig, costs: CostModel) -> None:
        self.cfg = cfg
        self.costs = costs
        self.level_stats: dict[int, LevelStats] = {}
        self.decisions = {"learned": 0, "skipped": 0, "bootstrap": 0}

    def t_wait(self, file_cap: int) -> float:
        if self.cfg.t_wait_us is not None:
            return self.cfg.t_wait_us
        return self.costs.t_build(file_cap)

    def observe_dead_file(self, t: SSTable, now: float) -> None:
        if t.lifetime(now) < self.cfg.short_lived_filter_us:
            return  # filter very short-lived files (§4.4.2)
        self.level_stats.setdefault(t.level, LevelStats()).observe(t)

    def cost(self, t: SSTable) -> float:
        return self.costs.t_build(t.n)

    def benefit(self, t: SSTable) -> float:
        """B_model estimate. Uses same-level stats of completed files,
        scaled by file size (factor f = s / s_bar_l)."""
        st = self.level_stats.get(t.level)
        c = self.costs
        if st is None or st.n_files < self.cfg.min_stat_files:
            return float("inf")  # bootstrap: always learn (T_wait still applies)
        scale = t.n / max(st.avg_size, 1.0)
        n_n = st.avg_neg * scale
        n_p = st.avg_pos * scale
        return (c.t_nb - c.t_nm) * n_n + (c.t_pb - c.t_pm) * n_p

    def should_learn(self, t: SSTable) -> tuple[bool, float]:
        """Returns (decision, priority = B - C)."""
        if self.cfg.policy == "never" or self.cfg.policy == "offline":
            return False, 0.0
        if self.cfg.policy == "always":
            return True, float("inf")
        b, cst = self.benefit(t), self.cost(t)
        if b == float("inf"):
            self.decisions["bootstrap"] += 1
            return True, float("inf")
        if b > cst:
            self.decisions["learned"] += 1
            return True, b - cst
        self.decisions["skipped"] += 1
        return False, 0.0


@dataclasses.dataclass
class MaintenanceConfig:
    """Knobs for CBA-scheduled background maintenance (durable stores)."""
    auto_gc: bool = True             # schedule value-log GC from _tick
    # maintain per-segment dead-entry estimates in the write path.  On by
    # default even with auto_gc off — the estimates persist via MANIFEST
    # vdead, so a later auto_gc=True session inherits them — but the
    # full-LSM liveness lookup costs per write batch; disable for pure
    # ingest benchmarks
    track_dead: bool = True
    gc_dead_ratio: float = 0.3       # candidacy watermark (estimated)

    def __post_init__(self):
        if self.auto_gc and not self.track_dead:
            # the scheduler's candidacy reads the estimates track_dead
            # maintains; "GC on, tracking off" would silently never collect
            raise ValueError(
                "auto_gc=True requires track_dead=True (GC candidacy is "
                "driven by the write-path dead-entry estimates)")
    gc_t_wait_us: float | None = None  # None -> worst-case collect cost
    gc_max_segments_per_tick: int = 4
    gc_scan_interval_us: float = 256.0  # min virtual time between scans
    auto_checkpoint: bool = True     # fold the MANIFEST once it grows
    checkpoint_bytes: int = 1 << 16  # edit-log size triggering compaction


class MaintenanceScheduler(CostBenefitAnalyzer):
    """CBA for maintenance: GC a sealed value-log segment iff

        B_gc > C_gc
        C_gc = scan cost (all entries) + relocation cost (live entries)
        B_gc = reclaimed dead bytes * avoided-amplification rate

    using the incremental per-segment dead estimates (ValueLog.note_dead)
    instead of a full-log scan, gated by a dead-ratio watermark and a
    per-segment T_wait (2-competitive ski-rental, as for learning: never
    wait longer than the work itself would have cost).  Also decides when
    the MANIFEST edit log is worth folding into a checkpoint.
    """

    def __init__(self, cfg: CBAConfig, costs: CostModel,
                 mcfg: MaintenanceConfig | None = None) -> None:
        super().__init__(cfg, costs)
        self.mcfg = mcfg if mcfg is not None else MaintenanceConfig()
        self.sealed_at: dict[int, float] = {}   # seg -> first-seen-sealed
        # decision counters are per segment-state transition, not per tick
        # (gc_candidates runs every tick; recounting would just measure
        # tick frequency)
        self._last_decision: dict[int, str] = {}
        self.gc_decisions = {"collected": 0, "skipped": 0, "waiting": 0}
        # scan gating: candidacy only changes when dead counts move, a new
        # segment seals, or a T_wait expires — ticks between those events
        # (and within the min scan interval) skip the per-segment loop
        self._seen_dead_version = -1
        self._seen_sealed = -1
        self._next_expiry = 0.0
        self._next_scan_at = 0.0
        self.gc_runs = 0
        self.gc_us = 0.0            # virtual time spent collecting
        self.gc_deferred = 0        # profitable segs pushed to a later tick
        self.last_plan_cost_us = 0.0  # estimated cost of the last candidate set
        self.last_plan_benefit_us = 0.0  # estimated benefit of that set
        self.checkpoints = 0
        self.checkpoint_us = 0.0
        self.checkpoint_overruns = 0  # folds too big for any tick budget
        # filter plane (per-level bloom filters ahead of the descent):
        # sizing decisions + build time, charged like learning jobs
        self.filter_decisions = {"bootstrap": 0, "sized": 0, "rebuilt": 0}
        self.filter_builds = 0
        self.filter_us = 0.0

    def gc_t_wait(self, seg_slots: int) -> float:
        if self.mcfg.gc_t_wait_us is not None:
            return self.mcfg.gc_t_wait_us
        # worst case: scanning + relocating a fully-live segment
        return self.costs.t_gc(seg_slots, seg_slots)

    def gc_cost(self, n_entries: int, n_dead: int) -> float:
        return self.costs.t_gc(n_entries, max(0, n_entries - n_dead))

    def gc_benefit(self, n_dead: int, entry_size: int) -> float:
        return self.costs.b_gc(n_dead * entry_size)

    def gc_candidates(self, vlog, now: float,
                      budget_us: float | None = None) -> list[int]:
        """Profitable sealed segments, best (B - C) first, capped at
        ``gc_max_segments_per_tick``.  Pure estimate — no file I/O, and
        the per-segment loop runs only when something could have changed.

        ``budget_us`` caps the *estimated* collection cost of the whole
        candidate set (the fleet coordinator's per-tick budget).  The
        estimate is conservative — dead counts only ever undercount, so
        estimated relocation work bounds the real thing from above —
        which makes the budget a hard ceiling on the virtual time the
        collection can actually charge.  Profitable segments that don't
        fit re-arm the change gate so the next tick reconsiders them
        instead of waiting for their dead counts to move again."""
        n_sealed = len(vlog) // vlog.seg_slots
        changed = (vlog.dead_version != self._seen_dead_version
                   or n_sealed != self._seen_sealed
                   or now >= self._next_expiry)
        if not changed or now < self._next_scan_at:
            return []
        self._seen_dead_version = vlog.dead_version
        self._seen_sealed = n_sealed
        self._next_scan_at = now + self.mcfg.gc_scan_interval_us
        self._next_expiry = float("inf")
        t_wait = self.gc_t_wait(vlog.seg_slots)
        scored: list[tuple[float, int]] = []
        for seg in vlog.sealed_segments():
            sealed = self.sealed_at.setdefault(seg, now)
            if now < sealed + t_wait:
                self._next_expiry = min(self._next_expiry, sealed + t_wait)
                self._count(seg, "waiting")
                continue
            n_dead = vlog.dead_by_seg.get(seg, 0)
            if vlog.dead_ratio_est(seg) < self.mcfg.gc_dead_ratio:
                self._count(seg, "skipped")
                continue
            b = self.gc_benefit(n_dead, vlog.entry_size)
            c = self.gc_cost(vlog.seg_slots, n_dead)
            if b <= c:
                self._count(seg, "skipped")
                continue
            scored.append((b - c, c, seg))
        scored.sort(reverse=True)
        picked: list[int] = []
        plan_cost = 0.0
        plan_benefit = 0.0
        deferred = 0
        for bc, c, seg in scored:
            if len(picked) >= self.mcfg.gc_max_segments_per_tick:
                deferred += 1
                continue
            if budget_us is not None and plan_cost + c > budget_us:
                deferred += 1
                continue
            picked.append(seg)
            plan_cost += c
            plan_benefit += bc + c   # scored holds (B - C, C, seg)
        if deferred:
            # budget (or the per-tick cap) left profitable work behind:
            # drop the change gate so the next scan re-scores it (the
            # scan-interval gate still rate-limits the per-segment loop)
            self._seen_dead_version = -1
            self.gc_deferred += deferred
        self.last_plan_cost_us = plan_cost
        self.last_plan_benefit_us = plan_benefit
        for seg in picked:
            self._last_decision.pop(seg, None)
        self.gc_decisions["collected"] += len(picked)
        return picked

    def _count(self, seg: int, decision: str) -> None:
        if self._last_decision.get(seg) != decision:
            self._last_decision[seg] = decision
            self.gc_decisions[decision] += 1

    def forget_segment(self, seg: int) -> None:
        """A segment was reclaimed: drop its scheduling bookkeeping."""
        self.sealed_at.pop(seg, None)
        self._last_decision.pop(seg, None)

    def should_checkpoint(self, manifest_bytes: int) -> bool:
        return (self.mcfg.auto_checkpoint
                and manifest_bytes > self.mcfg.checkpoint_bytes)

    # ------------------------------------------------------------ filters
    @staticmethod
    def filter_fpr(bits_per_key: int, k_hashes: int) -> float:
        """Expected bloom false-positive rate at the configured hash count
        (not the optimal-k approximation — k is fixed by the engine)."""
        return (1.0 - math.exp(-k_hashes / bits_per_key)) ** k_hashes

    def filter_bits_per_key(self, level: int, n_keys: int, base: int,
                            lo: int, hi: int, k_hashes: int) -> int:
        """CBA sizing for one level filter (§4.4 framing): per candidate
        bits-per-key, cost = expected false-positive probes over the
        level's observed miss traffic (each one a wasted model probe,
        t_nm) + memory rent on the held bits; pick the cheapest.  Without
        enough completed-file stats the base size is used (bootstrap, like
        always-learn)."""
        st = self.level_stats.get(level)
        if st is None or st.n_files < self.cfg.min_stat_files:
            self.filter_decisions["bootstrap"] += 1
            return base
        # miss traffic seen by a level of this size, scaled the same way
        # benefit() scales per-file stats (factor f = s / s_bar_l)
        n_neg = st.avg_neg * (n_keys / max(st.avg_size, 1.0))
        c = self.costs
        best, best_cost = base, float("inf")
        for bpk in range(lo, hi + 1):
            cost = (n_neg * self.filter_fpr(bpk, k_hashes) * c.t_nm
                    + n_keys * bpk * c.filter_mem_per_bit)
            if cost < best_cost:
                best, best_cost = bpk, cost
        self.filter_decisions["sized"] += 1
        return best


@dataclasses.dataclass(order=True)
class _Job:
    neg_priority: float
    seq: int
    table: SSTable = dataclasses.field(compare=False)
    ready_at: float = dataclasses.field(compare=False, default=0.0)
    level_version: int | None = dataclasses.field(compare=False, default=None)
    is_level: bool = dataclasses.field(compare=False, default=False)
    level: int = dataclasses.field(compare=False, default=-1)


class LearningExecutor:
    """Discrete-event learner pool over the virtual clock.

    Files become candidates T_wait after creation; profitable jobs enter a max
    priority queue on (B - C); ``slots`` jobs can run concurrently, each
    occupying virtual time T_build.  Level jobs fail if the level version
    changes before completion (reproducing §4.3's failed level learnings).
    """

    def __init__(self, cba: CostBenefitAnalyzer, costs: CostModel,
                 slots: int, plr_delta: int, seg_cap: int) -> None:
        self.cba = cba
        self.costs = costs
        self.slots = slots
        self.plr_delta = plr_delta
        self.seg_cap = seg_cap
        self.queue: list[_Job] = []
        self.running: list[tuple[float, _Job]] = []  # (finish_at, job)
        self.learn_time_us = 0.0      # total virtual time spent learning
        self.jobs_done = 0            # jobs that left the pipeline
        self.files_learned = 0
        self.level_attempts = 0
        self.level_failures = 0
        # monotonic identity for level models: every fit gets a fresh
        # epoch, cache keys and the MANIFEST ``lmodel`` record both use it.
        # A recovered store seeds this past the largest persisted epoch so
        # epochs stay unique across reopens.
        self.next_model_epoch = 0
        self._seq = itertools.count()
        # optional obs EventLog (BourbonStore.attach_obs wires it): each
        # job start logs a "learn" event with the CBA's cost/benefit
        # estimates — the paper's §4.4 decision inputs, made observable
        self.events = None

    def alloc_model_epoch(self) -> int:
        epoch = self.next_model_epoch
        self.next_model_epoch += 1
        return epoch

    # ------------------------------------------------------------ submission
    def maybe_submit_file(self, t: SSTable, now: float) -> None:
        if t.model is not None or t.learn_submitted or t.deleted_at is not None:
            return
        decision, prio = self.cba.should_learn(t)
        t.learn_submitted = True
        if decision:
            heapq.heappush(self.queue, _Job(-prio, next(self._seq), t,
                                            ready_at=now))

    def submit_level(self, tree: LSMTree, level: int, now: float) -> None:
        """Level-granularity learning job (§4.3)."""
        if not tree.levels[level]:
            return
        self.level_attempts += 1
        # a pseudo-job carrying the level version for invalidation
        job = _Job(-float("inf"), next(self._seq), tree.levels[level][0],
                   ready_at=now, level_version=tree.level_version[level],
                   is_level=True, level=level)
        heapq.heappush(self.queue, job)

    # ------------------------------------------------------------ execution
    def tick(self, tree: LSMTree, now: float, level_models: list) -> None:
        """Complete finished jobs; start new ones into free slots."""
        still = []
        for finish_at, job in self.running:
            if finish_at > now:
                still.append((finish_at, job))
                continue
            self.jobs_done += 1
            if job.is_level:
                if tree.level_version[job.level] != job.level_version:
                    self.level_failures += 1   # level changed mid-learn
                else:
                    level_models[job.level] = self._fit_level(tree, job.level)
            else:
                t = job.table
                if t.deleted_at is None and t.model is None:
                    t.learn(self.plr_delta, pad_to=self.seg_cap)
                    t.model_built_at = finish_at
                    self.files_learned += 1
        self.running = still
        while self.queue and len(self.running) < self.slots:
            job = heapq.heappop(self.queue)
            if not job.is_level:
                t = job.table
                if t.deleted_at is not None or t.model is not None:
                    self.jobs_done += 1   # drained without running
                    continue
                dur = self.costs.t_build(t.n)
            else:
                if tree.level_version[job.level] != job.level_version:
                    self.level_failures += 1
                    self.jobs_done += 1
                    continue
                dur = self.costs.t_build(tree.level_records(job.level))
            self.learn_time_us += dur
            if self.events is not None:
                prio = -job.neg_priority   # B - C (inf = always/bootstrap)
                self.events.log(
                    "learn", at_us=now, cost_us=dur, is_level=job.is_level,
                    level=job.level if job.is_level else job.table.level,
                    benefit_minus_cost_us=(None if prio == float("inf")
                                           else prio))
            self.running.append((now + dur, job))

    def _fit_level(self, tree: LSMTree, level: int):
        import numpy as np
        from .plr import greedy_plr_np
        keys = np.concatenate([t.keys for t in tree.levels[level]])
        model = greedy_plr_np(keys, delta=self.plr_delta)
        model.epoch = self.alloc_model_epoch()
        return model
