"""Greedy piecewise linear regression (PLR) — the paper's learned-index model.

Implements the Greedy-PLR algorithm (Xie et al., "Maximum Error-bounded
Piecewise Linear Representation for Online Stream Approximation", VLDB J. 2014)
used by Bourbon §4.1: one pass over (key, position) pairs maintaining a slope
cone; when a point cannot be covered within the error bound delta, the current
segment is closed and a new one begins.  Guarantee: for every trained point,
|predict(key) - pos| <= delta.

Two implementations:
  * ``greedy_plr_np``  — numpy, used by the host-side learner (fast path).
  * ``greedy_plr_jax`` — jax.lax.scan, identical semantics, jittable (used by
    property tests and by on-device learning experiments).

The fitted model is a :class:`PLRModel` pytree of padded segment arrays so it
can be stacked per-sstable and shipped to the device.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PLRModel", "greedy_plr_np", "greedy_plr_jax", "plr_predict_np"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PLRModel:
    """Piecewise-linear model: segment s covers keys in [starts[s], starts[s+1]).

    Arrays are padded to a fixed capacity with ``n_segments`` giving the live
    count; padding starts are +inf so searchsorted routes probes correctly.
    """

    starts: jnp.ndarray      # (S,) float64 segment start keys (padded +inf)
    slopes: jnp.ndarray      # (S,) float64
    intercepts: jnp.ndarray  # (S,) float64  (pos = slope * key + intercept)
    n_segments: jnp.ndarray  # () int32
    delta: int = 8           # static error bound
    # host-side identity: a monotonic epoch stamped by whoever fit (or
    # loaded) the model.  Cache keys use it instead of id(), which the
    # allocator can reuse after GC.  Not a pytree leaf — traced copies
    # reset to the -1 "unstamped" sentinel.
    epoch: int = -1

    def tree_flatten(self):
        return (self.starts, self.slopes, self.intercepts, self.n_segments), (self.delta,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, delta=aux[0])

    @property
    def nbytes(self) -> int:
        n = int(self.n_segments)
        return n * 3 * 8 + 4  # three float64 arrays + count


def _finalize_segment(x0, y0, slo, shi):
    slope = (slo + shi) / 2.0
    if not np.isfinite(slope):  # single-point segment: flat line through it
        slope = 0.0
    intercept = y0 - slope * x0
    return slope, intercept


def greedy_plr_np(keys: np.ndarray, delta: int = 8, pad_to: int | None = None) -> PLRModel:
    """Fit Greedy-PLR over sorted ``keys`` mapping key -> index.

    Linear time, single pass.  ``pad_to`` pads segment arrays to a fixed size
    (required when models are stacked across sstables).
    """
    keys = np.asarray(keys, dtype=np.float64)
    n = keys.shape[0]
    starts, slopes, intercepts = [], [], []
    if n > 0:
        x0, y0 = keys[0], 0.0
        slo, shi = -np.inf, np.inf
        for i in range(1, n):
            x, y = keys[i], float(i)
            dx = x - x0
            if dx <= 0:  # duplicate key: keep cone unchanged (same x)
                continue
            lo_i = (y - delta - y0) / dx
            hi_i = (y + delta - y0) / dx
            nlo, nhi = max(slo, lo_i), min(shi, hi_i)
            if nlo > nhi:  # cone empty -> close segment, start new at (x, y)
                s, b = _finalize_segment(x0, y0, slo, shi)
                starts.append(x0); slopes.append(s); intercepts.append(b)
                x0, y0 = x, y
                slo, shi = -np.inf, np.inf
            else:
                slo, shi = nlo, nhi
        s, b = _finalize_segment(x0, y0, slo, shi)
        starts.append(x0); slopes.append(s); intercepts.append(b)
    ns = len(starts)
    cap = pad_to if pad_to is not None else max(ns, 1)
    if ns > cap:
        raise ValueError(f"PLR needs {ns} segments > pad_to={cap}")
    st = np.full(cap, np.inf, dtype=np.float64)
    sl = np.zeros(cap, dtype=np.float64)
    ic = np.zeros(cap, dtype=np.float64)
    st[:ns] = starts; sl[:ns] = slopes; ic[:ns] = intercepts
    return PLRModel(jnp.asarray(st), jnp.asarray(sl), jnp.asarray(ic),
                    jnp.asarray(ns, jnp.int32), delta=delta)


def plr_predict_np(model: PLRModel, probes: np.ndarray) -> np.ndarray:
    """Reference host-side prediction (for tests)."""
    st = np.asarray(model.starts)
    ns = int(model.n_segments)
    seg = np.clip(np.searchsorted(st[:ns], probes, side="right") - 1, 0, max(ns - 1, 0))
    sl = np.asarray(model.slopes)[seg]
    ic = np.asarray(model.intercepts)[seg]
    return sl * probes.astype(np.float64) + ic


# ----------------------------------------------------------------------------
# jax.lax.scan version — identical cone algorithm, one step per key.
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("delta", "cap"))
def greedy_plr_jax(keys: jnp.ndarray, delta: int = 8, cap: int = 1024) -> PLRModel:
    """Greedy-PLR via lax.scan.  ``cap`` bounds the number of segments.

    Semantics match ``greedy_plr_np``; segments beyond ``cap`` raise in the
    numpy version and silently clamp here (callers size cap generously).
    """
    keys = keys.astype(jnp.float64)
    n = keys.shape[0]

    starts0 = jnp.full((cap,), jnp.inf, jnp.float64)
    slopes0 = jnp.zeros((cap,), jnp.float64)
    icepts0 = jnp.zeros((cap,), jnp.float64)

    # carry: (x0, y0, slo, shi, seg_idx, starts, slopes, intercepts)
    init = (keys[0], 0.0, -jnp.inf, jnp.inf, jnp.asarray(0, jnp.int32),
            starts0, slopes0, icepts0)

    def step(carry, xy):
        x0, y0, slo, shi, si, st, sl, ic = carry
        x, y = xy
        dx = x - x0
        lo_i = jnp.where(dx > 0, (y - delta - y0) / jnp.where(dx > 0, dx, 1.0), -jnp.inf)
        hi_i = jnp.where(dx > 0, (y + delta - y0) / jnp.where(dx > 0, dx, 1.0), jnp.inf)
        nlo, nhi = jnp.maximum(slo, lo_i), jnp.minimum(shi, hi_i)
        close = nlo > nhi
        # finalize current segment when closing
        fslope = (slo + shi) / 2.0
        # guard infinities (single-point segment): slope 0 through the point
        fslope = jnp.where(jnp.isfinite(fslope), fslope, 0.0)
        ficept = y0 - fslope * x0
        st = jnp.where(close, st.at[jnp.minimum(si, cap - 1)].set(x0), st)
        sl = jnp.where(close, sl.at[jnp.minimum(si, cap - 1)].set(fslope), sl)
        ic = jnp.where(close, ic.at[jnp.minimum(si, cap - 1)].set(ficept), ic)
        si = jnp.where(close, si + 1, si)
        x0n = jnp.where(close, x, x0)
        y0n = jnp.where(close, y, y0)
        slon = jnp.where(close, -jnp.inf, nlo)
        shin = jnp.where(close, jnp.inf, nhi)
        # duplicate keys (dx <= 0): carry unchanged
        dup = dx <= 0
        return (jnp.where(dup, x0, x0n), jnp.where(dup, y0, y0n),
                jnp.where(dup, slo, slon), jnp.where(dup, shi, shin),
                si, st, sl, ic), None

    ys = jnp.arange(1, n, dtype=jnp.float64)
    (x0, y0, slo, shi, si, st, sl, ic), _ = jax.lax.scan(step, init, (keys[1:], ys))
    fslope = (slo + shi) / 2.0
    fslope = jnp.where(jnp.isfinite(fslope), fslope, 0.0)
    ficept = y0 - fslope * x0
    idx = jnp.minimum(si, cap - 1)
    st = st.at[idx].set(x0)
    sl = sl.at[idx].set(fslope)
    ic = ic.at[idx].set(ficept)
    return PLRModel(st, sl, ic, si + 1, delta=delta)
