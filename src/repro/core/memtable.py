"""In-memory write buffer (memtable).

LevelDB uses a skiplist; the tensorized analogue is a sorted-run buffer:
puts append to an unsorted tail, and the table is (re)sorted lazily in
batches — batched writes are the TPU-native ingestion pattern.  Point reads
check the memtable before the tree (newest data wins).
"""

from __future__ import annotations

import numpy as np

__all__ = ["MemTable"]


class MemTable:
    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self._keys = np.empty(capacity, np.int64)
        self._seqs = np.empty(capacity, np.int64)
        self._vptrs = np.empty(capacity, np.int64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def full(self) -> bool:
        return self._n >= self.capacity

    def put_batch(self, keys: np.ndarray, seqs: np.ndarray, vptrs: np.ndarray) -> int:
        """Insert up to capacity; returns number consumed."""
        take = min(self.capacity - self._n, keys.shape[0])
        sl = slice(self._n, self._n + take)
        self._keys[sl] = keys[:take]
        self._seqs[sl] = seqs[:take]
        self._vptrs[sl] = vptrs[:take]
        self._n += take
        return take

    def get_batch(self, probes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(found bool, vptr int64) for each probe — newest seq wins."""
        found = np.zeros(probes.shape[0], bool)
        vptr = np.full(probes.shape[0], -1, np.int64)
        if self._n == 0:
            return found, vptr
        k = self._keys[: self._n]
        s = self._seqs[: self._n]
        v = self._vptrs[: self._n]
        # sort by (key, seq) and keep the newest version of each key
        order = np.lexsort((s, k))
        ks, ss, vs = k[order], s[order], v[order]
        last = np.r_[ks[1:] != ks[:-1], True]  # last occurrence = max seq
        ku, vu = ks[last], vs[last]
        idx = np.searchsorted(ku, probes)
        idx_c = np.minimum(idx, ku.shape[0] - 1)
        hit = ku[idx_c] == probes
        found[hit] = True
        vptr[hit] = vu[idx_c[hit]]
        return found, vptr

    def drain_sorted(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sort, dedupe (newest wins), clear; returns (keys, seqs, vptrs)."""
        k = self._keys[: self._n]
        s = self._seqs[: self._n]
        v = self._vptrs[: self._n]
        order = np.lexsort((s, k))
        ks, ss, vs = k[order], s[order], v[order]
        last = np.r_[ks[1:] != ks[:-1], True]
        out = ks[last].copy(), ss[last].copy(), vs[last].copy()
        self._n = 0
        return out
