"""Range-partitioned Bourbon store across the mesh (DESIGN.md §4).

The cluster analogue of the paper's read path: the sorted key space is
range-partitioned over every mesh device (the cluster-level "FindFiles"),
each shard holds its slice plus a local PLR model, and a batched GET is one
shard_map program:

    all-gather the probe batch (tiny: 8B/probe)
      -> each shard answers probes in its own range via the learned path
         (segment compare-count + FMA + delta-window probe)
      -> masked psum combines results (each probe owned by exactly one shard)

Collective bytes per GET: B*8 all-gather + 2*B*8 all-reduce — independent of
DB size; this is what the bourbon_kv dry-run cells measure.  The state is
built once from a sorted snapshot (an immutable "level" in paper terms) and
never mutated in place — updates land in per-host memtables and roll into a
new snapshot (BourbonStore semantics), so the distributed plane needs no
write locks.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from .jaxcompat import shard_map
from .plr import greedy_plr_np

__all__ = ["DistStoreConfig", "build_dist_state", "dist_state_specs",
           "build_dist_get", "dist_get_local"]

KEY_SENTINEL = np.iinfo(np.int64).max


@dataclasses.dataclass(frozen=True)
class DistStoreConfig:
    n_keys: int              # global keys in the snapshot
    probe_batch: int         # global probes per GET step
    delta: int = 8
    seg_cap: int = 512       # per-shard PLR segments (padded)

    def shard_cap(self, n_shards: int) -> int:
        per = -(-self.n_keys // n_shards)
        return 1 << max(0, (per - 1).bit_length())


def build_dist_state(keys: np.ndarray, vptrs: np.ndarray, n_shards: int,
                     cfg: DistStoreConfig):
    """Host build: sorted keys -> stacked (n_shards, C) arrays + per-shard
    PLR models + range boundaries."""
    n = keys.shape[0]
    cap = cfg.shard_cap(n_shards)
    ks = np.full((n_shards, cap), KEY_SENTINEL, np.int64)
    vs = np.full((n_shards, cap), -1, np.int64)
    ns = np.zeros((n_shards,), np.int32)
    lo = np.full((n_shards,), KEY_SENTINEL, np.int64)
    hi = np.full((n_shards,), KEY_SENTINEL, np.int64)
    starts = np.full((n_shards, cfg.seg_cap), np.inf, np.float64)
    slopes = np.zeros((n_shards, cfg.seg_cap), np.float64)
    icepts = np.zeros((n_shards, cfg.seg_cap), np.float64)
    nseg = np.zeros((n_shards,), np.int32)
    per = -(-n // n_shards)
    for s in range(n_shards):
        chunk = keys[s * per: (s + 1) * per]
        if chunk.shape[0] == 0:
            continue
        ks[s, : chunk.shape[0]] = chunk
        vs[s, : chunk.shape[0]] = vptrs[s * per: (s + 1) * per]
        ns[s] = chunk.shape[0]
        lo[s], hi[s] = chunk[0], chunk[-1]
        m = greedy_plr_np(chunk, delta=cfg.delta, pad_to=cfg.seg_cap)
        k = int(m.n_segments)
        starts[s, :k] = np.asarray(m.starts)[:k]
        slopes[s, :k] = np.asarray(m.slopes)[:k]
        icepts[s, :k] = np.asarray(m.intercepts)[:k]
        nseg[s] = k
    return {"keys": ks, "vptrs": vs, "n": ns, "lo": lo, "hi": hi,
            "starts": starts, "slopes": slopes, "icepts": icepts,
            "nseg": nseg}


def dist_state_specs(mesh, cfg: DistStoreConfig):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    n_shards = mesh.size
    cap = cfg.shard_cap(n_shards)
    ax = tuple(mesh.axis_names)

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(
            (n_shards,) + shape, dtype,
            sharding=NamedSharding(mesh, P(ax)))

    return {
        "keys": sds((cap,), jnp.int64), "vptrs": sds((cap,), jnp.int64),
        "n": sds((), jnp.int32), "lo": sds((), jnp.int64),
        "hi": sds((), jnp.int64),
        "starts": sds((cfg.seg_cap,), jnp.float64),
        "slopes": sds((cfg.seg_cap,), jnp.float64),
        "icepts": sds((cfg.seg_cap,), jnp.float64),
        "nseg": sds((), jnp.int32),
    }


def dist_get_local(shard, probes, delta: int, seg_search: str = "bisect"):
    """One shard's answers for the full probe batch (masked outside its
    range).  shard leaves have a leading length-1 shard dim inside shard_map.

    seg_search: "bisect" (log2(S) gather steps; bytes ~ B*8 per step) or
    "compare" (one (B, S) broadcast compare; bytes ~ B*S*8 — memory-bound at
    large B; kept for the perf log)."""
    import math
    keys = shard["keys"][0]
    C = keys.shape[0]
    mine = (probes >= shard["lo"][0]) & (probes <= shard["hi"][0])
    pf = probes.astype(jnp.float64)
    starts = shard["starts"][0]
    if seg_search == "compare":
        seg = jnp.maximum(
            jnp.sum(starts[None, :] <= pf[:, None], axis=-1) - 1, 0)
    else:
        S = starts.shape[0]
        steps = max(1, math.ceil(math.log2(S + 1)))
        lo_i = jnp.zeros(pf.shape, jnp.int32)
        hi_i = jnp.broadcast_to(jnp.maximum(shard["nseg"][0], 1),
                                pf.shape).astype(jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            active = lo < hi
            mid = (lo + hi) >> 1
            kv = starts[jnp.clip(mid, 0, S - 1)]
            right = kv <= pf
            lo2 = jnp.where(right, mid + 1, lo)
            hi2 = jnp.where(right, hi, mid)
            return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

        lo_i, _ = jax.lax.fori_loop(0, steps, body, (lo_i, hi_i))
        seg = jnp.maximum(lo_i - 1, 0)
    pos = shard["slopes"][0][seg] * pf + shard["icepts"][0][seg]
    pos = jnp.clip(jnp.round(pos).astype(jnp.int32), 0,
                   jnp.maximum(shard["n"][0] - 1, 0))
    offs = jnp.arange(-(delta + 1), delta + 2, dtype=jnp.int32)
    win_idx = jnp.clip(pos[:, None] + offs[None, :], 0, C - 1)
    win = keys[win_idx]
    eq = win == probes[:, None]
    hit = jnp.any(eq, axis=-1) & mine
    rel = jnp.argmax(eq, axis=-1)
    idx = win_idx[jnp.arange(probes.shape[0]), rel]
    vptr = jnp.where(hit, shard["vptrs"][0][idx], 0)
    return hit, vptr


def build_dist_get(mesh, cfg: DistStoreConfig, seg_search: str = "bisect",
                   combine: str = "reduce_scatter"):
    """Returns jit(dist_get)(state, probes) -> (found, vptr).

    combine="reduce_scatter": results return only to each probe's origin
    shard (psum_scatter; half the payload of an all-reduce, outputs stay
    sharded).  combine="allreduce": every device gets every result (v1,
    kept for the perf log).  found rides as int8 (each probe has exactly
    one owner, so the reduced value is 0/1 — no overflow)."""
    ax = tuple(mesh.axis_names)
    state_spec = P(ax)
    probe_spec = P(ax)   # probes arrive sharded by origin device

    def body(shard, probes_local):
        probes = probes_local
        for a in ax:
            probes = jax.lax.all_gather(probes, a, tiled=True)
        hit, vptr = dist_get_local(shard, probes, cfg.delta, seg_search)
        found = hit.astype(jnp.int8)
        vsum = jnp.where(hit, vptr, 0)
        if combine == "reduce_scatter":
            for a in reversed(ax):
                found = jax.lax.psum_scatter(found, a, tiled=True)
                vsum = jax.lax.psum_scatter(vsum, a, tiled=True)
        else:
            for a in ax:
                found = jax.lax.psum(found, a)
                vsum = jax.lax.psum(vsum, a)
        return found > 0, jnp.where(found > 0, vsum, -1)

    out_spec = probe_spec if combine == "reduce_scatter" else P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: state_spec,
                               {"keys": 0, "vptrs": 0, "n": 0, "lo": 0,
                                "hi": 0, "starts": 0, "slopes": 0,
                                "icepts": 0, "nseg": 0}),
                  probe_spec),
        out_specs=(out_spec, out_spec),
        check_vma=False)
    return jax.jit(fn)
