"""Range-partitioned Bourbon store across the mesh (DESIGN.md §4).

The cluster analogue of the paper's read path: the sorted key space is
range-partitioned over every mesh device (the cluster-level "FindFiles"),
each shard holds its slice plus a local PLR model, and a batched GET is one
shard_map program:

    all-gather the probe batch (tiny: 8B/probe)
      -> each shard answers probes in its own range via the learned path
         (segment compare-count + FMA + delta-window probe)
      -> masked psum combines results (each probe owned by exactly one shard)

Collective bytes per GET: B*8 all-gather + 2*B*8 all-reduce — independent of
DB size; this is what the bourbon_kv dry-run cells measure.  The state is
built once from a sorted snapshot (an immutable "level" in paper terms) and
never mutated in place — updates land in per-host memtables and roll into a
new snapshot (BourbonStore semantics), so the distributed plane needs no
write locks.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from .jaxcompat import shard_map
from .plr import greedy_plr_np

__all__ = ["DistStoreConfig", "build_dist_state", "build_dist_state_from_shards",
           "dist_state_specs", "build_dist_get", "dist_get_local", "next_pow2"]

KEY_SENTINEL = np.iinfo(np.int64).max


def next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class DistStoreConfig:
    n_keys: int              # global keys in the snapshot
    probe_batch: int         # global probes per GET step
    delta: int = 8
    seg_cap: int = 512       # per-shard PLR segments (padded)

    def shard_cap(self, n_shards: int) -> int:
        return next_pow2(-(-self.n_keys // n_shards))


def _stack_shards(chunks, delta: int, cap: int | None,
                  seg_cap: int | None, models=None, filters=None):
    """Stack per-shard sorted (keys, vptrs) snapshots into the device-state
    dict, fitting one PLR model per shard.  ``cap``/``seg_cap`` default to
    the live maxima (padded to a power of two) so disk-recovered shards of
    any size fit; passing them pins the legacy fixed geometry.  ``models``
    supplies pre-fit per-shard PLR models (must use the same ``delta``) so
    a caller refreshing one shard need not refit the rest.  ``filters``
    (per-shard LevelFilter or None) adds stacked bloom rows ``fbits``
    (S, W) / ``fnw`` (S,) to the state so the GET kernel can prune shards
    that definitely lack a probe; ``fnw == 0`` marks no-filter rows."""
    n_shards = len(chunks)
    if models is None:
        models = [greedy_plr_np(k, delta=delta) if k.shape[0] else None
                  for k, _ in chunks]
    if cap is None:
        cap = max(64, next_pow2(max((k.shape[0] for k, _ in chunks),
                                    default=1)))
    if seg_cap is None:
        seg_cap = max(16, next_pow2(max(
            (int(m.n_segments) for m in models if m is not None), default=1)))
    ks = np.full((n_shards, cap), KEY_SENTINEL, np.int64)
    vs = np.full((n_shards, cap), -1, np.int64)
    ns = np.zeros((n_shards,), np.int32)
    lo = np.full((n_shards,), KEY_SENTINEL, np.int64)
    hi = np.full((n_shards,), KEY_SENTINEL, np.int64)
    starts = np.full((n_shards, seg_cap), np.inf, np.float64)
    slopes = np.zeros((n_shards, seg_cap), np.float64)
    icepts = np.zeros((n_shards, seg_cap), np.float64)
    nseg = np.zeros((n_shards,), np.int32)
    for s, ((chunk, vp), m) in enumerate(zip(chunks, models)):
        if chunk.shape[0] == 0:
            continue
        if chunk.shape[0] > cap:
            raise ValueError(f"shard {s} holds {chunk.shape[0]} keys > "
                             f"cap {cap}")
        ks[s, : chunk.shape[0]] = chunk
        vs[s, : chunk.shape[0]] = vp
        ns[s] = chunk.shape[0]
        lo[s], hi[s] = chunk[0], chunk[-1]
        k = int(m.n_segments)
        if k > seg_cap:
            raise ValueError(f"shard {s} model needs {k} segments > "
                             f"seg_cap {seg_cap}")
        starts[s, :k] = np.asarray(m.starts)[:k]
        slopes[s, :k] = np.asarray(m.slopes)[:k]
        icepts[s, :k] = np.asarray(m.intercepts)[:k]
        nseg[s] = k
    out = {"keys": ks, "vptrs": vs, "n": ns, "lo": lo, "hi": hi,
           "starts": starts, "slopes": slopes, "icepts": icepts,
           "nseg": nseg}
    if filters is not None:
        fw = max(64, next_pow2(max(
            (f.n_words for f in filters if f is not None), default=1)))
        fbits = np.zeros((n_shards, fw), np.uint64)
        fnw = np.zeros((n_shards,), np.int32)
        for s, f in enumerate(filters):
            if f is not None:
                fbits[s, : f.n_words] = f.bits
                fnw[s] = f.n_words
        out["fbits"] = fbits
        out["fnw"] = fnw
    return out


def build_dist_state(keys: np.ndarray, vptrs: np.ndarray, n_shards: int,
                     cfg: DistStoreConfig):
    """Host build: one globally sorted snapshot -> equal-count range chunks
    stacked into (n_shards, C) arrays + per-shard PLR models."""
    n = keys.shape[0]
    per = -(-n // n_shards)
    chunks = [(keys[s * per: (s + 1) * per], vptrs[s * per: (s + 1) * per])
              for s in range(n_shards)]
    return _stack_shards(chunks, cfg.delta, cfg.shard_cap(n_shards),
                         cfg.seg_cap)


def build_dist_state_from_shards(snapshots, delta: int = 8, models=None,
                                 filters=None):
    """Device state from per-shard snapshots (the durable-plane entry
    point): ``snapshots`` is a list of (keys, vptrs) pairs, one per range
    partition, each sorted by key with shadowed versions and tombstones
    already dropped — exactly what ``repro.distributed`` derives from a
    shard directory's sstables.  Geometry (row capacity, segment cap) is
    sized to the live maxima, so shards recovered from disk never need a
    global key count up front.  ``models`` optionally carries pre-fit
    per-shard PLR models (same ``delta``), letting an epoch-cached caller
    refit only the shards whose snapshot actually changed.  ``filters``
    optionally carries per-shard bloom filters (see ``_stack_shards``)."""
    return _stack_shards([(np.asarray(k, np.int64), np.asarray(v, np.int64))
                          for k, v in snapshots], delta, None, None, models,
                         filters)


def dist_state_specs(mesh, cfg: DistStoreConfig):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    n_shards = mesh.size
    cap = cfg.shard_cap(n_shards)
    ax = tuple(mesh.axis_names)

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(
            (n_shards,) + shape, dtype,
            sharding=NamedSharding(mesh, P(ax)))

    return {
        "keys": sds((cap,), jnp.int64), "vptrs": sds((cap,), jnp.int64),
        "n": sds((), jnp.int32), "lo": sds((), jnp.int64),
        "hi": sds((), jnp.int64),
        "starts": sds((cfg.seg_cap,), jnp.float64),
        "slopes": sds((cfg.seg_cap,), jnp.float64),
        "icepts": sds((cfg.seg_cap,), jnp.float64),
        "nseg": sds((), jnp.int32),
    }


def dist_get_local(shard, probes, delta: int, seg_search: str = "bisect",
                   maybe=None, k_hashes: int = 7):
    """One shard's answers for the full probe batch (masked outside its
    range).  shard leaves have a leading length-1 shard dim inside shard_map.

    seg_search: "bisect" (log2(S) gather steps; bytes ~ B*8 per step) or
    "compare" (one (B, S) broadcast compare; bytes ~ B*S*8 — memory-bound at
    large B; kept for the perf log).

    Filter pruning: ``maybe`` (a (B,) bool mask the caller probed
    separately) or, absent that, the shard's own ``fbits``/``fnw`` bloom
    row probed in-kernel; probes the filter rules out skip the descent."""
    import math
    keys = shard["keys"][0]
    C = keys.shape[0]
    # an empty shard keeps lo = hi = KEY_SENTINEL, so a probe equal to the
    # sentinel would otherwise "match" and index the zeroed model — mask
    # empty shards out explicitly
    mine = ((shard["n"][0] > 0)
            & (probes >= shard["lo"][0]) & (probes <= shard["hi"][0]))
    if maybe is None and "fbits" in shard:
        from repro.kernels.ref import bloom_probe_stack_ref
        maybe = bloom_probe_stack_ref(shard["fbits"], shard["fnw"],
                                      probes, k_hashes)[0]
    if maybe is not None:
        mine = mine & maybe
    pf = probes.astype(jnp.float64)
    starts = shard["starts"][0]
    if seg_search == "compare":
        seg = jnp.maximum(
            jnp.sum(starts[None, :] <= pf[:, None], axis=-1) - 1, 0)
    else:
        S = starts.shape[0]
        steps = max(1, math.ceil(math.log2(S + 1)))
        lo_i = jnp.zeros(pf.shape, jnp.int32)
        hi_i = jnp.broadcast_to(jnp.maximum(shard["nseg"][0], 1),
                                pf.shape).astype(jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            active = lo < hi
            mid = (lo + hi) >> 1
            kv = starts[jnp.clip(mid, 0, S - 1)]
            right = kv <= pf
            lo2 = jnp.where(right, mid + 1, lo)
            hi2 = jnp.where(right, hi, mid)
            return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

        lo_i, _ = jax.lax.fori_loop(0, steps, body, (lo_i, hi_i))
        seg = jnp.maximum(lo_i - 1, 0)
    pos = shard["slopes"][0][seg] * pf + shard["icepts"][0][seg]
    pos = jnp.clip(jnp.round(pos).astype(jnp.int32), 0,
                   jnp.maximum(shard["n"][0] - 1, 0))
    offs = jnp.arange(-(delta + 1), delta + 2, dtype=jnp.int32)
    win_idx = jnp.clip(pos[:, None] + offs[None, :], 0, C - 1)
    win = keys[win_idx]
    eq = win == probes[:, None]
    hit = jnp.any(eq, axis=-1) & mine
    rel = jnp.argmax(eq, axis=-1)
    idx = win_idx[jnp.arange(probes.shape[0]), rel]
    vptr = jnp.where(hit, shard["vptrs"][0][idx], 0)
    return hit, vptr


def build_dist_get(mesh, cfg: DistStoreConfig, seg_search: str = "bisect",
                   combine: str = "reduce_scatter",
                   state_keys: tuple | None = None, k_hashes: int = 7):
    """Returns jit(dist_get)(state, probes) -> (found, vptr).

    combine="reduce_scatter": results return only to each probe's origin
    shard (psum_scatter; half the payload of an all-reduce, outputs stay
    sharded).  combine="allreduce": every device gets every result (v1,
    kept for the perf log).  found rides as int8 (each probe has exactly
    one owner, so the reduced value is 0/1 — no overflow).

    ``state_keys`` pins the state-dict layout (pass the caller's actual
    ``tuple(state)`` when it carries the optional ``fbits``/``fnw`` filter
    rows); the default is the filterless nine-leaf legacy layout."""
    ax = tuple(mesh.axis_names)
    state_spec = P(ax)
    probe_spec = P(ax)   # probes arrive sharded by origin device
    if state_keys is None:
        state_keys = ("keys", "vptrs", "n", "lo", "hi", "starts", "slopes",
                      "icepts", "nseg")

    def body(shard, probes_local):
        probes = probes_local
        for a in ax:
            probes = jax.lax.all_gather(probes, a, tiled=True)
        hit, vptr = dist_get_local(shard, probes, cfg.delta, seg_search,
                                   k_hashes=k_hashes)
        found = hit.astype(jnp.int8)
        vsum = jnp.where(hit, vptr, 0)
        if combine == "reduce_scatter":
            for a in reversed(ax):
                found = jax.lax.psum_scatter(found, a, tiled=True)
                vsum = jax.lax.psum_scatter(vsum, a, tiled=True)
        else:
            for a in ax:
                found = jax.lax.psum(found, a)
                vsum = jax.lax.psum(vsum, a)
        return found > 0, jnp.where(found > 0, vsum, -1)

    out_spec = probe_spec if combine == "reduce_scatter" else P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=({k: state_spec for k in state_keys}, probe_spec),
        out_specs=(out_spec, out_spec),
        check_vma=False)
    return jax.jit(fn)
