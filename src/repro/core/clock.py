"""Virtual clock for the discrete-event side of the store.

The container has no TPU, so wall-clock lifetimes from the paper (T_wait =
50 ms, sstable lifetimes in minutes) are reproduced on a *virtual* microsecond
clock: every operation advances time by a cost drawn from a calibrated
:class:`CostModel`.  The CBA math is unchanged — only the time base differs
(DESIGN.md §8.4).  Real measured tensor-path latencies are reported separately
by the benchmarks.
"""

from __future__ import annotations

import dataclasses

__all__ = ["CostModel", "VirtualClock"]


@dataclasses.dataclass
class CostModel:
    """Per-operation virtual costs in microseconds.

    Defaults are calibrated per-key numbers from the CPU engine microbench
    (benchmarks/bench_paths.py) scaled to the paper's regime; they are
    config-injectable so tests are deterministic.

    t_*: internal-lookup service times (paper §4.4.2 notation).
      n = negative, p = positive; b = baseline path, m = model path.
    """

    t_nb: float = 1.6      # negative internal lookup, baseline
    t_pb: float = 3.2      # positive internal lookup, baseline
    t_nm: float = 0.8      # negative internal lookup, model
    t_pm: float = 1.6      # positive internal lookup, model
    t_put: float = 1.0     # per-record insert cost
    learn_per_key: float = 0.23   # Greedy-PLR per key (us): 40ms per ~175k-record file (paper §4.4.1)
    compact_per_key: float = 0.15  # merge cost per key (us)
    # value-log GC terms (§4.4 framing applied to maintenance):
    # collecting a segment costs a liveness probe per entry plus a
    # relocation (append + LSM re-insert) per *live* entry; the benefit of
    # reclaiming a dead byte is the avoided read/space amplification,
    # calibrated against the same virtual regime as the lookup terms.
    gc_scan_per_entry: float = 0.4    # liveness check per sealed entry (us)
    gc_move_per_entry: float = 2.0    # relocate one live entry (us)
    gc_benefit_per_dead_byte: float = 0.1   # avoided amplification (us/B)
    checkpoint_per_byte: float = 0.001  # MANIFEST rewrite cost (us/B)
    # filter-plane terms: building hashes each key k times (cheaper than a
    # PLR fit), and every held filter bit charges an amortized memory rent
    # — the terms the CBA sizing trades against false-positive probe cost
    filter_build_per_key: float = 0.05   # bloom build per key (us)
    filter_mem_per_bit: float = 0.0002   # amortized rent per filter bit (us)

    def t_build(self, n_keys: int) -> float:
        return self.learn_per_key * n_keys

    def t_filter_build(self, n_keys: int) -> float:
        """Virtual cost of building one level filter."""
        return self.filter_build_per_key * n_keys

    def t_gc(self, n_entries: int, n_live: int) -> float:
        """Virtual cost of collecting one segment (scan + relocation)."""
        return (self.gc_scan_per_entry * n_entries
                + self.gc_move_per_entry * n_live)

    def b_gc(self, dead_bytes: int) -> float:
        """Virtual benefit of reclaiming ``dead_bytes`` from the log."""
        return self.gc_benefit_per_dead_byte * dead_bytes


class VirtualClock:
    def __init__(self) -> None:
        self.now = 0.0

    def advance(self, us: float) -> float:
        self.now += us
        return self.now
