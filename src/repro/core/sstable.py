"""Immutable sstable files (host representation).

An sstable holds fixed-size records (key + value-pointer + seqno) sorted by
key — the WiscKey layout (§2.2): values live in the value log, so records are
fixed-size and a learned model can turn a predicted *position* directly into a
byte offset (§4.2).

Blocks: records are grouped into BLOCK_RECORDS-record blocks; the per-block
first keys form the "index block" (fence keys) used by the baseline path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bloom import bloom_build_np, bloom_words
from .plr import PLRModel, greedy_plr_np

__all__ = ["SSTable", "BLOCK_RECORDS", "build_sstable", "advance_file_ids"]

BLOCK_RECORDS = 256  # records per data block (4KB block / 16B record in paper)
_next_file_id = 0


def _new_file_id() -> int:
    global _next_file_id
    v = _next_file_id
    _next_file_id += 1
    return v


def advance_file_ids(floor: int) -> None:
    """Keep new file ids above any recovered from a MANIFEST."""
    global _next_file_id
    _next_file_id = max(_next_file_id, floor)


@dataclasses.dataclass
class FileStats:
    """Per-file counters feeding the cost-benefit analyzer (§4.4.2)."""

    n_neg: int = 0          # negative internal lookups served
    n_pos: int = 0          # positive internal lookups served
    neg_baseline_us: float = 0.0   # time spent on baseline path during wait
    pos_baseline_us: float = 0.0


@dataclasses.dataclass(eq=False)
class SSTable:
    keys: np.ndarray        # (n,) int64 sorted unique
    seqs: np.ndarray        # (n,) int64
    vptrs: np.ndarray       # (n,) int64, -1 = tombstone
    fences: np.ndarray      # (n_blocks,) int64 first key of each block
    bloom: np.ndarray       # (W,) uint64
    bloom_k: int
    level: int
    file_id: int
    created_at: float       # virtual us
    deleted_at: float | None = None
    model: PLRModel | None = None
    model_built_at: float | None = None
    learn_submitted: bool = False
    stats: FileStats = dataclasses.field(default_factory=FileStats)

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def min_key(self) -> int:
        return int(self.keys[0])

    @property
    def max_key(self) -> int:
        return int(self.keys[-1])

    def lifetime(self, now: float) -> float:
        end = self.deleted_at if self.deleted_at is not None else now
        return end - self.created_at

    def learn(self, delta: int, pad_to: int | None = None) -> PLRModel:
        """Fit the PLR model over this file's keys (host Greedy-PLR)."""
        self.model = greedy_plr_np(self.keys, delta=delta, pad_to=pad_to)
        return self.model


def build_sstable(keys: np.ndarray, seqs: np.ndarray, vptrs: np.ndarray,
                  level: int, now: float, bits_per_key: int = 10,
                  bloom_k: int = 7) -> SSTable:
    assert keys.ndim == 1 and keys.shape == seqs.shape == vptrs.shape
    n_blocks = max(1, -(-keys.shape[0] // BLOCK_RECORDS))
    fences = keys[::BLOCK_RECORDS][:n_blocks].copy()
    bloom = bloom_build_np(keys, bloom_words(keys.shape[0], bits_per_key), bloom_k)
    return SSTable(
        keys=np.ascontiguousarray(keys, np.int64),
        seqs=np.ascontiguousarray(seqs, np.int64),
        vptrs=np.ascontiguousarray(vptrs, np.int64),
        fences=np.ascontiguousarray(fences, np.int64),
        bloom=bloom, bloom_k=bloom_k, level=level,
        file_id=_new_file_id(), created_at=now,
    )
