"""BourbonStore — the public facade tying the pieces together.

Modes
-----
* ``mode="wisckey"``      — baseline (no learning, binary-search path).
* ``mode="bourbon"``      — file-granularity learning with a policy:
    - ``policy="cba"``     cost-benefit analyzer (the paper's default)
    - ``policy="always"``  learn every file (Bourbon-always)
    - ``policy="offline"`` only the initially loaded data is learned
    - ``policy="never"``   never learn (= wisckey but keeps CBA accounting)
* ``granularity="level"`` — level models (read-only friendly, §4.3).

Writes go memtable -> L0 -> compaction (host, numpy); reads are batched
tensor lookups through :class:`LookupEngine`.  A virtual microsecond clock
(clock.py) drives T_wait / lifetimes / Fig-13-style accounting, while the
benchmarks measure the real tensor-path latencies separately.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .cba import CBAConfig, CostBenefitAnalyzer, LearningExecutor
from .clock import CostModel, VirtualClock
from .engine import EngineConfig, LookupEngine, LookupResult
from .lsm import LSMConfig, LSMTree, N_LEVELS
from .memtable import MemTable
from .valuelog import ValueLog

__all__ = ["StoreConfig", "BourbonStore"]

_PAD_PROBE = -(1 << 62)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass
class StoreConfig:
    mode: str = "bourbon"             # wisckey | bourbon
    granularity: str = "file"         # file | level
    policy: str = "cba"               # cba | always | offline | never
    lsm: LSMConfig = dataclasses.field(default_factory=LSMConfig)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    cba: CBAConfig = dataclasses.field(default_factory=CBAConfig)
    costs: CostModel = dataclasses.field(default_factory=CostModel)
    value_size: int = 64
    fetch_values: bool = False

    def __post_init__(self):
        self.engine.plr_delta = self.lsm.plr_delta
        self.engine.bloom_k = self.lsm.bloom_k
        self.engine.fetch_values = self.fetch_values
        self.cba.policy = self.policy


class BourbonStore:
    def __init__(self, cfg: StoreConfig) -> None:
        self.cfg = cfg
        self.clock = VirtualClock()
        self.tree = LSMTree(cfg.lsm)
        self.memtable = MemTable(cfg.lsm.memtable_cap)
        self.vlog = ValueLog(cfg.value_size)
        self.engine = LookupEngine(cfg.engine)
        self.cba = CostBenefitAnalyzer(cfg.cba, cfg.costs)
        self.executor = LearningExecutor(self.cba, cfg.costs,
                                         cfg.cba.learner_slots,
                                         cfg.lsm.plr_delta, cfg.engine.seg_cap)
        self.level_models: list = [None] * N_LEVELS
        self._level_model_versions = [-1] * N_LEVELS
        self._pending_wait: list = []
        self._seq = 0
        self._dead_seen = 0
        # accounting (Fig 13)
        self.foreground_us = 0.0
        self.lookups_model_path = 0
        self.lookups_baseline_path = 0
        self.n_gets = 0
        self.n_puts = 0

    # ------------------------------------------------------------------ write
    def put_batch(self, keys: np.ndarray, values: np.ndarray | None = None) -> None:
        keys = np.asarray(keys, np.int64)
        b = keys.shape[0]
        if values is None:
            values = np.zeros((b, self.cfg.value_size), np.uint8)
            values[:, 0] = (keys & 0xFF).astype(np.uint8)
        vptrs = self.vlog.append_batch(values)
        seqs = np.arange(self._seq, self._seq + b, dtype=np.int64)
        self._seq += b
        off = 0
        while off < b:
            took = self.memtable.put_batch(keys[off:], seqs[off:], vptrs[off:])
            off += took
            if self.memtable.full:
                self._flush()
        self.n_puts += b
        self.foreground_us += self.cfg.costs.t_put * b
        self.clock.advance(self.cfg.costs.t_put * b)
        self._tick()

    def delete_batch(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.int64)
        b = keys.shape[0]
        seqs = np.arange(self._seq, self._seq + b, dtype=np.int64)
        self._seq += b
        vptrs = np.full(b, -1, np.int64)  # tombstones
        off = 0
        while off < b:
            took = self.memtable.put_batch(keys[off:], seqs[off:], vptrs[off:])
            off += took
            if self.memtable.full:
                self._flush()
        self.clock.advance(self.cfg.costs.t_put * b)
        self._tick()

    def _flush(self) -> None:
        k, s, v = self.memtable.drain_sorted()
        created = self.tree.flush(k, s, v, self.clock.now)
        self._pending_wait.extend(created)
        while (ev := self.tree.compact_once(self.clock.now)) is not None:
            self._pending_wait.extend(
                t for lvl in self.tree.levels for t in lvl
                if t.file_id in ev.created)
        self._after_structure_change()

    def _after_structure_change(self) -> None:
        # drain dead files into CBA stats
        for t in self.tree.dead_files[self._dead_seen:]:
            self.cba.observe_dead_file(t, self.clock.now)
        self._dead_seen = len(self.tree.dead_files)
        # invalidate level models on change; resubmit level learning
        if self.cfg.granularity == "level" and self.cfg.mode == "bourbon":
            for i in range(1, N_LEVELS):
                if self.tree.level_version[i] != self._level_model_versions[i]:
                    self.level_models[i] = None
                    self._level_model_versions[i] = self.tree.level_version[i]
                    if self.cfg.policy != "offline":
                        self.executor.submit_level(self.tree, i, self.clock.now)
        else:
            for i in range(N_LEVELS):
                if self.tree.level_version[i] != self._level_model_versions[i]:
                    self._level_model_versions[i] = self.tree.level_version[i]

    def _tick(self) -> None:
        if self.cfg.mode != "bourbon" or self.cfg.policy in ("offline", "never"):
            # offline/never: no online learning
            self.executor.tick(self.tree, self.clock.now, self.level_models)
            return
        if self.cfg.granularity == "file":
            t_wait = self.cba.t_wait(self.cfg.lsm.file_cap)
            still = []
            for t in self._pending_wait:
                if t.deleted_at is not None or t.model is not None:
                    continue
                if self.clock.now >= t.created_at + t_wait:
                    self.executor.maybe_submit_file(t, self.clock.now)
                else:
                    still.append(t)
            self._pending_wait = still
        self.executor.tick(self.tree, self.clock.now, self.level_models)

    # ------------------------------------------------------------------ read
    def _engine_mode(self) -> str:
        if self.cfg.mode == "wisckey":
            return "baseline"
        if self.cfg.granularity == "level":
            return "level"
        if all(t.model is not None for t in self.tree.all_files()):
            return "model_pure"   # skip the dead baseline arm
        return "model"

    def get_batch(self, probes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (found bool (B,), values (B, value_size) or vptrs)."""
        probes = np.asarray(probes, np.int64)
        B = probes.shape[0]
        mt_found, mt_vptr = self.memtable.get_batch(probes)
        miss = ~mt_found
        n_miss = int(miss.sum())
        found = mt_found.copy()
        vptr = mt_vptr.copy()
        if n_miss:
            pad = _next_pow2(max(n_miss, 64))
            eng_probes = np.full(pad, _PAD_PROBE, np.int64)
            eng_probes[:n_miss] = probes[miss]
            state = self.engine.build_state(self.tree, self.level_models)
            res = self.engine.lookup(state, eng_probes, self._engine_mode(),
                                     self.vlog,
                                     l0_live=len(self.tree.levels[0]))
            found[miss] = res.found[:n_miss]
            vptr[miss] = res.vptr[:n_miss]
            self._account_lookup(res)
        # a located tombstone (vptr -1) shadows older versions but the GET
        # reports not-found
        found &= vptr >= 0
        self.n_gets += B
        self.clock.advance(0.0)  # time added in _account_lookup
        self._tick()
        if self.cfg.fetch_values:
            return found, self.vlog.get_batch_np(vptr)
        return found, vptr

    def _account_lookup(self, res: LookupResult) -> None:
        """Attribute per-file internal lookups; advance virtual time by
        per-path costs (model path where the file had a model)."""
        c = self.cfg.costs
        us = 0.0
        for li in range(N_LEVELS):
            tables = self.tree.levels[li]
            pos_c, neg_c = res.pos_counts[li], res.neg_counts[li]
            for i, t in enumerate(tables):
                p = int(pos_c[i]) if i < pos_c.shape[0] else 0
                n = int(neg_c[i]) if i < neg_c.shape[0] else 0
                if p == 0 and n == 0:
                    continue
                t.stats.n_pos += p
                t.stats.n_neg += n
                has_model = (t.model is not None or
                             (self.cfg.granularity == "level" and
                              self.level_models[li] is not None))
                if has_model:
                    us += p * c.t_pm + n * c.t_nm
                    self.lookups_model_path += p + n
                else:
                    us += p * c.t_pb + n * c.t_nb
                    self.lookups_baseline_path += p + n
        self.foreground_us += us
        self.clock.advance(us)

    def range_query(self, start_keys: np.ndarray, length: int) -> np.ndarray:
        """Batched short scans: locate each start key (indexed path), then
        merge-scan `length` items host-side.  Returns (B, length) keys."""
        start_keys = np.asarray(start_keys, np.int64)
        out = np.full((start_keys.shape[0], length), -1, np.int64)
        # host merge across levels (values shadowing by seq)
        for bi, sk in enumerate(start_keys):
            heads = []
            for lvl in self.tree.levels:
                for t in lvl:
                    idx = int(np.searchsorted(t.keys, sk))
                    if idx < t.n:
                        heads.append((t.keys, idx))
            # simple k-way: repeatedly take global min >= cursor
            cursor = sk
            for j in range(length):
                best = None
                for keys, idx in heads:
                    while idx < keys.shape[0] and keys[idx] < cursor:
                        idx += 1
                    if idx < keys.shape[0]:
                        v = keys[idx]
                        if best is None or v < best:
                            best = v
                if best is None:
                    break
                out[bi, j] = best
                cursor = best + 1
        return out

    # --------------------------------------------------------------- control
    def learn_all(self) -> int:
        """Synchronously learn every live file (or level) — used to set up
        read-only experiments and ``offline`` mode initial models."""
        n = 0
        if self.cfg.granularity == "level":
            from .plr import greedy_plr_np
            for i in range(1, N_LEVELS):
                if self.tree.levels[i]:
                    keys = np.concatenate([t.keys for t in self.tree.levels[i]])
                    self.level_models[i] = greedy_plr_np(
                        keys, delta=self.cfg.lsm.plr_delta)
                    self._level_model_versions[i] = self.tree.level_version[i]
                    n += 1
            # L0 cannot be level-learned (overlapping ranges) -> file models
            for t in self.tree.levels[0]:
                t.learn(self.cfg.lsm.plr_delta, pad_to=self.cfg.engine.seg_cap)
                n += 1
            return n
        for lvl in self.tree.levels:
            for t in lvl:
                if t.model is None:
                    t.learn(self.cfg.lsm.plr_delta,
                            pad_to=self.cfg.engine.seg_cap)
                    n += 1
        self.executor.files_learned += n
        return n

    def flush_all(self) -> None:
        """Flush memtable + settle compactions (load-phase end)."""
        if len(self.memtable):
            self._flush()
        self._tick()

    def drain_learning(self, max_us: float = 1e12) -> None:
        """Advance virtual time until the learning queue is empty."""
        guard = 0
        while (self.executor.queue or self.executor.running) and guard < 10000:
            self.clock.advance(1000.0)
            self._tick()
            guard += 1

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        files = list(self.tree.all_files())
        n_learned = sum(1 for t in files if t.model is not None)
        model_bytes = sum(t.model.nbytes for t in files if t.model is not None)
        data_bytes = sum(t.n * 24 for t in files)
        segs = [int(t.model.n_segments) for t in files if t.model is not None]
        return {
            "n_files": len(files),
            "n_records": self.tree.total_records(),
            "n_learned": n_learned,
            "model_bytes": model_bytes,
            "data_bytes": data_bytes,
            "space_overhead": model_bytes / max(data_bytes, 1),
            "avg_segments": float(np.mean(segs)) if segs else 0.0,
            "total_segments": int(np.sum(segs)) if segs else 0,
            "foreground_us": self.foreground_us,
            "learn_us": self.executor.learn_time_us,
            "compact_us": self.tree.compacted_records * self.cfg.costs.compact_per_key,
            "files_learned": self.executor.files_learned,
            "model_path_frac": self.lookups_model_path /
                max(self.lookups_model_path + self.lookups_baseline_path, 1),
            "level_attempts": self.executor.level_attempts,
            "level_failures": self.executor.level_failures,
            "cba_decisions": dict(self.cba.decisions),
        }
