"""BourbonStore — the public facade tying the pieces together.

Modes
-----
* ``mode="wisckey"``      — baseline (no learning, binary-search path).
* ``mode="bourbon"``      — file-granularity learning with a policy:
    - ``policy="cba"``     cost-benefit analyzer (the paper's default)
    - ``policy="always"``  learn every file (Bourbon-always)
    - ``policy="offline"`` only the initially loaded data is learned
    - ``policy="never"``   never learn (= wisckey but keeps CBA accounting)
* ``granularity="level"`` — level models (read-only friendly, §4.3).

Writes go memtable -> L0 -> compaction (host, numpy); reads are batched
tensor lookups through :class:`LookupEngine`.  A virtual microsecond clock
(clock.py) drives T_wait / lifetimes / Fig-13-style accounting, while the
benchmarks measure the real tensor-path latencies separately.
"""

from __future__ import annotations

import copy
import dataclasses

import numpy as np

from repro.obs import NULL_CTRACE, NULL_HANDLE, publish_stats

from .cba import (CBAConfig, LearningExecutor, MaintenanceConfig,
                  MaintenanceScheduler)
from .clock import CostModel, VirtualClock
from .engine import EngineConfig, LookupEngine, LookupResult, PendingLookup
from .filters import FilterConfig, build_level_filter, filter_maybe_np
from .lsm import LSMConfig, LSMTree, N_LEVELS
from .memtable import MemTable
from .valuelog import ValueLog

__all__ = ["StoreConfig", "BourbonStore", "PendingBatch"]

_PAD_PROBE = -(1 << 62)

# below this batch size a pooled value fetch costs more in hand-off than
# the arena read itself; resolve stays inline
_IO_FETCH_CHUNK = 4096


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass
class StoreConfig:
    mode: str = "bourbon"             # wisckey | bourbon
    granularity: str = "file"         # file | level
    policy: str = "cba"               # cba | always | offline | never
    lsm: LSMConfig = dataclasses.field(default_factory=LSMConfig)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    cba: CBAConfig = dataclasses.field(default_factory=CBAConfig)
    costs: CostModel = dataclasses.field(default_factory=CostModel)
    maintenance: MaintenanceConfig = dataclasses.field(
        default_factory=MaintenanceConfig)
    filters: FilterConfig = dataclasses.field(default_factory=FilterConfig)
    value_size: int = 64
    fetch_values: bool = False
    # durability (repro.storage): None = in-memory store (seed behavior)
    storage_dir: str | None = None
    vlog_seg_slots: int = 1 << 12     # value-log entries per segment file
    fsync: bool = False               # fsync every append (power-loss safe)
    # group-commit WAL (repro.storage.wal.GroupCommitWAL): put_batch
    # acknowledges once the frame is queued and ordered; durability is at
    # the next wal_sync() — many batches coalesce into one fsync.  False
    # keeps the per-append writer (durable before put_batch returns)
    wal_group_commit: bool = False

    def __post_init__(self):
        self.engine.plr_delta = self.lsm.plr_delta
        self.engine.bloom_k = self.lsm.bloom_k
        self.engine.fetch_values = self.fetch_values
        self.cba.policy = self.policy


class _HostLookupRes:
    """Shape-compatible stand-in for LookupResult when a small remainder
    was answered host-side: only the per-file counters _account_lookup
    reads."""

    __slots__ = ("pos_counts", "neg_counts")

    def __init__(self, pos_counts, neg_counts):
        self.pos_counts = pos_counts
        self.neg_counts = neg_counts


@dataclasses.dataclass
class PendingBatch:
    """Dispatch half of a batched GET: the memtable overlay is already
    answered host-side, the engine part is in flight on the device
    (`PendingLookup`), and the whole handle is pinned to the device-state
    snapshot that was current at dispatch.  `BourbonStore.resolve_get`
    is the synchronization point — accounting, learning ticks, and value
    fetches all happen there, so dispatching N+1 never blocks on N."""
    probes: np.ndarray                 # (B,) int64, as submitted
    found: np.ndarray                  # (B,) bool, memtable hits prefilled
    vptr: np.ndarray                   # (B,) int64, memtable hits prefilled
    miss: np.ndarray                   # (B,) bool, keys the engine answers
    n_miss: int
    pending: PendingLookup | None      # None when the memtable answered all
    resolved: bool = False


class BourbonStore:
    def __init__(self, cfg: StoreConfig) -> None:
        self.cfg = cfg
        self.clock = VirtualClock()
        self.tree = LSMTree(cfg.lsm)
        self.memtable = MemTable(cfg.lsm.memtable_cap)
        # durable stores get a DurableValueLog from _attach_storage below —
        # don't allocate a throwaway in-memory arena for them
        self.vlog = ValueLog(cfg.value_size) if cfg.storage_dir is None \
            else None
        self.engine = LookupEngine(cfg.engine)
        self.cba = MaintenanceScheduler(cfg.cba, cfg.costs, cfg.maintenance)
        self.executor = LearningExecutor(self.cba, cfg.costs,
                                         cfg.cba.learner_slots,
                                         cfg.lsm.plr_delta, cfg.engine.seg_cap)
        self.level_models: list = [None] * N_LEVELS
        self._level_model_versions = [-1] * N_LEVELS
        # filter plane: per-level bloom filters ahead of the PLR descent
        # (core.filters).  Rebuilt lazily at dispatch when a level's
        # version moved; CBA picks bits-per-key from observed miss traffic
        self.level_filters: list = [None] * N_LEVELS
        self._filter_versions = [-1] * N_LEVELS
        self._filter_sized_at: dict[int, int] = {}  # level -> stat files seen
        self._flt_persisted: dict[int, int] = {}    # level -> epoch on disk
        self.filters_recovered = 0
        self.filters_built = 0
        self.filter_screened = 0       # keys answered "absent" pre-dispatch
        self.filter_screen_total = 0   # keys the host screen examined
        self.filter_host_answered = 0  # post-screen keys answered host-side
        self._pending_wait: list = []
        self._seq = 0
        self._dead_seen = 0
        # accounting (Fig 13)
        self.foreground_us = 0.0
        self.lookups_model_path = 0
        self.lookups_baseline_path = 0
        self.n_gets = 0
        self.n_puts = 0
        # durability (repro.storage)
        self._storage = None
        self._closed = False
        self._events_persisted = 0
        self._models_swept_at = 0
        self.models_recovered = 0
        self.level_models_recovered = 0
        self._lm_persisted: dict[int, int] = {}  # level -> epoch on disk
        # CBA-scheduled maintenance (auto value-log GC + checkpointing)
        self._in_maintenance = False
        # True = a fleet coordinator owns the maintenance ticks: _tick()
        # stops self-driving and run_maintenance() is called externally
        # with a per-tick budget (repro.server.FleetMaintenanceCoordinator)
        self.maintenance_deferred = False
        self.last_maintenance_us = 0.0   # virtual cost of the last round
        # observability (repro.obs): attach_obs wires these; the defaults
        # are null objects so the hot paths never branch on "obs on?"
        self._obs = None
        self._obs_labels: dict = {}
        self._obs_events = None
        self._vf = NULL_HANDLE           # value-fetch stage handle
        self._fp = NULL_HANDLE           # filter-probe stage handle
        # host I/O plane (repro.io): attach_io wires a worker pool so
        # large value fetches chunk across threads; None = inline fetch
        self._io = None
        self.auto_gc_stats = {"runs": 0, "segments_removed": 0,
                              "bytes_reclaimed": 0, "entries_moved": 0}
        if cfg.storage_dir is not None:
            self._attach_storage(cfg.storage_dir)

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def open(cls, path, cfg: StoreConfig | None = None) -> "BourbonStore":
        """Open (or create) a durable store at ``path``.

        An existing directory is recovered: MANIFEST replay rebuilds the
        levels from mmap'd sstables (persisted PLR models reload without
        retraining), the value log is reloaded, and the WAL is replayed
        into the memtable.
        """
        cfg = cfg if cfg is not None else StoreConfig()
        # deep copy: the caller's config (and its nested lsm/engine/cba)
        # must not be shared with or mutated through this store
        cfg = copy.deepcopy(cfg)
        cfg.storage_dir = str(path)
        return cls(cfg)

    def _attach_storage(self, path: str) -> None:
        # imported lazily: repro.storage depends on repro.core submodules
        from repro.storage import DurableValueLog, StorageEngine, load_tables
        self._storage = StorageEngine(
            path, fsync=self.cfg.fsync,
            group_commit=self.cfg.wal_group_commit)
        try:
            # validate (or record, on a fresh dir) the store geometry
            # before any segment file is parsed with a possibly-wrong
            # entry size or models served with a smaller search window
            self._storage.ensure_format(self.cfg.value_size,
                                        self.cfg.vlog_seg_slots,
                                        self.cfg.lsm.plr_delta)
            if self._storage.recovered:
                self._recover(load_tables, DurableValueLog)
            else:
                self.vlog = DurableValueLog(self.cfg.value_size, path,
                                            seg_slots=self.cfg.vlog_seg_slots,
                                            fsync=self.cfg.fsync)
        except BaseException:
            # release the directory lock: a failed open must not wedge the
            # next (correctly configured) one
            self._storage.abort()
            self._storage = None
            raise

    def _recover(self, load_tables, durable_vlog_cls) -> None:
        eng = self._storage
        state = eng.state
        self.tree.levels = load_tables(eng.dir, state)
        for t in self.tree.all_files():
            if t.model is not None:
                eng.persisted_models.add(t.file_id)
        self.models_recovered = len(eng.persisted_models)
        # epochs must stay unique across reopens: resume past the largest
        # persisted one even when the models/filters themselves aren't
        # loaded (e.g. a file-granularity open of a level-granularity dir)
        epochs = list(state.level_models.values()) + list(
            state.filters.values())
        if epochs:
            self.executor.next_model_epoch = max(epochs) + 1
        # persisted level models (§4.3): reload them BEFORE WAL replay and
        # pin the version baseline, so a replay-triggered flush invalidates
        # exactly the levels it touches — mirroring the manifest, whose
        # add/del edits drop the lmodel records of touched levels
        if self.cfg.granularity == "level" and self.cfg.mode == "bourbon":
            from repro.storage import load_level_model
            from repro.storage.format import lmodel_path
            for level, epoch in state.level_models.items():
                m = load_level_model(lmodel_path(eng.dir, level, epoch))
                if m is None:
                    continue   # torn sidecar: fall back to relearning
                m.epoch = epoch
                self.level_models[level] = m
                self._lm_persisted[level] = epoch
                self.level_models_recovered += 1
        # persisted filters reload the same way (before WAL replay, version
        # baseline pinned): a reopened store serves the filtered path with
        # zero rebuild.  A filter built under a different hash count is
        # useless to this engine — treat it like a torn sidecar
        if self.cfg.filters.enabled and state.filters:
            from repro.storage import load_level_filter
            from repro.storage.format import filter_path
            for level, epoch in state.filters.items():
                lf = load_level_filter(filter_path(eng.dir, level, epoch))
                if lf is None or lf.k_hashes != self.cfg.lsm.bloom_k:
                    continue   # torn/mismatched sidecar: rebuild lazily
                lf.epoch = epoch
                self.level_filters[level] = lf
                self._flt_persisted[level] = epoch
                self.filters_recovered += 1
        self._level_model_versions = list(self.tree.level_version)
        self._filter_versions = list(self.tree.level_version)
        self.vlog = durable_vlog_cls.open(
            eng.dir, self.cfg.value_size, self.cfg.vlog_seg_slots,
            state.vlog_removed, state.vhead, fsync=self.cfg.fsync,
            dead_by_seg=state.vlog_dead)
        self.clock.advance(state.clock)
        self._seq = state.seq
        for keys, seqs, vptrs in eng.replay_old_wal():
            if seqs.shape[0]:
                self._seq = max(self._seq, int(seqs.max()) + 1)
            self._ingest(keys, seqs, vptrs)
        # if replay flushed, flush the remainder too so the recovery WAL
        # (whose records would otherwise re-flush into duplicate tables on
        # every reopen) can be rotated away empty
        if self._events_persisted and len(self.memtable):
            self._flush()
        eng.finish_recovery(self._seq, self.clock.now, len(self.vlog),
                            rotate=bool(self._events_persisted))
        # recovered-but-unlearned files re-enter the learning pipeline
        self._pending_wait.extend(
            t for t in self.tree.all_files() if t.model is None)
        # levels whose persisted model was missing, torn, or invalidated by
        # a replay flush resubmit their learning jobs — the rest serve the
        # model path immediately with an empty learn queue
        if (self.cfg.granularity == "level" and self.cfg.mode == "bourbon"
                and self.cfg.policy != "offline"):
            queued = {j.level for j in self.executor.queue if j.is_level}
            queued |= {j.level for _, j in self.executor.running
                       if j.is_level}
            for i in range(1, N_LEVELS):
                if (self.tree.levels[i] and self.level_models[i] is None
                        and i not in queued):
                    self.executor.submit_level(self.tree, i, self.clock.now)

    def close(self) -> None:
        """Release durable resources.  The memtable is NOT flushed — the
        WAL re-derives it on the next open (exercising the recovery path
        even on clean shutdown)."""
        if self._storage is None:
            return
        self._sweep_level_models()
        self._sweep_filters()
        self.vlog.close()
        self._storage.close(self._seq, self.clock.now, len(self.vlog),
                            vdead=self.vlog.dead_delta())
        self._storage = None
        self._closed = True  # a closed durable store must not accept writes

    def _check_writable(self) -> None:
        if self._closed:
            raise RuntimeError("store is closed — writes would be silently "
                               "non-durable; reopen with BourbonStore.open()")

    def wal_sync(self) -> None:
        """Durability barrier for acknowledged writes: under the
        group-commit WAL this waits for (at most) one coalesced
        flush+fsync covering everything ``put_batch`` acknowledged so
        far; with the per-append writer (or no storage) it is a no-op
        — every append was already durable when it returned."""
        if self._storage is not None:
            self._storage.wal_sync()

    # -------------------------------------------------------------- io plane
    def attach_io(self, pool) -> None:
        """Join a :class:`repro.io.IOPool`: value fetches for large
        batches are chunked across the pool's workers (fixed-slice
        scatter into one preallocated array, so results are identical to
        the inline path for any pool size)."""
        self._io = pool

    def detach_io(self) -> None:
        self._io = None

    def _fetch_values(self, vptr: np.ndarray) -> np.ndarray:
        """Materialize values for a batch of resolved pointers.  Small
        batches stay inline (a pool round-trip costs more than the arena
        read); large ones fan out in fixed slices."""
        pool = self._io
        b = vptr.shape[0]
        if pool is None or b <= _IO_FETCH_CHUNK:
            return self.vlog.get_batch_np(vptr)
        from repro.io import wait_all
        out = np.empty((b, self.cfg.value_size), np.uint8)

        def fetch(lo: int, hi: int) -> None:
            out[lo:hi] = self.vlog.get_batch_np(vptr[lo:hi])

        futs = [pool.submit(fetch, lo, min(lo + _IO_FETCH_CHUNK, b))
                for lo in range(0, b, _IO_FETCH_CHUNK)]
        wait_all(futs)
        return out

    # ------------------------------------------------------------------ write
    def put_batch(self, keys: np.ndarray, values: np.ndarray | None = None) -> None:
        self._check_writable()
        keys = np.asarray(keys, np.int64)
        b = keys.shape[0]
        if values is None:
            values = np.zeros((b, self.cfg.value_size), np.uint8)
            values[:, 0] = (keys & 0xFF).astype(np.uint8)
        seqs = np.arange(self._seq, self._seq + b, dtype=np.int64)
        self._seq += b
        vptrs = self.vlog.append_kv(keys, seqs, values)
        if self._storage is not None and self.cfg.maintenance.track_dead:
            self._note_superseded(keys, vptrs)   # before ingest: pre-write
        self._ingest(keys, seqs, vptrs)
        self.n_puts += b
        self.foreground_us += self.cfg.costs.t_put * b
        self.clock.advance(self.cfg.costs.t_put * b)
        self._tick()

    def delete_batch(self, keys: np.ndarray) -> None:
        self._check_writable()
        keys = np.asarray(keys, np.int64)
        b = keys.shape[0]
        seqs = np.arange(self._seq, self._seq + b, dtype=np.int64)
        self._seq += b
        vptrs = np.full(b, -1, np.int64)  # tombstones
        if self._storage is not None and self.cfg.maintenance.track_dead:
            self._note_superseded(keys, None)
        self._ingest(keys, seqs, vptrs)
        self.clock.advance(self.cfg.costs.t_put * b)
        self._tick()

    def _note_superseded(self, keys: np.ndarray,
                         new_vptrs: np.ndarray | None) -> None:
        """Write-path half of the dead-entry estimate: every overwrite or
        delete retires the key's previous value-log slot, and duplicate
        keys within one batch retire all but the batch's last slot.  The
        per-segment counters this feeds (ValueLog.note_dead) are what lets
        GC candidacy skip the full-log scan."""
        uniq = np.unique(keys)
        old = self._host_get_vptrs(uniq)
        self.vlog.note_dead(old[old >= 0])
        if new_vptrs is not None and uniq.shape[0] < keys.shape[0]:
            order = np.lexsort((np.arange(keys.shape[0]), keys))
            ks = keys[order]
            dup = np.r_[ks[1:] == ks[:-1], False]  # non-last occurrences
            self.vlog.note_dead(new_vptrs[order][dup])

    def _ingest(self, keys: np.ndarray, seqs: np.ndarray,
                vptrs: np.ndarray) -> None:
        """Memtable insertion in WAL-aligned chunks: each chunk is logged
        durably before it enters the memtable, and a flush only ever runs
        with the WAL covering exactly the drained records (so rotation at
        flush time cannot drop acknowledged writes)."""
        b = keys.shape[0]
        off = 0
        while off < b:
            take = min(self.memtable.capacity - len(self.memtable), b - off)
            sl = slice(off, off + take)
            if self._storage is not None:
                self._storage.wal_append(keys[sl], seqs[sl], vptrs[sl])
            took = self.memtable.put_batch(keys[sl], seqs[sl], vptrs[sl])
            assert took == take
            off += take
            if self.memtable.full:
                self._flush()

    def _flush(self) -> None:
        k, s, v = self.memtable.drain_sorted()
        created = self.tree.flush(k, s, v, self.clock.now)
        self._pending_wait.extend(created)
        while (ev := self.tree.compact_once(self.clock.now)) is not None:
            self._pending_wait.extend(
                t for lvl in self.tree.levels for t in lvl
                if t.file_id in ev.created)
        if self._storage is not None:
            self._persist_structure()
        self._after_structure_change()

    def _persist_structure(self) -> None:
        """Durably commit the flush/compaction batch that just settled:
        net-new files are written, net deletions recorded, and the WAL
        rotated (the memtable is empty here, so the old WAL is covered)."""
        events = self.tree.events[self._events_persisted:]
        if not events:
            return
        created: list[int] = []
        deleted: set[int] = set()
        for ev in events:
            created.extend(ev.created)
            deleted.update(ev.deleted)
        live_by_id = {t.file_id: t for t in self.tree.all_files()}
        add_tables = [live_by_id[fid] for fid in created
                      if fid in live_by_id]
        self._storage.persist_flush(add_tables, sorted(deleted), self._seq,
                                    self.clock.now, len(self.vlog),
                                    vdead=self.vlog.dead_delta())
        # only after the commit landed: a transient I/O error above must
        # leave these events pending, not silently dropped
        self._events_persisted = len(self.tree.events)
        self.vlog.clear_dead_dirty()

    def _after_structure_change(self) -> None:
        # drain dead files into CBA stats
        for t in self.tree.dead_files[self._dead_seen:]:
            self.cba.observe_dead_file(t, self.clock.now)
        self._dead_seen = len(self.tree.dead_files)
        # invalidate level models on change; resubmit level learning
        if self.cfg.granularity == "level" and self.cfg.mode == "bourbon":
            for i in range(1, N_LEVELS):
                if self.tree.level_version[i] != self._level_model_versions[i]:
                    self.level_models[i] = None
                    # the manifest's add/del edit (already appended by
                    # _persist_structure) dropped this level's lmodel
                    # record; mirror that here and reap the sidecar
                    stale = self._lm_persisted.pop(i, None)
                    if stale is not None and self._storage is not None:
                        self._storage.drop_level_model(i, stale)
                    self._level_model_versions[i] = self.tree.level_version[i]
                    if self.cfg.policy != "offline":
                        self.executor.submit_level(self.tree, i, self.clock.now)
        else:
            for i in range(N_LEVELS):
                if self.tree.level_version[i] != self._level_model_versions[i]:
                    self._level_model_versions[i] = self.tree.level_version[i]
        # filters invalidate on any structure change, independent of model
        # granularity: compaction churn rewrites a level's key set, so its
        # filter (and the persisted sidecar record, already dropped from
        # the MANIFEST by the add/del edit) is stale.  The rebuild happens
        # lazily at the next dispatch (_ensure_filters)
        if self.cfg.filters.enabled:
            for i in range(N_LEVELS):
                if self.tree.level_version[i] != self._filter_versions[i]:
                    self.level_filters[i] = None
                    stale = self._flt_persisted.pop(i, None)
                    if stale is not None and self._storage is not None:
                        self._storage.drop_level_filter(i, stale)

    def _tick(self) -> None:
        if self.cfg.mode != "bourbon" or self.cfg.policy in ("offline", "never"):
            # offline/never: no online learning
            self.executor.tick(self.tree, self.clock.now, self.level_models)
            self._sweep_level_models()
            self._sweep_filters()
            self._maintenance_tick()
            return
        if self.cfg.granularity == "file":
            t_wait = self.cba.t_wait(self.cfg.lsm.file_cap)
            still = []
            for t in self._pending_wait:
                if t.deleted_at is not None or t.model is not None:
                    continue
                if self.clock.now >= t.created_at + t_wait:
                    self.executor.maybe_submit_file(t, self.clock.now)
                else:
                    still.append(t)
            self._pending_wait = still
        self.executor.tick(self.tree, self.clock.now, self.level_models)
        if (self._storage is not None
                and self.executor.files_learned != self._models_swept_at):
            self._models_swept_at = self.executor.files_learned
            self._persist_new_models()
        self._sweep_level_models()
        self._sweep_filters()
        self._maintenance_tick()

    def _maintenance_tick(self) -> None:
        if self.maintenance_deferred:
            return   # a fleet coordinator owns the ticks (repro.server)
        self.run_maintenance()

    def run_maintenance(self, budget_us: float | None = None) -> float:
        """One round of CBA-scheduled maintenance (§4.4 extended): run
        value-log GC on segments whose estimated reclaim benefit exceeds
        the relocation cost, and fold the MANIFEST once its edit log is
        worth rewriting.  Both charge the virtual clock like any other
        background work.

        ``budget_us`` makes the round budget-bounded: GC candidates are
        picked only while their (conservative) estimated cost fits, and
        the checkpoint is skipped when its cost would overrun — so the
        virtual time charged never exceeds the budget.  Returns the
        virtual microseconds actually charged (also exposed as
        ``last_maintenance_us``), 0.0 when nothing was worth doing."""
        if self._storage is None or self._in_maintenance or self._closed:
            return 0.0
        m = self.cfg.maintenance
        t0 = self.clock.now
        self._in_maintenance = True
        try:
            if m.auto_gc:
                segs = self.cba.gc_candidates(self.vlog, self.clock.now,
                                              budget_us=budget_us)
                if segs:
                    res = self.gc_value_log(min_dead_ratio=0.0,
                                            segments=segs)
                    self.cba.gc_runs += 1
                    self.auto_gc_stats["runs"] += 1
                    for k in ("segments_removed", "bytes_reclaimed",
                              "entries_moved"):
                        self.auto_gc_stats[k] += res[k]
                    if self._obs_events is not None:
                        self._obs_events.log(
                            "gc", at_us=self.clock.now,
                            candidates=len(segs),
                            cost_us=self.cba.last_plan_cost_us,
                            benefit_us=self.cba.last_plan_benefit_us,
                            **res, **self._obs_labels)
            if (not self._storage.in_recovery and self.cba.should_checkpoint(
                    self._storage.manifest_tail_bytes())):
                # the fold rewrites the whole live state, so its cost is
                # known up front — defer it when over budget.  But the
                # fold is atomic and its cost only grows with the store:
                # when it exceeds even an otherwise-unspent budget it
                # would be deferred forever while the edit log grows, so
                # run it anyway and count the overrun
                est = (self.cfg.costs.checkpoint_per_byte
                       * self._storage.manifest_bytes())
                spent = self.clock.now - t0
                never_fits = (budget_us is not None and spent == 0.0
                              and est > budget_us)
                if budget_us is None or spent + est <= budget_us \
                        or never_fits:
                    if never_fits:
                        self.cba.checkpoint_overruns += 1
                    folded = self._storage.checkpoint()
                    cost = self.cfg.costs.checkpoint_per_byte * folded
                    self.cba.checkpoints += 1
                    self.cba.checkpoint_us += cost
                    self.clock.advance(cost)
                    if self._obs_events is not None:
                        self._obs_events.log(
                            "checkpoint", at_us=self.clock.now,
                            cost_us=cost, folded_bytes=folded,
                            **self._obs_labels)
        finally:
            self._in_maintenance = False
        self.last_maintenance_us = self.clock.now - t0
        return self.last_maintenance_us

    def _persist_new_models(self) -> None:
        """Append just-learned PLR models into their sstable files."""
        for t in self.tree.all_files():
            if t.model is not None:
                self._storage.persist_model(t)

    def _sweep_level_models(self) -> None:
        """Durably publish level models whose epoch the MANIFEST doesn't
        reference yet.  Every fit stamps a fresh monotonic epoch (the
        executor's counter, seeded past the persisted maximum on
        recovery), so "new" is simply epoch-not-yet-persisted."""
        if self._storage is None or self.cfg.granularity != "level":
            return
        for i, m in enumerate(self.level_models):
            if m is None or getattr(m, "epoch", -1) < 0:
                continue
            if self._lm_persisted.get(i) == m.epoch:
                continue
            self._storage.persist_level_model(i, m)
            self._lm_persisted[i] = m.epoch

    def _sweep_filters(self) -> None:
        """Durably publish level filters the MANIFEST doesn't reference yet
        (same epoch-not-yet-persisted discipline as _sweep_level_models)."""
        if self._storage is None or not self.cfg.filters.enabled:
            return
        for i, f in enumerate(self.level_filters):
            if f is None or f.epoch < 0:
                continue
            if self._flt_persisted.get(i) == f.epoch:
                continue
            self._storage.persist_level_filter(i, f)
            self._flt_persisted[i] = f.epoch

    # --------------------------------------------------------------- filters
    def _ensure_filters(self) -> None:
        """(Re)build level filters whose level changed since the last
        build, plus CBA-triggered resizes when fresh miss-traffic stats
        move the optimal bits-per-key far enough from what's built.  Build
        is host-side numpy over the level's full key set (tombstones
        included — a tombstone must pass its filter so the engine finds it
        and reports the delete); cost is charged to the virtual clock like
        a learning job."""
        fc = self.cfg.filters
        for li in range(N_LEVELS):
            tables = self.tree.levels[li]
            fresh = self.tree.level_version[li] != self._filter_versions[li]
            if not tables:
                if fresh:
                    self.level_filters[li] = None
                    self._filter_versions[li] = self.tree.level_version[li]
                continue
            cur = self.level_filters[li]
            rebuilt = False
            if not fresh and cur is not None:
                # FPR drift: compaction churn changed the observed miss
                # traffic — re-size only when the completed-file stats
                # actually moved (cheap gate, not per-dispatch math)
                st = self.cba.level_stats.get(li)
                nf = st.n_files if st is not None else 0
                # nf == 0 means no stats (e.g. right after reopen): sizing
                # would just return the bootstrap base, so a recovered
                # CBA-sized filter must not be churned against it
                if nf and nf != self._filter_sized_at.get(li, -1):
                    self._filter_sized_at[li] = nf
                    n_keys = sum(t.n for t in tables)
                    want = self.cba.filter_bits_per_key(
                        li, n_keys, fc.bits_per_key, fc.min_bits_per_key,
                        fc.max_bits_per_key, self.cfg.lsm.bloom_k)
                    if abs(want - cur.bits_per_key) >= fc.rebuild_delta_bpk:
                        rebuilt = True
                        self.cba.filter_decisions["rebuilt"] += 1
            if cur is not None and not fresh and not rebuilt:
                continue
            n_keys = sum(t.n for t in tables)
            bpk = self.cba.filter_bits_per_key(
                li, n_keys, fc.bits_per_key, fc.min_bits_per_key,
                fc.max_bits_per_key, self.cfg.lsm.bloom_k)
            keys = (tables[0].keys if len(tables) == 1 else
                    np.concatenate([t.keys for t in tables]))
            f = build_level_filter(keys, bpk, self.cfg.lsm.bloom_k)
            f.epoch = self.executor.alloc_model_epoch()
            self.level_filters[li] = f
            self._filter_versions[li] = self.tree.level_version[li]
            self.filters_built += 1
            self.cba.filter_builds += 1
            cost = self.cfg.costs.t_filter_build(n_keys)
            self.cba.filter_us += cost
            self.clock.advance(cost)

    # ------------------------------------------------------------------ read
    def _engine_mode(self) -> str:
        if self.cfg.mode == "wisckey":
            return "baseline"
        if self.cfg.granularity == "level":
            return "level"
        files = list(self.tree.all_files())
        # an empty tree must not claim model_pure (vacuous all()): the
        # mixed path stays correct for whatever flushes next
        if files and all(t.model is not None for t in files):
            return "model_pure"   # skip the dead baseline arm
        return "model"

    def _host_answer(self, keys: np.ndarray, fmaybe_keep: np.ndarray,
                     live_idx: list) -> tuple:
        """Answer a small post-screen remainder without a device round
        trip: numpy binary search over the host sstable key arrays,
        mirroring the engine's descent exactly (newest-first L0 slots,
        then the candidate file per sorted level, per-level filter mask
        applied the same way) so results stay byte-identical with the
        device path.  An absent sweep collapses to a handful of bloom
        false positives — not worth the fixed device-dispatch cost."""
        B = keys.shape[0]
        found = np.zeros(B, bool)
        vptr = np.full(B, -1, np.int64)
        pos = [np.zeros(len(self.tree.levels[li]), np.int64)
               for li in range(N_LEVELS)]
        neg = [np.zeros_like(p) for p in pos]
        mrow = {li: fmaybe_keep[r] for r, li in enumerate(live_idx)}
        maxk = {li: np.array([t.keys[-1] for t in self.tree.levels[li]],
                             np.int64)
                for li in live_idx if li > 0}
        for bi in range(B):
            k = int(keys[bi])
            for li in live_idx:
                row = mrow[li]
                if not row[bi]:
                    continue                  # filter-pruned level
                tables = self.tree.levels[li]
                hit = False
                if li == 0:
                    for si, t in enumerate(tables):
                        if t.keys[0] <= k <= t.keys[-1]:
                            j = int(np.searchsorted(t.keys, k))
                            if j < t.n and int(t.keys[j]) == k:
                                pos[0][si] += 1
                                vptr[bi] = int(t.vptrs[j])
                                hit = True
                                break
                            neg[0][si] += 1
                else:
                    # candidate = first file with max_key >= k (engine's
                    # FindFiles), valid if the file's range covers k
                    si = int(np.searchsorted(maxk[li], k))
                    if si < len(tables) and int(tables[si].keys[0]) <= k:
                        t = tables[si]
                        j = int(np.searchsorted(t.keys, k))
                        if j < t.n and int(t.keys[j]) == k:
                            pos[li][si] += 1
                            vptr[bi] = int(t.vptrs[j])
                            hit = True
                        else:
                            neg[li][si] += 1
                if hit:
                    found[bi] = True
                    break
        return found, vptr, pos, neg

    def dispatch_get(self, probes: np.ndarray) -> PendingBatch:
        """Non-blocking half of :meth:`get_batch`: answer the memtable
        overlay host-side and launch the device lookup for the misses,
        returning a :class:`PendingBatch` without waiting for the device.
        The handle is pinned to the device state current at dispatch —
        writes applied afterwards are invisible to it, which is exactly
        the snapshot-per-batch contract the serving plane wants."""
        probes = np.asarray(probes, np.int64)
        mt_found, mt_vptr = self.memtable.get_batch(probes)
        mt_found = mt_found.copy()
        mt_vptr = mt_vptr.copy()
        miss = ~mt_found
        n_miss = int(miss.sum())
        fstate = None
        fmaybe_keep = live_idx = None
        if self.cfg.filters.enabled and n_miss:
            # host screen: keys the filters rule out at *every* level never
            # dispatch — they resolve as misses with zero device probes
            self._ensure_filters()
            t0 = self._fp.begin()
            # only populated levels can hold the key; an empty level must
            # not contribute an all-maybe row or nothing ever screens
            live_idx = [li for li in range(N_LEVELS) if self.tree.levels[li]]
            live_filters = [self.level_filters[li] for li in live_idx]
            fmaybe = filter_maybe_np(live_filters, probes[miss])
            screened = ~fmaybe.any(axis=0)
            self._fp.end(t0)
            n_scr = int(screened.sum())
            self.filter_screen_total += n_miss
            if n_scr:
                self.filter_screened += n_scr
                miss_idx = np.flatnonzero(miss)
                miss[miss_idx[screened]] = False
                mt_vptr[miss_idx[screened]] = -1   # engine miss convention
                n_miss -= n_scr
            fmaybe_keep = fmaybe[:, ~screened]
            fstate = self.engine.build_filter_state(self.level_filters)
            if 0 < n_miss <= self.cfg.filters.host_answer_max:
                # remainder too small to be worth a device round trip:
                # binary-search the host sstable arrays instead
                idx = np.flatnonzero(miss)
                hf, hv, hpos, hneg = self._host_answer(
                    probes[miss], fmaybe_keep, live_idx)
                mt_found[idx] = hf
                mt_vptr[idx] = hv
                miss[idx] = False
                self.filter_host_answered += n_miss
                n_miss = 0
                self._account_lookup(_HostLookupRes(hpos, hneg))
        pending = None
        if n_miss:
            # quarter-pow2 buckets, not pow2: the filter screen shrinks
            # n_miss to arbitrary sizes, and rounding 2100 all the way back
            # up to 4096 would hand the screening win straight back to the
            # kernel width.  Still a small, bounded set of jit cache keys.
            n = max(n_miss, 64)
            step = max(64, _next_pow2(n) // 4)
            pad = -(-n // step) * step
            eng_probes = np.full(pad, _PAD_PROBE, np.int64)
            eng_probes[:n_miss] = probes[miss]
            fm_host = level_hint = None
            if fstate is not None:
                # reuse the host screen's hashes for the dispatched keys —
                # all-True rows for filterless levels match the device
                # probe; pad lanes stay all-True (results are discarded)
                fm_host = np.ones((N_LEVELS, pad), bool)
                hint = [True] * N_LEVELS
                for row, li in enumerate(live_idx):
                    fm_host[li, :n_miss] = fmaybe_keep[row]
                    # no dispatched key can live at a level whose mask row
                    # is all-False — the engine drops it from the program
                    hint[li] = bool(fmaybe_keep[row].any())
                level_hint = tuple(hint)
            state = self.engine.build_state(self.tree, self.level_models)
            pending = self.engine.lookup_async(
                state, eng_probes, self._engine_mode(), self.vlog,
                l0_live=len(self.tree.levels[0]), fstate=fstate,
                fmaybe_host=fm_host, level_maybe=level_hint)
        return PendingBatch(probes, mt_found, mt_vptr,
                            miss, n_miss, pending)

    def resolve_get(self, pb: PendingBatch) -> tuple[np.ndarray, np.ndarray]:
        """Blocking half: materialize the device results, merge them under
        the memtable overlay, account the lookup, and tick the store."""
        if pb.resolved:
            raise RuntimeError("PendingBatch already resolved")
        pb.resolved = True
        found, vptr = pb.found, pb.vptr
        if pb.pending is not None:
            res = pb.pending.resolve()
            found[pb.miss] = res.found[:pb.n_miss]
            vptr[pb.miss] = res.vptr[:pb.n_miss]
            self._account_lookup(res)
        # a located tombstone (vptr -1) shadows older versions but the GET
        # reports not-found
        found &= vptr >= 0
        self.n_gets += pb.probes.shape[0]
        self.clock.advance(0.0)  # time added in _account_lookup
        self._tick()
        if self.cfg.fetch_values:
            t0 = self._vf.begin()
            vals = self._fetch_values(vptr)
            self._vf.end(t0)
            return found, vals
        return found, vptr

    def get_batch(self, probes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (found bool (B,), values (B, value_size) or vptrs)."""
        return self.resolve_get(self.dispatch_get(probes))

    def _account_lookup(self, res: LookupResult) -> None:
        """Attribute per-file internal lookups; advance virtual time by
        per-path costs (model path where the file had a model)."""
        c = self.cfg.costs
        us = 0.0
        for li in range(N_LEVELS):
            tables = self.tree.levels[li]
            pos_c, neg_c = res.pos_counts[li], res.neg_counts[li]
            for i, t in enumerate(tables):
                p = int(pos_c[i]) if i < pos_c.shape[0] else 0
                n = int(neg_c[i]) if i < neg_c.shape[0] else 0
                if p == 0 and n == 0:
                    continue
                t.stats.n_pos += p
                t.stats.n_neg += n
                has_model = (t.model is not None or
                             (self.cfg.granularity == "level" and
                              self.level_models[li] is not None))
                if has_model:
                    us += p * c.t_pm + n * c.t_nm
                    self.lookups_model_path += p + n
                else:
                    us += p * c.t_pb + n * c.t_nb
                    self.lookups_baseline_path += p + n
        self.foreground_us += us
        self.clock.advance(us)

    def range_query(self, start_keys: np.ndarray, length: int) -> np.ndarray:
        """Batched short scans: locate each start key (indexed path), then
        merge-scan `length` live items host-side.  Returns (B, length)
        keys, -1 padded.  Versions shadow by seq: a key whose newest
        flushed version is a tombstone is skipped, not emitted.  Scans the
        flushed tree only — flush before ranging over fresh writes."""
        start_keys = np.asarray(start_keys, np.int64)
        out = np.full((start_keys.shape[0], length), -1, np.int64)
        tables = list(self.tree.all_files())
        for bi, sk in enumerate(start_keys):
            heads = [[t, int(np.searchsorted(t.keys, sk))] for t in tables]
            heads = [h for h in heads if h[1] < h[0].n]
            cursor = int(sk)
            j = 0
            # k-way: repeatedly take the global min key >= cursor, then
            # let its newest version decide liveness
            while j < length and heads:
                best = None
                for h in heads:
                    t, idx = h
                    while idx < t.n and t.keys[idx] < cursor:
                        idx += 1
                    h[1] = idx
                    if idx < t.n:
                        v = int(t.keys[idx])
                        if best is None or v < best:
                            best = v
                heads = [h for h in heads if h[1] < h[0].n]
                if best is None:
                    break
                seq = -1
                vptr = -1
                for t, idx in heads:
                    if (t.keys[idx] == best and int(t.seqs[idx]) > seq):
                        seq = int(t.seqs[idx])
                        vptr = int(t.vptrs[idx])
                if vptr >= 0:               # tombstones shadow silently
                    out[bi, j] = best
                    j += 1
                cursor = best + 1
        return out

    # --------------------------------------------------------------- control
    def learn_all(self) -> int:
        """Synchronously learn every live file (or level) — used to set up
        read-only experiments and ``offline`` mode initial models."""
        self._check_writable()   # a closed store could not persist models
        n = 0
        n_file_models = 0
        if self.cfg.granularity == "level":
            from .plr import greedy_plr_np
            for i in range(1, N_LEVELS):
                if self.tree.levels[i]:
                    keys = np.concatenate([t.keys for t in self.tree.levels[i]])
                    self.level_models[i] = greedy_plr_np(
                        keys, delta=self.cfg.lsm.plr_delta)
                    self.level_models[i].epoch = \
                        self.executor.alloc_model_epoch()
                    self._level_model_versions[i] = self.tree.level_version[i]
                    n += 1
            # L0 cannot be level-learned (overlapping ranges) -> file models
            for t in self.tree.levels[0]:
                if t.model is None:
                    t.learn(self.cfg.lsm.plr_delta,
                            pad_to=self.cfg.engine.seg_cap)
                    n_file_models += 1
        else:
            for lvl in self.tree.levels:
                for t in lvl:
                    if t.model is None:
                        t.learn(self.cfg.lsm.plr_delta,
                                pad_to=self.cfg.engine.seg_cap)
                        n_file_models += 1
        n += n_file_models
        self.executor.files_learned += n_file_models
        if self._storage is not None:
            self._models_swept_at = self.executor.files_learned
            self._persist_new_models()
            self._sweep_level_models()
        return n

    def flush_all(self) -> None:
        """Flush memtable + settle compactions (load-phase end)."""
        self._check_writable()
        if len(self.memtable):
            self._flush()
        self._tick()

    # --------------------------------------------------------------- vlog GC
    def _host_get_vptrs(self, keys: np.ndarray) -> np.ndarray:
        """Authoritative host-side lookup: current vptr per key, -2 when the
        key is absent (tombstones return -1).  Newest seq wins across the
        memtable and every level — the liveness oracle for value-log GC."""
        n = keys.shape[0]
        best_vp = np.full(n, -2, np.int64)
        best_seq = np.full(n, -1, np.int64)
        mt_found, mt_vp = self.memtable.get_batch(keys)
        best_vp[mt_found] = mt_vp[mt_found]
        # memtable versions are strictly newer than anything flushed
        best_seq[mt_found] = np.iinfo(np.int64).max
        for t in self.tree.all_files():
            idx = np.searchsorted(t.keys, keys)
            idx_c = np.minimum(idx, t.n - 1)
            hit = t.keys[idx_c] == keys
            newer = hit & (t.seqs[idx_c] > best_seq)
            best_vp[newer] = t.vptrs[idx_c[newer]]
            best_seq[newer] = t.seqs[idx_c[newer]]
        return best_vp

    def gc_value_log(self, min_dead_ratio: float = 0.3,
                     max_segments: int | None = None,
                     segments: list[int] | None = None) -> dict:
        """WiscKey value-log GC (§2.2): scan sealed segments, relocate live
        entries to the head (updating their pointers through the LSM via a
        fresh-seq put), and delete segments whose dead ratio exceeds the
        threshold.  Returns reclamation stats.

        ``segments`` restricts the scan to an explicit candidate list (the
        MaintenanceScheduler passes the segments its dead-entry estimates
        deemed profitable, so the auto path never scans the whole log);
        liveness is still verified per entry before anything is dropped."""
        self._check_writable()
        if self._storage is None:
            raise RuntimeError("value-log GC requires a durable store "
                               "(BourbonStore.open(path))")
        removed: list[int] = []
        moved = 0
        reclaimed = 0
        scanned = 0
        # Liveness is checked in chunks of segments with one batched
        # full-LSM scan per chunk (a per-segment scan would make GC
        # quadratic in store size), and chunking keeps max_segments from
        # scanning the whole sealed log.  A chunk's snapshot stays valid
        # through its loop: a key's sealed entry only changes liveness when
        # its own segment is relocated, and relocated entries land in
        # unsealed head segments.
        if segments is None:
            sealed = self.vlog.sealed_segments()
        else:
            ok = set(self.vlog.sealed_segments())
            sealed = [s for s in segments if s in ok]
        chunk_size = 64
        done = False
        for start in range(0, len(sealed), chunk_size):
            if done:
                break
            seg_meta = []
            for seg in sealed[start: start + chunk_size]:
                ptrs, keys, _seqs, _ = self.vlog.read_segment(
                    seg, with_values=False)
                seg_meta.append((seg, ptrs, keys))
            cur = self._host_get_vptrs(
                np.concatenate([m[2] for m in seg_meta]))
            scanned += int(cur.shape[0])
            off = 0
            for seg, ptrs, keys in seg_meta:
                live = cur[off: off + ptrs.shape[0]] == ptrs
                off += ptrs.shape[0]
                if max_segments is not None and len(removed) >= max_segments:
                    done = True
                    break
                dead_ratio = (1.0 - float(live.mean())
                              if ptrs.shape[0] else 1.0)
                if dead_ratio < min_dead_ratio:
                    continue
                # victim re-read with payloads (page-cache warm from the
                # liveness pass)
                _p, _k, _s, values = self.vlog.read_segment(seg)
                lk, lv = keys[live], values[live]
                if lk.shape[0]:
                    new_seqs = np.arange(self._seq, self._seq + lk.shape[0],
                                         dtype=np.int64)
                    self._seq += lk.shape[0]
                    new_ptrs = self.vlog.append_kv(lk, new_seqs, lv)
                    self._ingest(lk, new_seqs, new_ptrs)
                    moved += lk.shape[0]
                # manifest edit BEFORE the unlink: a crash in between leaves
                # a removed-but-present file, which recovery cleans up; the
                # other order would leave a missing file the log references
                self._storage.persist_gc([seg], self._seq, self.clock.now,
                                         len(self.vlog),
                                         vdead=self.vlog.dead_delta())
                self.vlog.clear_dead_dirty()
                reclaimed += self.vlog.drop_segment(seg)
                self.cba.forget_segment(seg)
                removed.append(seg)
        # charge the collection to the virtual clock (background work,
        # same accounting discipline as learning)
        gc_us = (self.cfg.costs.gc_scan_per_entry * scanned
                 + self.cfg.costs.gc_move_per_entry * moved)
        self.cba.gc_us += gc_us
        self.clock.advance(gc_us)
        return {"segments_removed": len(removed),
                "bytes_reclaimed": reclaimed,
                "entries_moved": moved}

    def drain_learning(self, max_us: float = 1e12) -> int:
        """Advance virtual time until the learning queue is empty; returns
        the number of jobs drained.  Raises instead of giving up silently:
        a caller that proceeds with jobs still queued would silently
        benchmark the baseline path."""
        done0 = self.executor.jobs_done
        start = self.clock.now
        while self.executor.queue or self.executor.running:
            if self.executor.running:
                # event-driven: jump straight to the next job completion
                # (a fixed step would need ~duration/step iterations)
                nxt = min(finish for finish, _ in self.executor.running)
                step = max(nxt - self.clock.now, 0.0)
            else:
                step = 1000.0   # queued-only: let the next tick start them
            if (self.clock.now + step) - start > max_us:
                outstanding = (len(self.executor.queue)
                               + len(self.executor.running))
                raise RuntimeError(
                    f"drain_learning: {outstanding} jobs still outstanding; "
                    f"draining needs more than max_us={max_us:.0f} virtual "
                    f"us")
            self.clock.advance(step)
            self._tick()
        return self.executor.jobs_done - done0

    # -------------------------------------------------------------------- obs
    def attach_obs(self, obs, labels: dict | None = None) -> None:
        """Join an :class:`repro.obs.Obs` plane: register a snapshot-time
        collector (keyed on the labels, so a store reopening with the
        same labels replaces its stale predecessor instead of
        double-reporting), route maintenance/learning decisions into the
        event log, enable the engine's in-graph probe-split accumulator,
        and pre-bind the value-fetch stage handle.  Nothing here touches
        the read hot path beyond one extra async device add per batch."""
        self._obs = obs
        self._obs_labels = dict(labels or {})
        self._obs_events = obs.events
        self.executor.events = obs.events
        self.engine.record_probe_split = True
        self._vf = obs.tracer.stage("value_fetch")
        self._fp = obs.tracer.stage("filter_probe")
        if self._storage is not None:
            # traced writes span into the WAL: append -> commit-group
            # fsync becomes a causal fan-in in the span graph
            self._storage.set_tracer(obs.ctrace)
        key = ("store", tuple(sorted(self._obs_labels.items())))
        obs.registry.register_collector(key, self._collect_obs)

    def detach_obs(self) -> None:
        """Undo :meth:`attach_obs`: restore the null handles so the hot
        path records nothing, disable the probe-split accumulator, and
        drop this store's collector from the registry.  A later
        attach_obs (same or different plane) starts clean."""
        if self._obs is not None:
            self._obs.registry.unregister_collector(
                ("store", tuple(sorted(self._obs_labels.items()))))
        self._obs = None
        self._obs_labels = {}
        self._obs_events = None
        self.executor.events = None
        self.engine.record_probe_split = False
        self._vf = NULL_HANDLE
        self._fp = NULL_HANDLE
        if self._storage is not None:
            self._storage.set_tracer(NULL_CTRACE)

    def _collect_obs(self, reg) -> None:
        """Snapshot-time collector: curated monotonic counters (restart-
        safe across reopen via observe_total), per-level gauges, the
        lazily-materialized engine probe split, and the full ``stats()``
        dict flattened so no metric is lost in the migration."""
        lb = self._obs_labels
        c = reg.counter
        c("store_gets_total", **lb).observe_total(self.n_gets)
        c("store_puts_total", **lb).observe_total(self.n_puts)
        c("store_files_learned_total", **lb).observe_total(
            self.executor.files_learned)
        c("store_lookups_model_path_total", **lb).observe_total(
            self.lookups_model_path)
        c("store_lookups_baseline_path_total", **lb).observe_total(
            self.lookups_baseline_path)
        c("store_gc_us_total", **lb).observe_total(self.cba.gc_us)
        c("store_checkpoints_total", **lb).observe_total(self.cba.checkpoints)
        # per-level model-path vs baseline-path probe attribution: ONE
        # device->host sync for the whole accumulated history (satellite
        # of the lazy LookupResult pattern — the hot path never syncs)
        split = self.engine.probe_split_np()
        for li in range(N_LEVELS):
            c("engine_probes_total", level=str(li), path="model",
              **lb).observe_total(int(split[li, 0]))
            c("engine_probes_total", level=str(li), path="baseline",
              **lb).observe_total(int(split[li, 1]))
        # per-level filter pruning and false-positive attribution, same
        # lazy one-sync discipline as the probe split
        fsplit = self.engine.filter_stats_np()
        for li in range(N_LEVELS):
            c("engine_filter_pruned_total", level=str(li),
              **lb).observe_total(int(fsplit[li, 0]))
            c("engine_filter_fp_total", level=str(li),
              **lb).observe_total(int(fsplit[li, 1]))
        c("store_filter_screened_total", **lb).observe_total(
            self.filter_screened)
        c("store_filter_host_answered_total", **lb).observe_total(
            self.filter_host_answered)
        c("store_filter_builds_total", **lb).observe_total(self.filters_built)
        if self._storage is not None:
            ws = self._storage.wal_stats()
            c("store_wal_appends_total", **lb).observe_total(ws["appends"])
            c("store_wal_fsyncs_total", **lb).observe_total(ws["fsyncs"])
            c("store_wal_commits_total", **lb).observe_total(ws["commits"])
            h = reg.histogram("store_wal_group_batch", **lb)
            for n in self._storage.drain_wal_batch_sizes():
                h.observe(n)
        g = reg.gauge
        for li, tables in enumerate(self.tree.levels):
            g("store_level_files", level=str(li), **lb).set(len(tables))
            g("store_level_records", level=str(li), **lb).set(
                sum(t.n for t in tables))
            g("store_level_learned", level=str(li), **lb).set(
                sum(1 for t in tables if t.model is not None))
        publish_stats(reg, "store", self.stats(), lb)

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        files = list(self.tree.all_files())
        n_learned = sum(1 for t in files if t.model is not None)
        model_bytes = sum(t.model.nbytes for t in files if t.model is not None)
        # honest per-record width: whatever the key/seq/vptr arrays hold
        # (not a hardcoded 24), so space_overhead tracks format changes
        data_bytes = sum(
            t.n * (t.keys.dtype.itemsize + t.seqs.dtype.itemsize
                   + t.vptrs.dtype.itemsize) for t in files)
        segs = [int(t.model.n_segments) for t in files if t.model is not None]
        out = {
            "n_files": len(files),
            "n_records": self.tree.total_records(),
            "n_gets": self.n_gets,
            "n_puts": self.n_puts,
            "n_learned": n_learned,
            "model_bytes": model_bytes,
            "data_bytes": data_bytes,
            "space_overhead": model_bytes / max(data_bytes, 1),
            "avg_segments": float(np.mean(segs)) if segs else 0.0,
            "total_segments": int(np.sum(segs)) if segs else 0,
            "foreground_us": self.foreground_us,
            "learn_us": self.executor.learn_time_us,
            "compact_us": self.tree.compacted_records * self.cfg.costs.compact_per_key,
            "files_learned": self.executor.files_learned,
            "model_path_frac": self.lookups_model_path /
                max(self.lookups_model_path + self.lookups_baseline_path, 1),
            "level_attempts": self.executor.level_attempts,
            "level_failures": self.executor.level_failures,
            "cba_decisions": dict(self.cba.decisions),
            "filters_built": self.filters_built,
            "filter_screened": self.filter_screened,
            "filter_host_answered": self.filter_host_answered,
            "filter_screen_total": self.filter_screen_total,
            "filter_us": self.cba.filter_us,
            "filter_decisions": dict(self.cba.filter_decisions),
            "filter_bits": sum(f.n_words * 64 for f in self.level_filters
                               if f is not None),
        }
        if self._storage is not None:
            out.update(
                models_recovered=self.models_recovered,
                level_models_recovered=self.level_models_recovered,
                level_models_persisted=dict(self._lm_persisted),
                filters_recovered=self.filters_recovered,
                filters_persisted=dict(self._flt_persisted),
                vlog_disk_bytes=self.vlog.disk_bytes(),
                vlog_segments_removed=len(self.vlog.removed),
                vlog_dead_entries=self.vlog.dead_entries,
                gc_us=self.cba.gc_us,
                gc_decisions=dict(self.cba.gc_decisions),
                auto_gc=dict(self.auto_gc_stats),
                manifest_bytes=self._storage.manifest_bytes(),
                manifest_checkpoints=self.cba.checkpoints,
                checkpoint_overruns=self.cba.checkpoint_overruns,
                wal=self._storage.wal_stats(),
            )
        return out
