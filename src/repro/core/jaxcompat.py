"""Version-guarded access to JAX APIs that moved between releases.

The repo targets the modern spelling (``jax.make_mesh(axis_types=...)``,
``jax.shard_map``, ``jax.set_mesh``) but must also run on older installs
where meshes have no axis types, ``shard_map`` lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``), and there is no mesh context manager (the explicit
``mesh=`` argument to shard_map makes one unnecessary).
"""

from __future__ import annotations

import contextlib
import inspect

import jax

__all__ = ["make_mesh", "shard_map", "set_mesh"]


def _axis_types_kwargs(kind: str, n: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (getattr(axis_type, kind),) * n}


def make_mesh(axis_shapes, axis_names, *, axis_type: str = "Auto",
              devices=None):
    """``jax.make_mesh`` with ``axis_types`` when the install supports it.

    ``axis_type`` is the AxisType member name ("Auto" | "Explicit" |
    "Manual"), applied to every axis; ignored on JAX without typed meshes.
    Falls back through make_mesh-without-axis_types to a hand-built
    ``Mesh`` on installs predating ``jax.make_mesh`` itself.
    """
    mk = getattr(jax, "make_mesh", None)
    if mk is None:
        from jax.experimental import mesh_utils
        devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
        return jax.sharding.Mesh(devs, axis_names)
    kwargs = _axis_types_kwargs(axis_type, len(axis_names))
    if kwargs and "axis_types" not in inspect.signature(mk).parameters:
        kwargs = {}  # AxisType exists but make_mesh can't take it yet
    return mk(axis_shapes, axis_names, devices=devices, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    # the replication-check kwarg was renamed check_rep -> check_vma during
    # the experimental->top-level promotion; pick whichever this install has
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: check_vma})


def set_mesh(mesh):
    """Context manager binding ``mesh`` for explicit-sharding code paths.

    No-op on JAX without ``set_mesh``/``use_mesh`` — there shard_map's
    explicit ``mesh=`` argument already carries the binding.
    """
    ctx = getattr(jax, "set_mesh", None)
    if ctx is not None:
        return ctx(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return contextlib.nullcontext(mesh)
