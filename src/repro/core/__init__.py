"""Bourbon core: learned-index LSM tree (the paper's contribution)."""

from .clock import CostModel, VirtualClock
from .plr import PLRModel, greedy_plr_np, greedy_plr_jax, plr_predict_np
from .lsm import LSMConfig, LSMTree
from .engine import EngineConfig, LookupEngine
from .cba import (CBAConfig, CostBenefitAnalyzer, LearningExecutor,
                  MaintenanceConfig, MaintenanceScheduler)
from .store import StoreConfig, BourbonStore
from .datasets import make_dataset, DATASETS
from .workloads import WorkloadSpec, iter_workload, request_indices

__all__ = [
    "CostModel", "VirtualClock", "PLRModel", "greedy_plr_np", "greedy_plr_jax",
    "plr_predict_np", "LSMConfig", "LSMTree", "EngineConfig", "LookupEngine",
    "CBAConfig", "CostBenefitAnalyzer", "LearningExecutor",
    "MaintenanceConfig", "MaintenanceScheduler", "StoreConfig",
    "BourbonStore", "make_dataset", "DATASETS", "WorkloadSpec", "iter_workload",
    "request_indices",
]
