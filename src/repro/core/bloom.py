"""Per-sstable bloom filters, vectorized.

Build is host-side numpy (at flush/compaction time, like LevelDB's filter
block); probe is a pure-jnp batched function (the TPU data plane), mirrored by
the Pallas kernel in ``repro.kernels.bloom_probe``.

Hashing: double hashing h1 + i*h2 (Kirsch-Mitzenmacher) over 64-bit
Fibonacci-mixed keys — branch-free and gather-only, which is what the VPU
wants.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["bloom_build_np", "bloom_probe_np", "bloom_probe_hashed_np",
           "bloom_probe_ref",
           "bloom_words", "DEFAULT_BITS_PER_KEY"]

DEFAULT_BITS_PER_KEY = 10
_MIX1 = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xC2B2AE3D27D4EB4F)


def bloom_words(n_keys: int, bits_per_key: int = DEFAULT_BITS_PER_KEY) -> int:
    """Number of uint64 words for n_keys (rounded up, min 1)."""
    bits = max(64, n_keys * bits_per_key)
    return (bits + 63) // 64


def _hash2_np(keys: np.ndarray):
    k = keys.astype(np.uint64)
    h1 = (k * _MIX1)
    h1 ^= h1 >> np.uint64(29)
    h2 = (k * _MIX2) | np.uint64(1)
    h2 ^= h2 >> np.uint64(31)
    return h1, h2


def bloom_build_np(keys: np.ndarray, n_words: int, k_hashes: int = 7) -> np.ndarray:
    """Build packed filter bits (uint64 words) for the given keys."""
    bits = np.zeros(n_words, dtype=np.uint64)
    if keys.size == 0:
        return bits
    m = np.uint64(n_words * 64)
    h1, h2 = _hash2_np(keys)
    for i in range(k_hashes):
        pos = (h1 + np.uint64(i) * h2) % m
        np.bitwise_or.at(bits, (pos >> np.uint64(6)).astype(np.int64),
                         np.uint64(1) << (pos & np.uint64(63)))
    return bits


def bloom_probe_np(bits: np.ndarray, probes: np.ndarray, k_hashes: int = 7,
                   n_words: int | None = None) -> np.ndarray:
    """Host-side numpy probe of one (W,) filter — the store's pre-dispatch
    screen (no device work, no transfers).  Same math as bloom_probe_ref."""
    h1, h2 = _hash2_np(probes)
    return bloom_probe_hashed_np(bits, h1, h2, k_hashes, n_words)


def bloom_probe_hashed_np(bits: np.ndarray, h1: np.ndarray, h2: np.ndarray,
                          k_hashes: int = 7,
                          n_words: int | None = None) -> np.ndarray:
    """Probe with pre-mixed hashes: the double-hash bases are filter-
    independent, so a multi-level screen mixes the batch once and probes
    every level's filter with the same (h1, h2)."""
    if n_words is None:
        n_words = bits.shape[0]
    m = np.uint64(int(n_words) * 64)
    maybe = np.ones(h1.shape, bool)
    for i in range(k_hashes):
        pos = (h1 + np.uint64(i) * h2) % m
        word = bits[(pos >> np.uint64(6)).astype(np.int64)]
        maybe &= ((word >> (pos & np.uint64(63))) & np.uint64(1)).astype(bool)
    return maybe


def bloom_probe_ref(bits: jnp.ndarray, probes: jnp.ndarray, k_hashes: int = 7,
                    n_words=None) -> jnp.ndarray:
    """Pure-jnp batched probe.

    bits: (W,) shared filter, or (B, W) per-probe filter rows (padded).
    probes: (B,) int64 keys.
    n_words: live word count (scalar or (B,)) — the hash modulus must use the
    filter's *build-time* size, not the padded width.
    Returns bool (B,): True = maybe present.
    """
    if n_words is None:
        n_words = bits.shape[-1]
    m = (jnp.asarray(n_words).astype(jnp.uint64) * jnp.uint64(64))
    m = jnp.broadcast_to(m, probes.shape)
    kk = probes.astype(jnp.uint64)
    h1 = kk * jnp.uint64(0x9E3779B97F4A7C15)
    h1 = h1 ^ (h1 >> jnp.uint64(29))
    h2 = (kk * jnp.uint64(0xC2B2AE3D27D4EB4F)) | jnp.uint64(1)
    h2 = h2 ^ (h2 >> jnp.uint64(31))
    maybe = jnp.ones(probes.shape, bool)
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2) % m
        widx = (pos >> jnp.uint64(6)).astype(jnp.int32)
        if bits.ndim == 1:
            word = bits[widx]
        else:
            word = jnp.take_along_axis(bits, widx[..., None], axis=-1)[..., 0]
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    return maybe
