"""Synthetic + real-shaped key datasets (paper §5, Fig. 7).

All generators return sorted unique int64 keys < 2^53 (exactly representable
in the float64 PLR domain, mirroring the paper's 16B integer keys).
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_dataset", "DATASETS"]


def _unique_sorted(keys: np.ndarray, n: int, rng: np.random.Generator) -> np.ndarray:
    keys = np.unique(keys.astype(np.int64))
    while keys.shape[0] < n:  # top up collisions
        extra = rng.integers(0, 1 << 52, size=n, dtype=np.int64)
        keys = np.unique(np.concatenate([keys, extra]))
    return keys[:n]


def linear(n: int, rng) -> np.ndarray:
    """All keys consecutive (paper: best case, 1 segment)."""
    return np.arange(n, dtype=np.int64)


def segmented(n: int, gap_every: int, rng) -> np.ndarray:
    """Gap after every `gap_every` consecutive keys."""
    base = np.arange(n, dtype=np.int64)
    gaps = (base // gap_every) * 1000
    return base + gaps


def normal(n: int, rng) -> np.ndarray:
    """Sampled from N(0,1), scaled to integers (paper's construction)."""
    x = rng.standard_normal(n * 2)
    keys = (x * (1 << 40)).astype(np.int64) + (1 << 45)
    return _unique_sorted(keys, n, rng)


def lognormal_ar(n: int, rng) -> np.ndarray:
    """Amazon-reviews-like: heavy-tailed id space."""
    x = rng.lognormal(mean=0.0, sigma=2.0, size=n * 2)
    keys = (x * (1 << 30)).astype(np.int64)
    return _unique_sorted(keys, n, rng)


def osm_like(n: int, rng) -> np.ndarray:
    """OpenStreetMaps-like: clustered mixture (dense cities, sparse rest)."""
    n_clusters = max(8, n // 4096)
    centers = np.sort(rng.integers(0, 1 << 50, size=n_clusters, dtype=np.int64))
    sizes = rng.multinomial(n * 2, rng.dirichlet(np.ones(n_clusters) * 0.3))
    parts = [c + np.abs(rng.standard_normal(s) * 65536).astype(np.int64)
             for c, s in zip(centers, sizes) if s > 0]
    return _unique_sorted(np.concatenate(parts), n, rng)


def uniform_sparse(n: int, rng) -> np.ndarray:
    """SOSD uspr-like: uniform sparse 64-bit-ish."""
    return _unique_sorted(rng.integers(0, 1 << 52, size=n * 2, dtype=np.int64), n, rng)


def uniform_dense(n: int, rng) -> np.ndarray:
    """SOSD uden-like: dense with small random gaps."""
    gaps = rng.integers(1, 4, size=n, dtype=np.int64)
    return np.cumsum(gaps)


def facebook_like(n: int, rng) -> np.ndarray:
    """SOSD face-like: piecewise uniform with regime shifts."""
    n_seg = 64
    bounds = np.sort(rng.integers(0, 1 << 51, size=n_seg, dtype=np.int64))
    sizes = rng.multinomial(n * 2, np.ones(n_seg) / n_seg)
    parts = [rng.integers(b, b + (1 << 44), size=s, dtype=np.int64)
             for b, s in zip(bounds, sizes)]
    return _unique_sorted(np.concatenate(parts), n, rng)


DATASETS = {
    "linear": linear,
    "seg1%": lambda n, rng: segmented(n, 100, rng),
    "seg10%": lambda n, rng: segmented(n, 10, rng),
    "normal": normal,
    "ar": lognormal_ar,
    "osm": osm_like,
    # SOSD-like family (§5.5.2)
    "amzn": lognormal_ar,
    "face": facebook_like,
    "logn": lognormal_ar,
    "norm": normal,
    "uden": uniform_dense,
    "uspr": uniform_sparse,
}


def make_dataset(name: str, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    keys = DATASETS[name](n, rng)
    assert keys.shape[0] == n and np.all(np.diff(keys) > 0)
    return keys
