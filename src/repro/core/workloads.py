"""Request-distribution generators + YCSB-style workload mixes (§5.2.3, §5.5).

Distributions pick *indices into the loaded key set*; workloads yield batches
of (op, keys) with the paper's read/write mixes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["request_indices", "YCSB_MIXES", "WorkloadSpec", "iter_workload"]


def zipf_indices(rng, n_keys: int, size: int, theta: float = 0.99) -> np.ndarray:
    """YCSB-style scrambled zipfian over [0, n_keys)."""
    # inverse-CDF zipf over ranks, then scramble via multiplicative hash
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = 1.0 / ranks ** theta
    cdf = np.cumsum(w) / np.sum(w)
    u = rng.random(size)
    idx = np.searchsorted(cdf, u)
    scr = (idx.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)) % np.uint64(n_keys)
    return scr.astype(np.int64)


def request_indices(dist: str, rng: np.random.Generator, n_keys: int,
                    size: int, step: int = 0) -> np.ndarray:
    if dist == "uniform":
        return rng.integers(0, n_keys, size=size)
    if dist == "zipfian":
        return zipf_indices(rng, n_keys, size)
    if dist == "sequential":
        start = (step * size) % n_keys
        return (start + np.arange(size)) % n_keys
    if dist == "hotspot":  # 80% of requests to 20% of keys
        hot = rng.random(size) < 0.8
        lo = rng.integers(0, max(n_keys // 5, 1), size=size)
        hi = rng.integers(0, n_keys, size=size)
        return np.where(hot, lo, hi)
    if dist == "exponential":
        x = rng.exponential(scale=n_keys / 8.0, size=size).astype(np.int64)
        return np.clip(x, 0, n_keys - 1)
    if dist == "latest":  # skewed towards recently inserted (highest index)
        x = n_keys - 1 - rng.exponential(scale=n_keys / 8.0, size=size).astype(np.int64)
        return np.clip(x, 0, n_keys - 1)
    raise ValueError(dist)


# YCSB core workload mixes (§5.5.1)
YCSB_MIXES = {
    "A": dict(read=0.5, update=0.5, scan=0.0, insert=0.0, dist="zipfian"),
    "B": dict(read=0.95, update=0.05, scan=0.0, insert=0.0, dist="zipfian"),
    "C": dict(read=1.0, update=0.0, scan=0.0, insert=0.0, dist="zipfian"),
    "D": dict(read=0.95, update=0.0, scan=0.0, insert=0.05, dist="latest"),
    "E": dict(read=0.0, update=0.0, scan=0.95, insert=0.05, dist="zipfian"),
    "F": dict(read=0.5, update=0.5, scan=0.0, insert=0.0, dist="zipfian"),  # RMW
}


@dataclasses.dataclass
class WorkloadSpec:
    n_ops: int
    batch: int = 4096
    read_frac: float = 1.0
    scan_frac: float = 0.0
    insert_frac: float = 0.0
    dist: str = "uniform"
    scan_len: int = 50
    seed: int = 1

    @classmethod
    def ycsb(cls, name: str, n_ops: int, batch: int = 4096, seed: int = 1):
        m = YCSB_MIXES[name]
        return cls(n_ops=n_ops, batch=batch, read_frac=m["read"],
                   scan_frac=m["scan"], insert_frac=m["insert"],
                   dist=m["dist"], seed=seed)


def iter_workload(spec: WorkloadSpec, keys: np.ndarray):
    """Yields (op, key_batch) where op in {get, put, scan}.

    Updates re-insert existing keys; inserts add fresh keys past the max.
    """
    rng = np.random.default_rng(spec.seed)
    n_keys = keys.shape[0]
    next_new = int(keys[-1]) + 1
    done = 0
    step = 0
    while done < spec.n_ops:
        b = min(spec.batch, spec.n_ops - done)
        u = rng.random()
        if u < spec.read_frac:
            idx = request_indices(spec.dist, rng, n_keys, b, step)
            yield "get", keys[idx]
        elif u < spec.read_frac + spec.scan_frac:
            idx = request_indices(spec.dist, rng, n_keys, max(b // spec.scan_len, 1), step)
            yield "scan", keys[idx]
        elif u < spec.read_frac + spec.scan_frac + spec.insert_frac:
            fresh = np.arange(next_new, next_new + b, dtype=np.int64)
            next_new += b
            yield "put", fresh
        else:  # update = write existing key
            idx = request_indices(spec.dist, rng, n_keys, b, step)
            yield "put", keys[idx]
        done += b
        step += 1
