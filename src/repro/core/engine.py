"""Batched lookup data plane (the TPU-native reformulation of Bourbon's
read path).

The host LSM (lsm.py) is stacked into padded per-level device arrays; a
lookup batch of B probe keys is then one tensor program implementing the
paper's steps (Fig. 1 / Fig. 6):

  baseline path:  FindFiles -> SearchIB (fence binsearch) -> SearchFB (bloom)
                  -> SearchDB (in-block binsearch) -> ReadValue
  model path:     FindFiles -> ModelLookup (PLR segment binsearch + FMA)
                  -> SearchFB -> LoadChunk+LocateKey (delta-window probe)
                  -> ReadValue

All steps are branch-free vectorized gathers (pure jnp here; the Pallas
kernels in repro.kernels implement the same contracts for TPU).  Per-level
positive/negative internal-lookup *counts* are computed in-graph and returned
as tiny vectors for the cost-benefit analyzer.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bloom import bloom_probe_ref
from .lsm import LSMTree, N_LEVELS
from .sstable import BLOCK_RECORDS

__all__ = ["EngineConfig", "DeviceLevel", "DeviceState", "FilterState",
           "LookupEngine", "LookupResult", "PendingLookup", "binsearch_rows"]

KEY_SENTINEL = np.iinfo(np.int64).max


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


# ----------------------------------------------------------------------------
# pytrees
# ----------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceLevel:
    keys: jnp.ndarray        # (F, C) int64, padded KEY_SENTINEL
    vptrs: jnp.ndarray       # (F, C) int64
    n: jnp.ndarray           # (F,) int32 live records per file
    fences: jnp.ndarray      # (F, NB) int64 padded KEY_SENTINEL
    n_blocks: jnp.ndarray    # (F,) int32
    bloom: jnp.ndarray       # (F, W) uint64
    bloom_nw: jnp.ndarray    # (F,) int32 live filter words (hash modulus)
    min_key: jnp.ndarray     # (F,) int64 (SENTINEL when slot empty)
    max_key: jnp.ndarray     # (F,) int64 (SENTINEL when slot empty)
    starts: jnp.ndarray      # (F, S) f64 PLR segment starts (+inf pad)
    slopes: jnp.ndarray      # (F, S) f64
    icepts: jnp.ndarray      # (F, S) f64
    nseg: jnp.ndarray        # (F,) int32 (0 = no model)
    n_files: jnp.ndarray     # () int32

    def tree_flatten(self):
        # NOT dataclasses.astuple: astuple deep-copies every leaf, and
        # flatten runs on every jitted dispatch — the copy dominated the
        # host-side cost of small-batch lookups
        return (self.keys, self.vptrs, self.n, self.fences, self.n_blocks,
                self.bloom, self.bloom_nw, self.min_key, self.max_key,
                self.starts, self.slopes, self.icepts, self.nseg,
                self.n_files), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LevelModel:
    """Level-granularity PLR (§4.3): key -> global index in the level."""
    starts: jnp.ndarray      # (S,) f64
    slopes: jnp.ndarray      # (S,) f64
    icepts: jnp.ndarray      # (S,) f64
    nseg: jnp.ndarray        # () int32 (0 = no model)
    file_start: jnp.ndarray  # (F,) int64 global index of each file's first key

    def tree_flatten(self):
        return (self.starts, self.slopes, self.icepts, self.nseg,
                self.file_start), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceState:
    levels: tuple            # N_LEVELS DeviceLevel
    level_models: tuple      # N_LEVELS (LevelModel | None -> encoded w/ nseg=0)

    def tree_flatten(self):
        return (self.levels, self.level_models), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FilterState:
    """The filter plane: per-level bloom filters stacked to a padded (L, W)
    device array, probed by one batched kernel call ahead of the descent."""
    bits: jnp.ndarray        # (N_LEVELS, W) uint64, width-padded
    nw: jnp.ndarray          # (N_LEVELS,) int32 build-time words; 0 = none
    has: jnp.ndarray         # (N_LEVELS,) bool — nw > 0, precomputed

    def tree_flatten(self):
        return (self.bits, self.nw, self.has), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class LookupResult:
    """Materialized lookup answers.

    ``found`` / ``vptr`` / ``served_level`` are host arrays (the caller
    asked for them by resolving).  The per-level CBA counter vectors stay
    on device until first touched: callers that only want values (the
    serving hot path) never pay the extra device->host transfer, while
    the stats path (`BourbonStore._account_lookup`) materializes them
    once, lazily, on access.

    ``n_materializations`` is a class-wide count of device->host counter
    transfers — the observability regression tests assert that attaching
    the metrics plane adds zero of these per batch."""

    n_materializations = 0

    def __init__(self, found, vptr, served_level, pos_counts, neg_counts,
                 values=None):
        self.found = found                 # (B,) bool
        self.vptr = vptr                   # (B,) int64
        self.served_level = served_level   # (B,) int8, -1 = miss everywhere
        self._pos_dev = pos_counts         # per level (F,) device int32
        self._neg_dev = neg_counts
        self._pos_np: list | None = None
        self._neg_np: list | None = None
        self.values = values

    @property
    def pos_counts(self) -> list:
        if self._pos_np is None:
            LookupResult.n_materializations += 1
            self._pos_np = [np.asarray(p) for p in self._pos_dev]
        return self._pos_np

    @property
    def neg_counts(self) -> list:
        if self._neg_np is None:
            LookupResult.n_materializations += 1
            self._neg_np = [np.asarray(n) for n in self._neg_dev]
        return self._neg_np


@dataclasses.dataclass
class PendingLookup:
    """The dispatch half of a lookup: every field is a device array still
    being computed (JAX async dispatch).  Nothing here blocks the host —
    `resolve()` is the synchronization point, so a caller can dispatch
    batch N+1 (admission, cache probing, memtable overlay) while the
    device works on batch N."""
    found: jnp.ndarray       # (B,) bool, device
    vptr: jnp.ndarray        # (B,) int64, device
    served: jnp.ndarray      # (B,) int8, device
    pos_counts: tuple        # per level (F,) int32, device
    neg_counts: tuple
    values: jnp.ndarray | None = None

    def resolve(self) -> LookupResult:
        """Block on the device results and hand back host arrays (counter
        vectors stay lazy — see LookupResult)."""
        return LookupResult(np.asarray(self.found), np.asarray(self.vptr),
                            np.asarray(self.served),
                            self.pos_counts, self.neg_counts,
                            None if self.values is None
                            else np.asarray(self.values))


# ----------------------------------------------------------------------------
# vectorized primitives
# ----------------------------------------------------------------------------

def binsearch_rows(mat: jnp.ndarray, rows: jnp.ndarray, probes: jnp.ndarray,
                   lo: jnp.ndarray, hi: jnp.ndarray, side: str = "left") -> jnp.ndarray:
    """Batched bisect over rows of a (F, C) matrix.

    Returns per-probe insertion index within [lo, hi).  log2(C) gather steps —
    the jnp oracle for kernels/sstable_search.
    """
    C = mat.shape[-1]
    steps = max(1, math.ceil(math.log2(C + 1)))
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kv = mat[rows, jnp.clip(mid, 0, C - 1)]
        go_right = (kv < probes) if side == "left" else (kv <= probes)
        lo2 = jnp.where(go_right, mid + 1, lo)
        hi2 = jnp.where(go_right, hi, mid)
        return jnp.where(active, lo2, lo), jnp.where(active, hi2, hi)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def count_le_rows(mat: jnp.ndarray, rows: jnp.ndarray, probes: jnp.ndarray,
                  side: str = "right") -> jnp.ndarray:
    """Broadcast compare-count over gathered rows: #entries {<, <=} probe.
    One (B, W) gather + one vectorized compare + one reduce — the VPU-native
    replacement for a serial bisect when W is small (fences, PLR segments,
    data blocks)."""
    rowvals = mat[rows]                      # (B, W)
    p = probes[:, None].astype(rowvals.dtype)
    cmp = (rowvals <= p) if side == "right" else (rowvals < p)
    return jnp.sum(cmp, axis=-1).astype(jnp.int32)


def bloom_probe_rows(bits: jnp.ndarray, nwords: jnp.ndarray, rows: jnp.ndarray,
                     probes: jnp.ndarray, k_hashes: int) -> jnp.ndarray:
    """Row-indexed bloom probe: bits (F, W), nwords (F,), rows (B,).

    Gathers only the k addressed words per probe (never whole filter rows —
    that would move B*W bytes per call)."""
    m = nwords[rows].astype(jnp.uint64) * jnp.uint64(64)
    kk = probes.astype(jnp.uint64)
    h1 = kk * jnp.uint64(0x9E3779B97F4A7C15)
    h1 = h1 ^ (h1 >> jnp.uint64(29))
    h2 = (kk * jnp.uint64(0xC2B2AE3D27D4EB4F)) | jnp.uint64(1)
    h2 = h2 ^ (h2 >> jnp.uint64(31))
    maybe = jnp.ones(probes.shape, bool)
    W = bits.shape[-1]
    for i in range(k_hashes):
        pos = (h1 + jnp.uint64(i) * h2) % m
        widx = jnp.clip((pos >> jnp.uint64(6)).astype(jnp.int32), 0, W - 1)
        word = bits[rows, widx]
        bit = (word >> (pos & jnp.uint64(63))) & jnp.uint64(1)
        maybe = maybe & (bit == jnp.uint64(1))
    return maybe


# ----------------------------------------------------------------------------
# engine
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    plr_delta: int = 8
    bloom_k: int = 7
    block_records: int = BLOCK_RECORDS
    seg_cap: int = 4096          # max PLR segments per file
    level_seg_cap: int = 65536   # max PLR segments per level model
    fetch_values: bool = False
    filter_impl: str = "ref"     # filter-plane probe kernel impl (ops._mode)


class LookupEngine:
    """Builds device state from the host tree and runs jitted lookups."""

    def __init__(self, cfg: EngineConfig) -> None:
        self.cfg = cfg
        self._state_cache: dict[int, DeviceLevel] = {}
        self._state_versions: list[int] = [-1] * N_LEVELS
        self._lm_versions: list = [-1] * N_LEVELS
        self._lm_cache: dict[int, LevelModel] = {}
        self._jit_cache: dict = {}
        # traces of _lookup_impl actually taken (incremented at trace
        # time): a fresh DeviceState with unchanged geometry must reuse
        # the cached program — regression-tested, since a silent retrace
        # per epoch would swamp the lookups it serves
        self.trace_count = 0
        # stamp for level models that arrive without an epoch: unique,
        # decreasing, never reused — store-fit models carry epochs >= 0
        self._unstamped_epoch = -2
        # per-level (model_probes, baseline_probes) attribution, computed
        # in-graph and accumulated as a single (N_LEVELS, 2) device add
        # per dispatched batch — never synced to the host until
        # probe_split_np() (the obs snapshot path) asks.  Off by default:
        # BourbonStore.attach_obs flips it on
        self.record_probe_split = False
        self.probe_split_acc = None
        self.probe_acc_materializations = 0   # host syncs of the acc
        # filter plane: stacked (L, W) device filters, cached by the
        # per-level filter epochs (same discipline as the lm cache); the
        # (L, 2) [pruned, false-positive] counters accumulate in-graph
        self._filter_cache: tuple | None = None
        self.filter_stats_acc = None
        self.filter_acc_materializations = 0  # host syncs of the filter acc

    # ---------------------------------------------------------------- build
    def _build_level(self, tables, cfg: EngineConfig) -> DeviceLevel:
        F = max(2, _next_pow2(len(tables) + 1))
        C = max(cfg.block_records,
                _next_pow2(max((t.n for t in tables), default=1)))
        NB = max(1, C // cfg.block_records)
        W = max(1, _next_pow2(max((t.bloom.shape[0] for t in tables), default=1)))
        # size the segment arrays to the live maximum: the bisect step count
        # is log2(S), so padding to cfg.seg_cap would burn gather steps
        live_ns = [int(t.model.n_segments) for t in tables
                   if t.model is not None]
        S = max(16, _next_pow2(max(live_ns, default=1)))
        keys = np.full((F, C), KEY_SENTINEL, np.int64)
        vptrs = np.full((F, C), -1, np.int64)
        n = np.zeros(F, np.int32)
        fences = np.full((F, NB), KEY_SENTINEL, np.int64)
        n_blocks = np.zeros(F, np.int32)
        bloom = np.zeros((F, W), np.uint64)
        bloom_nw = np.ones(F, np.int32)
        min_key = np.full(F, KEY_SENTINEL, np.int64)
        max_key = np.full(F, KEY_SENTINEL, np.int64)
        starts = np.full((F, S), np.inf, np.float64)
        slopes = np.zeros((F, S), np.float64)
        icepts = np.zeros((F, S), np.float64)
        nseg = np.zeros(F, np.int32)
        for i, t in enumerate(tables):
            keys[i, : t.n] = t.keys
            vptrs[i, : t.n] = t.vptrs
            n[i] = t.n
            fences[i, : t.fences.shape[0]] = t.fences
            n_blocks[i] = t.fences.shape[0]
            bloom[i, : t.bloom.shape[0]] = t.bloom
            bloom_nw[i] = t.bloom.shape[0]
            min_key[i] = t.min_key
            max_key[i] = t.max_key
            if t.model is not None:
                ns = int(t.model.n_segments)
                if ns > S:
                    raise ValueError(f"file model has {ns} segments > cap {S}")
                starts[i, :ns] = np.asarray(t.model.starts)[:ns]
                slopes[i, :ns] = np.asarray(t.model.slopes)[:ns]
                icepts[i, :ns] = np.asarray(t.model.intercepts)[:ns]
                nseg[i] = ns
        dev = jax.device_put
        return DeviceLevel(dev(keys), dev(vptrs), dev(n), dev(fences),
                           dev(n_blocks), dev(bloom), dev(bloom_nw),
                           dev(min_key), dev(max_key),
                           dev(starts), dev(slopes), dev(icepts), dev(nseg),
                           jnp.asarray(len(tables), jnp.int32))

    def _build_level_model(self, tree: LSMTree, level: int, model) -> LevelModel:
        tables = tree.levels[level]
        F = max(2, _next_pow2(len(tables) + 1))
        file_start = np.zeros(F, np.int64)
        acc = 0
        for i, t in enumerate(tables):
            file_start[i] = acc
            acc += t.n
        S = self.cfg.level_seg_cap
        starts = np.full(S, np.inf, np.float64)
        slopes = np.zeros(S, np.float64)
        icepts = np.zeros(S, np.float64)
        ns = 0
        if model is not None:
            ns = int(model.n_segments)
            starts[:ns] = np.asarray(model.starts)[:ns]
            slopes[:ns] = np.asarray(model.slopes)[:ns]
            icepts[:ns] = np.asarray(model.intercepts)[:ns]
        dev = jax.device_put
        return LevelModel(dev(starts), dev(slopes), dev(icepts),
                          jnp.asarray(ns, jnp.int32), dev(file_start))

    def build_state(self, tree: LSMTree, level_models=None) -> DeviceState:
        """Stack host tree to device, reusing unchanged levels (dirty tracking)."""
        levels = []
        lms = []
        level_models = level_models or [None] * N_LEVELS
        for i in range(N_LEVELS):
            ver = tree.level_version[i]
            # cache key = (level version, model epoch): id() is unsafe here
            # (the allocator reuses addresses after GC, which can serve a
            # stale LevelModel for a same-version level); the epoch is
            # monotonic per store and persisted, so it also survives reopen
            lm = level_models[i]
            if lm is not None and getattr(lm, "epoch", -1) == -1:
                lm.epoch = self._unstamped_epoch
                self._unstamped_epoch -= 1
            mver = (ver, None if lm is None else lm.epoch)
            if self._state_versions[i] != ver or i not in self._state_cache:
                self._state_cache[i] = self._build_level(tree.levels[i], self.cfg)
                self._state_versions[i] = ver
            if self._lm_versions[i] != mver or i not in self._lm_cache:
                self._lm_cache[i] = self._build_level_model(tree, i, level_models[i])
                self._lm_versions[i] = mver
            levels.append(self._state_cache[i])
            lms.append(self._lm_cache[i])
        return DeviceState(tuple(levels), tuple(lms))

    def build_filter_state(self, level_filters) -> FilterState:
        """Stack per-level host filters (core.filters.LevelFilter | None) to
        one padded (N_LEVELS, W) device array, reused while no filter epoch
        changed.  A level without a filter gets nw = 0 (probe yields
        all-True there — never prune without evidence)."""
        key = []
        for f in level_filters:
            if f is None:
                key.append(None)
                continue
            if f.epoch == -1:
                f.epoch = self._unstamped_epoch
                self._unstamped_epoch -= 1
            key.append((f.epoch, f.n_words))
        sig = tuple(key)
        if self._filter_cache is not None and self._filter_cache[0] == sig:
            return self._filter_cache[1]
        L = len(level_filters)
        W = max(1, _next_pow2(max((f.n_words for f in level_filters
                                   if f is not None), default=1)))
        bits = np.zeros((L, W), np.uint64)
        nw = np.zeros(L, np.int32)
        for i, f in enumerate(level_filters):
            if f is not None:
                bits[i, : f.n_words] = f.bits
                nw[i] = f.n_words
        fs = FilterState(jax.device_put(bits), jax.device_put(nw),
                         jax.device_put(nw > 0))
        self._filter_cache = (sig, fs)
        return fs

    def filter_probe(self, fstate: FilterState, probes: jnp.ndarray):
        """One batched filter-plane probe for the whole batch: (L, B) bool
        maybe-mask ahead of the descent (SearchFB hoisted in front of
        FindFiles).  Dispatches async like the lookup itself."""
        from repro.kernels.ops import bloom_probe_stack
        key = ("fprobe", probes.shape[0], fstate.bits.shape,
               self.cfg.filter_impl)
        if key not in self._jit_cache:
            k, impl = self.cfg.bloom_k, self.cfg.filter_impl
            self._jit_cache[key] = jax.jit(
                lambda bits, nw, p: bloom_probe_stack(bits, nw, p,
                                                      k_hashes=k, impl=impl))
        return self._jit_cache[key](fstate.bits, fstate.nw, probes)

    # ---------------------------------------------------------------- probes
    def _probe_file_baseline(self, lv: DeviceLevel, f, probes):
        cfg = self.cfg
        # SearchIB: fence compare-count -> block id (bisect_right - 1).
        # Fences padded with KEY_SENTINEL never count.
        blk = jnp.maximum(count_le_rows(lv.fences, f, probes) - 1, 0)
        # SearchFB: bloom
        maybe = bloom_probe_rows(lv.bloom, lv.bloom_nw, f, probes, cfg.bloom_k)
        # SearchDB: gather the data block (the "LoadDB" bytes), locate inside
        C = lv.keys.shape[-1]
        base = blk * cfg.block_records
        cols = jnp.clip(base[:, None]
                        + jnp.arange(cfg.block_records, dtype=jnp.int32)[None],
                        0, C - 1)
        block = lv.keys[f[:, None], cols]                 # (B, block)
        within = jnp.sum(block < probes[:, None], axis=-1).astype(jnp.int32)
        idx = base + within
        kv = lv.keys[f, jnp.clip(idx, 0, C - 1)]
        hit = maybe & (idx < lv.n[f]) & (kv == probes)
        vptr = jnp.where(hit, lv.vptrs[f, jnp.clip(idx, 0, C - 1)], -1)
        return hit, vptr

    def _probe_file_model(self, lv: DeviceLevel, f, probes):
        cfg = self.cfg
        d = cfg.plr_delta
        # ModelLookup: segment compare-count (+inf pads never count) + FMA;
        # falls back to bisect only when the segment table is wide
        S = lv.starts.shape[-1]
        if S <= 1024:
            seg = count_le_rows(lv.starts, f, probes.astype(jnp.float64)) - 1
        else:
            seg = binsearch_rows(lv.starts, f, probes.astype(jnp.float64),
                                 jnp.zeros_like(f, jnp.int32),
                                 jnp.maximum(lv.nseg[f], 1), side="right") - 1
        seg = jnp.maximum(seg, 0)
        pos = lv.slopes[f, seg] * probes.astype(jnp.float64) + lv.icepts[f, seg]
        pos = jnp.clip(jnp.round(pos).astype(jnp.int32), 0,
                       jnp.maximum(lv.n[f] - 1, 0))
        # SearchFB
        maybe = bloom_probe_rows(lv.bloom, lv.bloom_nw, f, probes, cfg.bloom_k)
        # LoadChunk + LocateKey: delta-window gather + compare
        offs = jnp.arange(-(d + 1), d + 2, dtype=jnp.int32)   # rounding slack
        C = lv.keys.shape[-1]
        win_idx = jnp.clip(pos[:, None] + offs[None, :], 0, C - 1)
        win = lv.keys[f[:, None], win_idx]                    # (B, 2d+3)
        eq = win == probes[:, None]
        hit_in = jnp.any(eq, axis=-1)
        rel = jnp.argmax(eq, axis=-1)
        idx = win_idx[jnp.arange(probes.shape[0]), rel]
        hit = maybe & hit_in & (idx < lv.n[f])
        vptr = jnp.where(hit, lv.vptrs[f, idx], -1)
        return hit, vptr

    def _probe_level_via_model(self, lv: DeviceLevel, lm: LevelModel, probes):
        """Level-model path: PLR gives a global index -> (file, local idx)."""
        cfg = self.cfg
        d = cfg.plr_delta
        B = probes.shape[0]
        zeros = jnp.zeros((B,), jnp.int32)
        seg = binsearch_rows(lm.starts[None, :], zeros,
                             probes.astype(jnp.float64), zeros,
                             jnp.broadcast_to(jnp.maximum(lm.nseg, 1), (B,)),
                             side="right") - 1
        seg = jnp.maximum(seg, 0)
        gpos = lm.slopes[seg] * probes.astype(jnp.float64) + lm.icepts[seg]
        total = jnp.sum(lv.n.astype(jnp.int64))
        gpos = jnp.clip(jnp.round(gpos).astype(jnp.int64), 0,
                        jnp.maximum(total - 1, 0))
        offs = jnp.arange(-(d + 1), d + 2, dtype=jnp.int64)
        gidx = jnp.clip(gpos[:, None] + offs[None, :], 0,
                        jnp.maximum(total - 1, 0))        # (B, 2d+3) global
        Fdim = lm.file_start.shape[0]
        nf = lv.n_files
        # global -> (file, local): file = bisect_right(file_start, g) - 1
        flat_g = gidx.reshape(-1)
        zf = jnp.zeros_like(flat_g, jnp.int32)
        fidx = binsearch_rows(lm.file_start[None, :], zf,
                              flat_g, zf,
                              jnp.broadcast_to(nf, flat_g.shape),
                              side="right") - 1
        fidx = jnp.clip(fidx, 0, Fdim - 1)
        local = flat_g - lm.file_start[fidx]
        C = lv.keys.shape[-1]
        local = jnp.clip(local, 0, C - 1).astype(jnp.int32)
        win = lv.keys[fidx, local].reshape(B, -1)
        eq = win == probes[:, None]
        hit_in = jnp.any(eq, axis=-1)
        rel = jnp.argmax(eq, axis=-1)
        sel = jnp.arange(B) * win.shape[1] + rel
        f_sel = fidx[sel]
        l_sel = local[sel]
        maybe = bloom_probe_rows(lv.bloom, lv.bloom_nw, f_sel, probes, cfg.bloom_k)
        hit = maybe & hit_in
        vptr = jnp.where(hit, lv.vptrs[f_sel, l_sel], -1)
        return hit, vptr, f_sel

    def _find_file(self, lv: DeviceLevel, probes):
        """FindFiles for a sorted level: candidate = first file with
        max_key >= probe; valid if min_key <= probe."""
        B = probes.shape[0]
        zeros = jnp.zeros((B,), jnp.int32)
        nf = jnp.broadcast_to(lv.n_files, (B,))
        f = binsearch_rows(lv.max_key[None, :], zeros, probes, zeros, nf,
                           side="left")
        Fdim = lv.max_key.shape[0]
        f_c = jnp.clip(f, 0, Fdim - 1)
        valid = (f < lv.n_files) & (lv.min_key[f_c] <= probes)
        return f_c, valid

    # ---------------------------------------------------------------- lookup
    def _lookup_impl(self, state: DeviceState, probes, mode: str,
                     l0_slots: tuple, live_levels: tuple = (True,) * N_LEVELS,
                     fmaybe=None, fhas=None, use_filters: bool = False):
        # l0_slots / live_levels / use_filters — static per jit
        # specialization; empty levels are skipped entirely (no dead
        # gathers).  fmaybe: (N_LEVELS, B) filter-plane maybe-mask; fhas:
        # (N_LEVELS,) which levels carry a real filter (for FP accounting).
        """mode: 'baseline' | 'model' | 'mixed' | 'level'."""
        self.trace_count += 1   # python side effect: runs only at trace
        B = probes.shape[0]
        found = jnp.zeros(B, bool)
        vptr = jnp.full(B, -1, jnp.int64)
        served = jnp.full(B, -1, jnp.int8)
        pos_counts, neg_counts = [], []
        prn_l, fp_l = [], []     # per-level pruned / false-positive probes

        def probe_one(lv, f, probes):
            if mode == "baseline":
                return self._probe_file_baseline(lv, f, probes)
            if mode == "model_pure":
                # every live file is learned: skip the baseline arm entirely
                return self._probe_file_model(lv, f, probes)
            hit_m, v_m = self._probe_file_model(lv, f, probes)
            has = lv.nseg[f] > 0
            hit_b, v_b = self._probe_file_baseline(lv, f, probes)
            return jnp.where(has, hit_m, hit_b), jnp.where(has, v_m, v_b)

        for li in range(N_LEVELS):
            lv = state.levels[li]
            Fdim = lv.max_key.shape[0]
            pos_c = jnp.zeros(Fdim, jnp.int32)
            neg_c = jnp.zeros(Fdim, jnp.int32)
            prn = jnp.zeros((), jnp.int64)
            fpc = jnp.zeros((), jnp.int64)
            if not live_levels[li]:
                pos_counts.append(pos_c)
                neg_counts.append(neg_c)
                prn_l.append(prn)
                fp_l.append(fpc)
                continue
            if li == 0:
                # probe each L0 slot newest-first; unrolled over static slots
                for s in range(l0_slots[0]):
                    f = jnp.full(B, s, jnp.int32)
                    in_range = ((lv.min_key[s] <= probes) &
                                (probes <= lv.max_key[s]) &
                                (s < lv.n_files))
                    active = ~found & in_range
                    if use_filters:
                        # the L0 filter row covers the union of all L0
                        # tables: a screened key skips every slot's probe
                        prn = prn + jnp.sum(active & ~fmaybe[0],
                                            dtype=jnp.int64)
                        active = active & fmaybe[0]
                    hit, v = probe_one(lv, f, probes)
                    hit = hit & active
                    if use_filters:
                        fpc = fpc + jnp.where(
                            fhas[0],
                            jnp.sum(active & ~hit, dtype=jnp.int64),
                            jnp.int64(0))
                    pos_c = pos_c.at[s].add(jnp.sum(hit, dtype=jnp.int32))
                    neg_c = neg_c.at[s].add(
                        jnp.sum(active & ~hit, dtype=jnp.int32))
                    vptr = jnp.where(hit, v, vptr)
                    served = jnp.where(hit, jnp.int8(0), served)
                    found = found | hit
            else:
                if mode == "level":
                    lm = state.level_models[li]
                    use_lm = lm.nseg > 0
                    f_cand, valid = self._find_file(lv, probes)
                    active = ~found & valid
                    if use_filters:
                        prn = prn + jnp.sum(active & ~fmaybe[li],
                                            dtype=jnp.int64)
                        active = active & fmaybe[li]
                    hit_lm, v_lm, f_lm = self._probe_level_via_model(
                        lv, lm, probes)
                    hit_b, v_b = self._probe_file_baseline(lv, f_cand, probes)
                    hit = jnp.where(use_lm, hit_lm, hit_b) & active
                    v = jnp.where(use_lm, v_lm, v_b)
                    fattr = jnp.where(use_lm, f_lm, f_cand)
                else:
                    f_cand, valid = self._find_file(lv, probes)
                    active = ~found & valid
                    if use_filters:
                        prn = prn + jnp.sum(active & ~fmaybe[li],
                                            dtype=jnp.int64)
                        active = active & fmaybe[li]
                    hit, v = probe_one(lv, f_cand, probes)
                    hit = hit & active
                    fattr = f_cand
                if use_filters:
                    fpc = fpc + jnp.where(
                        fhas[li],
                        jnp.sum(active & ~hit, dtype=jnp.int64),
                        jnp.int64(0))
                pos_c = pos_c + jax.ops.segment_sum(
                    hit.astype(jnp.int32), fattr, num_segments=Fdim)
                neg_c = neg_c + jax.ops.segment_sum(
                    (active & ~hit).astype(jnp.int32), fattr,
                    num_segments=Fdim)
                vptr = jnp.where(hit, v, vptr)
                served = jnp.where(hit, jnp.int8(li), served)
                found = found | hit
            pos_counts.append(pos_c)
            neg_counts.append(neg_c)
            prn_l.append(prn)
            fp_l.append(fpc)
        # per-level model-path vs baseline-path attribution, in-graph so
        # the host never has to materialize the per-file vectors: mirrors
        # BourbonStore._account_lookup's has-model rule per engine mode
        mps, bps = [], []
        for li in range(N_LEVELS):
            lv = state.levels[li]
            tot_f = (pos_counts[li] + neg_counts[li]).astype(jnp.int64)
            tot = jnp.sum(tot_f)
            if mode == "baseline":
                mp = jnp.int64(0)
            elif mode == "model_pure":
                mp = tot
            elif mode == "level" and li > 0:
                mp = jnp.where(state.level_models[li].nseg > 0, tot,
                               jnp.int64(0))
            else:   # mixed per-file arm (L0 in every mode, 'model' levels)
                mp = jnp.sum(jnp.where(lv.nseg > 0, tot_f, jnp.int64(0)))
            mps.append(mp)
            bps.append(tot - mp)
        probe_split = jnp.stack([jnp.stack(mps), jnp.stack(bps)], axis=1)
        filter_stats = jnp.stack([jnp.stack(prn_l), jnp.stack(fp_l)], axis=1)
        return (found, vptr, served, tuple(pos_counts), tuple(neg_counts),
                probe_split, filter_stats)

    @staticmethod
    def state_signature(state: DeviceState) -> tuple:
        """Full shape/dtype signature of a device state.  Two states with
        equal signatures are guaranteed to reuse one traced program —
        keying the jit cache on the keys-array shapes alone would let a
        state whose bloom/fence/segment padding moved silently retrace
        inside a cached wrapper."""
        return tuple((tuple(leaf.shape), str(leaf.dtype))
                     for leaf in jax.tree_util.tree_leaves(state))

    def _jitted_lookup(self, state: DeviceState, B: int, mode: str,
                       l0_live: int | None, fsig: tuple | None = None,
                       level_maybe: tuple | None = None):
        l0_cap = int(state.levels[0].max_key.shape[0])
        # bucket the L0 slot count (0 or cap): occupancy changes must not
        # retrigger compilation in mixed read/write workloads
        l0_n = 0 if (l0_live == 0) else l0_cap
        live = tuple(bool(int(lv.n_files) > 0) for lv in state.levels)
        if level_maybe is not None:
            # filter-plane hint: a level whose maybe-mask is all-False for
            # every dispatched key cannot serve any of them (zero false
            # negatives) — drop it from the traced program entirely, which
            # is where miss-heavy batches actually save wall-clock
            live = tuple(a and b for a, b in zip(live, level_maybe))
            l0_n = l0_n if live[0] else 0
        key = (mode, B, l0_n, live, fsig, self.state_signature(state))
        if key not in self._jit_cache:
            fn = partial(self._lookup_impl, mode=mode, l0_slots=(l0_n,),
                         live_levels=live)
            if fsig is None:
                self._jit_cache[key] = jax.jit(
                    lambda st, p: fn(st, p))
            else:
                self._jit_cache[key] = jax.jit(
                    lambda st, p, fm, fh: fn(st, p, fmaybe=fm, fhas=fh,
                                             use_filters=True))
        return self._jit_cache[key]

    def lookup_async(self, state: DeviceState, probes: np.ndarray, mode: str,
                     vlog=None, l0_live: int | None = None,
                     fstate: FilterState | None = None,
                     fmaybe_host: np.ndarray | None = None,
                     level_maybe: tuple | None = None) -> PendingLookup:
        """Dispatch half of the lookup: launches the device program and
        returns immediately with device-array futures (JAX async
        dispatch).  The host is free to admit/coalesce the next batch
        while this one computes; `PendingLookup.resolve()` blocks.

        With ``fstate`` the filter plane runs first: one batched probe of
        the stacked per-level filters, whose (L, B) maybe-mask prunes the
        levels the descent visits per key (still a single async dispatch
        chain — no host sync).  A caller that already hashed the batch
        host-side (the store's pre-dispatch screen) passes the mask as
        ``fmaybe_host`` so the device doesn't probe the same keys twice."""
        B = probes.shape[0]
        p_dev = jnp.asarray(probes, jnp.int64)
        if fstate is None:
            fn = self._jitted_lookup(state, B, mode, l0_live)
            (found, vptr, served, pos_c, neg_c, probe_split,
             filter_stats) = fn(state, p_dev)
        else:
            fmaybe = (jnp.asarray(fmaybe_host) if fmaybe_host is not None
                      else self.filter_probe(fstate, p_dev))
            fsig = (tuple(fstate.bits.shape), self.cfg.filter_impl)
            fn = self._jitted_lookup(state, B, mode, l0_live, fsig,
                                     level_maybe)
            (found, vptr, served, pos_c, neg_c, probe_split,
             filter_stats) = fn(state, p_dev, fmaybe, fstate.has)
        if self.record_probe_split:
            # one async device-side add per batch; the running totals are
            # synced to the host only when *_np() is called
            self.probe_split_acc = (
                probe_split if self.probe_split_acc is None
                else self.probe_split_acc + probe_split)
            if fstate is not None:
                self.filter_stats_acc = (
                    filter_stats if self.filter_stats_acc is None
                    else self.filter_stats_acc + filter_stats)
        values = None
        if self.cfg.fetch_values and vlog is not None:
            dv = vlog.device_view()
            safe = jnp.clip(vptr, 0, dv.shape[0] - 1)
            values = dv[safe]
        return PendingLookup(found, vptr, served, pos_c, neg_c, values)

    def lookup(self, state: DeviceState, probes: np.ndarray, mode: str,
               vlog=None, l0_live: int | None = None,
               fstate: FilterState | None = None) -> LookupResult:
        return self.lookup_async(state, probes, mode, vlog, l0_live,
                                 fstate).resolve()

    def probe_split_np(self) -> np.ndarray:
        """Materialize the accumulated per-level (model, baseline) probe
        counts — ONE device->host sync, meant for the snapshot path only
        (``probe_acc_materializations`` counts these so tests can assert
        the hot path never pays it)."""
        if self.probe_split_acc is None:
            return np.zeros((N_LEVELS, 2), np.int64)
        self.probe_acc_materializations += 1
        return np.asarray(self.probe_split_acc)

    def filter_stats_np(self) -> np.ndarray:
        """Materialize the accumulated per-level (pruned, false-positive)
        filter-plane counts — same one-sync snapshot-only discipline as
        probe_split_np (own counter, so probe-split sync assertions stay
        exact)."""
        if self.filter_stats_acc is None:
            return np.zeros((N_LEVELS, 2), np.int64)
        self.filter_acc_materializations += 1
        return np.asarray(self.filter_stats_acc)
