"""WiscKey value log (key-value separation, §2.2/§4.2).

Values are appended to a log; sstables store only (key, value-pointer).
Host side is a growable numpy arena; ``device_view`` exposes the log to the
jitted ReadValue step as a (capacity, value_size) device array.

The log also keeps an incremental dead-entry estimate: whenever the store
observes that a slot was superseded (overwrite or delete), it calls
:meth:`note_dead` with the old pointers.  The durable subclass buckets the
counts per segment so GC candidacy needs no full-log scan.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["ValueLog"]


class ValueLog:
    def __init__(self, value_size: int = 64, capacity: int = 1 << 16) -> None:
        self.value_size = value_size
        self._buf = np.zeros((capacity, value_size), np.uint8)
        self._head = 0
        self._device = None  # lazily mirrored; invalidated on append
        self.dead_entries = 0  # slots superseded by overwrites/deletes

    def __len__(self) -> int:
        return self._head

    def note_dead(self, ptrs: np.ndarray) -> None:
        """Record that these slots were superseded.  Negative pointers
        (tombstones / never-stored) carry no log bytes and are ignored."""
        self.dead_entries += int((np.asarray(ptrs) >= 0).sum())

    def append_batch(self, values: np.ndarray) -> np.ndarray:
        """Append (B, value_size) payloads; returns (B,) int64 pointers."""
        b = values.shape[0]
        while self._head + b > self._buf.shape[0]:
            self._buf = np.concatenate([self._buf, np.zeros_like(self._buf)], axis=0)
        ptrs = np.arange(self._head, self._head + b, dtype=np.int64)
        self._buf[self._head: self._head + b] = values
        self._head += b
        self._device = None
        return ptrs

    def append_kv(self, keys: np.ndarray, seqs: np.ndarray,
                  values: np.ndarray) -> np.ndarray:
        """Append with key/seq metadata.  The in-memory log has no use for
        them; the durable log (repro.storage.vlog) persists them so GC can
        test entry liveness against the LSM."""
        del keys, seqs
        return self.append_batch(values)

    def get_batch_np(self, ptrs: np.ndarray) -> np.ndarray:
        ok = (ptrs >= 0) & (ptrs < self._head)
        safe = np.where(ok, ptrs, 0)
        out = self._buf[safe]
        out[~ok] = 0
        return out

    def device_view(self) -> jnp.ndarray:
        if self._device is None or self._device.shape[0] < self._head:
            self._device = jnp.asarray(self._buf[: self._head])
        return self._device
