"""LSM tree: levels, flush and compaction (LevelDB-style, §2.1).

Geometry follows LevelDB: seven levels, L0 may hold overlapping files and is
compacted when it reaches a file-count trigger; L1..L6 hold disjoint sorted
files with a 10x per-level record budget.  Compaction merges the picked file
with overlapping files in the next level, drops shadowed versions (newest seq
wins) and tombstones at the bottom, and re-chunks into file_cap-record files.

Every structural change bumps a per-level version (used by level-model
invalidation, §3 "Lifetime of Levels") and logs creations/deletions for the
lifetime analyses (Fig. 3/5).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .sstable import SSTable, build_sstable

__all__ = ["LSMConfig", "LSMTree", "CompactionEvent"]

N_LEVELS = 7


@dataclasses.dataclass
class LSMConfig:
    memtable_cap: int = 1 << 14        # records buffered before flush
    file_cap: int = 1 << 15            # max records per sstable
    l0_trigger: int = 4                # L0 file count triggering compaction
    l1_cap_records: int = 1 << 17      # L1 budget; Li = L1 * 10^(i-1)
    level_factor: int = 10
    bits_per_key: int = 10
    bloom_k: int = 7
    plr_delta: int = 8

    def level_cap(self, level: int) -> int:
        if level == 0:
            return self.l0_trigger * self.file_cap
        return self.l1_cap_records * self.level_factor ** (level - 1)


@dataclasses.dataclass
class CompactionEvent:
    at: float
    level: int            # source level (-1 = memtable flush)
    n_records: int
    created: list[int]
    deleted: list[int]


class LSMTree:
    def __init__(self, cfg: LSMConfig) -> None:
        self.cfg = cfg
        self.levels: list[list[SSTable]] = [[] for _ in range(N_LEVELS)]
        self.level_version = [0] * N_LEVELS
        self.level_changed_at = [0.0] * N_LEVELS
        self.events: list[CompactionEvent] = []
        self.dead_files: list[SSTable] = []   # for lifetime stats
        self.compacted_records = 0

    # ------------------------------------------------------------------ stats
    def all_files(self):
        for lvl in self.levels:
            yield from lvl

    def total_records(self) -> int:
        return sum(t.n for t in self.all_files())

    def level_records(self, level: int) -> int:
        return sum(t.n for t in self.levels[level])

    # ------------------------------------------------------------------ mutation
    def _touch(self, level: int, now: float) -> None:
        self.level_version[level] += 1
        self.level_changed_at[level] = now

    def _retire(self, table: SSTable, now: float) -> None:
        table.deleted_at = now
        self.dead_files.append(table)

    def flush(self, keys: np.ndarray, seqs: np.ndarray, vptrs: np.ndarray,
              now: float) -> list[SSTable]:
        """Memtable -> one L0 file (memtable_cap <= file_cap by config)."""
        if keys.size == 0:
            return []
        t = build_sstable(keys, seqs, vptrs, 0, now,
                          self.cfg.bits_per_key, self.cfg.bloom_k)
        # newest-first ordering inside L0 (search order = recency)
        self.levels[0].insert(0, t)
        self._touch(0, now)
        self.events.append(CompactionEvent(now, -1, t.n, [t.file_id], []))
        return [t]

    def needs_compaction(self) -> int | None:
        """Return a level to compact, or None."""
        if len(self.levels[0]) >= self.cfg.l0_trigger:
            return 0
        for i in range(1, N_LEVELS - 1):
            if self.level_records(i) > self.cfg.level_cap(i):
                return i
        return None

    def compact_once(self, now: float) -> CompactionEvent | None:
        lvl = self.needs_compaction()
        if lvl is None:
            return None
        return self._compact_level(lvl, now)

    def _merge(self, tables: list[SSTable], drop_tombstones: bool):
        keys = np.concatenate([t.keys for t in tables])
        seqs = np.concatenate([t.seqs for t in tables])
        vptrs = np.concatenate([t.vptrs for t in tables])
        order = np.lexsort((seqs, keys))
        k, s, v = keys[order], seqs[order], vptrs[order]
        last = np.r_[k[1:] != k[:-1], True]   # newest version of each key
        k, s, v = k[last], s[last], v[last]
        if drop_tombstones:
            live = v >= 0
            k, s, v = k[live], s[live], v[live]
        return k, s, v

    def _compact_level(self, lvl: int, now: float) -> CompactionEvent:
        cfg = self.cfg
        if lvl == 0:
            srcs = list(self.levels[0])
        else:
            # pick the oldest file (round-robin analogue) at this level
            srcs = [min(self.levels[lvl], key=lambda t: t.created_at)]
        lo = min(t.min_key for t in srcs)
        hi = max(t.max_key for t in srcs)
        nxt = lvl + 1
        overlap = [t for t in self.levels[nxt]
                   if not (t.max_key < lo or t.min_key > hi)]
        merged = srcs + overlap
        bottom = nxt == N_LEVELS - 1 or all(
            not self.levels[j] for j in range(nxt + 1, N_LEVELS))
        k, s, v = self._merge(merged, drop_tombstones=bottom)
        self.compacted_records += sum(t.n for t in merged)

        created: list[SSTable] = []
        for off in range(0, k.shape[0], cfg.file_cap):
            sl = slice(off, off + cfg.file_cap)
            created.append(build_sstable(k[sl], s[sl], v[sl], nxt, now,
                                         cfg.bits_per_key, cfg.bloom_k))
        for t in srcs:
            self.levels[lvl].remove(t)
            self._retire(t, now)
        for t in overlap:
            self.levels[nxt].remove(t)
            self._retire(t, now)
        self.levels[nxt].extend(created)
        self.levels[nxt].sort(key=lambda t: t.min_key)
        self._touch(lvl, now)
        self._touch(nxt, now)
        ev = CompactionEvent(now, lvl, int(k.shape[0]),
                             [t.file_id for t in created],
                             [t.file_id for t in srcs + overlap])
        self.events.append(ev)
        return ev
