"""IOPool — bounded host worker pool for the serving/storage I/O plane.

The tick loop must never block on file or arena I/O it could overlap
with device compute (the paper's §6 point: once the learned index
collapses indexing CPU, I/O dominates — so I/O must run beside the
accelerator, not in front of it).  This pool is the one place host
threads are created:

* **bounded** — a fixed worker count and an unbounded-but-accounted
  queue; ``depth()`` is exported as the ``io_pool_queue_depth`` gauge so
  saturation is visible instead of silent.
* **deterministic composition** — the pool itself promises nothing about
  completion order; callers that need request-order results use
  :class:`ValueFetch`, which scatters every task's output into a
  preallocated array at indices fixed *at submit time*.  Tasks write
  disjoint rows, so any completion order (and any pool size, 1..N)
  yields bit-identical results — the CI determinism gate relies on it.
* **no new dependencies** — plain ``threading`` + ``queue``; daemon
  workers die with the process.

Futures must be consumed: a submitted task whose :class:`IOFuture` is
dropped can fail silently (the exception is parked in the future).
bourbonlint's PAIRING rule flags unconsumed ``pool.submit`` /
``resolve_get_async`` handles statically, and HOTSYNC keeps blocking
device transfers out of ``submit``/``wait`` bodies.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Sequence

from repro.obs import NULL_HANDLE

__all__ = ["IOFuture", "IOPool", "ValueFetch", "wait_all"]

_now = time.perf_counter


class IOFuture:
    """Result slot for one submitted task.  ``result()`` blocks until the
    task ran and re-raises its exception in the caller's thread — errors
    surface at the join point, never in a worker's stderr."""

    __slots__ = ("_ev", "_value", "_exc")

    def __init__(self) -> None:
        self._ev = threading.Event()
        self._value: Any = None
        self._exc: BaseException | None = None

    def _finish(self, value: Any, exc: BaseException | None) -> None:
        self._value = value
        self._exc = exc
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self) -> Any:
        self._ev.wait()
        if self._exc is not None:
            raise self._exc
        return self._value


def wait_all(futs: Sequence[IOFuture]) -> None:
    """Join a batch of futures (re-raising the first failure) — the
    consumption point PAIRING expects every submitted handle to reach."""
    for f in futs:
        f.result()


class IOPool:
    """Fixed-size daemon worker pool.  ``submit`` enqueues ``fn(*args)``
    and returns an :class:`IOFuture`; ``close`` drains and stops the
    workers (idempotent — a closed pool runs submitted work inline, so a
    shut-down server still completes stragglers deterministically)."""

    def __init__(self, workers: int = 2, name: str = "io") -> None:
        if workers < 1:
            raise ValueError("IOPool needs at least one worker")
        self.workers = int(workers)
        self.name = name
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        # accounting (exported through the server's io_pool_* metrics)
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.max_depth = 0
        self._threads = [
            threading.Thread(target=self._run, name=f"{name}-{i}",
                             daemon=True)
            for i in range(self.workers)]
        for t in self._threads:
            t.start()

    # ---------------------------------------------------------------- submit
    def submit(self, fn: Callable, *args: Any) -> IOFuture:
        fut = IOFuture()
        if self._closed:
            # inline fallback keeps late stragglers correct (and ordered
            # by the caller's own join) instead of silently dropped
            try:
                fut._finish(fn(*args), None)
            except BaseException as exc:  # parked; re-raised at result()
                fut._finish(None, exc)
            return fut
        with self._lock:
            self.submitted += 1
            depth = self.submitted - self.completed
            if depth > self.max_depth:
                self.max_depth = depth
        self._q.put((fut, fn, args))
        return fut

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fut, fn, args = item
            try:
                fut._finish(fn(*args), None)
            except BaseException as exc:
                fut._finish(None, exc)
            with self._lock:
                self.completed += 1

    # ------------------------------------------------------------- lifecycle
    def depth(self) -> int:
        """Tasks submitted but not yet completed (queued + running)."""
        with self._lock:
            return self.submitted - self.completed

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join()

    def stats(self) -> dict:
        with self._lock:
            return {"workers": self.workers,
                    "submitted": self.submitted,
                    "completed": self.completed,
                    "depth": self.submitted - self.completed,
                    "max_depth": self.max_depth}


class ValueFetch:
    """Handle for an in-flight batched value materialization.

    ``tasks`` are closures that each scatter one chunk's values into a
    caller-owned preallocated array at indices fixed before submission
    (disjoint rows per task), so results land in request order no matter
    which worker finishes first — pool size 1 and N are bit-identical.
    With a pool the tasks start immediately and ``wait()`` joins them;
    without one (``pool=None``) the tasks run inside ``wait()``, which
    is exactly the old synchronous resolve path.

    ``wait()`` is idempotent, times the *exposed* wait under the
    ``value_fetch`` stage handle, and reports (hidden_us, exposed_us) to
    ``on_done`` — the raw material for the fleet's value-fetch overlap
    ratio (hidden = fetch time that ran concurrently with other host or
    device work before the caller blocked)."""

    __slots__ = ("_result", "_tasks", "_futs", "_stage", "_on_done",
                 "_t0", "_done", "span")

    def __init__(self, result: Any, tasks: Sequence[Callable],
                 pool: IOPool | None = None, stage=NULL_HANDLE,
                 on_done: Callable | None = None, span=None) -> None:
        self._result = result
        self._stage = stage
        self._on_done = on_done
        # causal-tracing span of the blocking half (repro.obs.trace): the
        # producer parks it here so the join site can flow-link its
        # exposed wait back to the worker-side io_task span
        self.span = span
        self._done = False
        self._t0 = _now()
        if pool is not None and tasks:
            self._tasks: Sequence[Callable] = ()
            self._futs = [pool.submit(t) for t in tasks]
        else:
            self._tasks = tuple(tasks)
            self._futs = []

    def done(self) -> bool:
        return self._done

    def wait(self) -> Any:
        """Block until every chunk landed; returns the result object the
        fetch was created with (e.g. the (found, vals) pair)."""
        if self._done:
            return self._result
        self._done = True
        t_wait = _now()
        t0 = self._stage.begin()
        if self._futs:
            wait_all(self._futs)
        else:
            for t in self._tasks:
                t()
        self._stage.end(t0)
        if self._on_done is not None:
            # hidden time is only real when workers actually ran the
            # tasks concurrently; the inline path exposes everything
            hidden = (t_wait - self._t0) if self._futs else 0.0
            self._on_done(hidden * 1e6, (_now() - t_wait) * 1e6)
        return self._result
