"""repro.io — the host I/O plane.

A bounded worker pool (:class:`IOPool`) plus the future/handle types the
storage and serving layers use to take file and arena I/O off the tick
loop: value-log fetches become :class:`ValueFetch` handles that overlap
device compute, and (together with ``repro.storage.wal.GroupCommitWAL``)
WAL appends coalesce into group commits.  See ``src/repro/server`` and
``src/repro/storage`` READMEs for how the planes compose.
"""

from .pool import IOFuture, IOPool, ValueFetch, wait_all

__all__ = ["IOFuture", "IOPool", "ValueFetch", "wait_all"]
