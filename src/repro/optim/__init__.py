"""Distributed optimizer substrate."""

from .adamw import AdamWConfig, adamw_init, adamw_update, adamw_state_shapes, global_norm
from .schedule import lr_schedule
from .grad_compress import quantize_int8, dequantize_int8, compressed_psum

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "adamw_state_shapes",
           "global_norm", "lr_schedule", "quantize_int8", "dequantize_int8",
           "compressed_psum"]
