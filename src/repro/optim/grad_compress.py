"""Int8 gradient compression for cross-pod data-parallel reduction.

The beyond-paper distributed trick (DESIGN.md §6): on the multi-pod mesh the
pod axis rides the slow DCI links, so the cross-pod gradient all-reduce is
quantized to int8 with per-block scales and stochastic rounding:

    in-pod reduce-scatter (bf16, fast ICI)
      -> int8 quantize -> cross-pod all-reduce (DCI, 2x fewer bytes than bf16)
      -> dequantize -> in-pod all-gather

Used inside shard_map over the pod axis (trainer option
``cross_pod_compress``); tests validate the quantization error bound and the
unbiasedness of stochastic rounding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum"]

BLOCK = 256


def quantize_int8(x: jnp.ndarray, rng=None):
    """Per-block (BLOCK elements) absmax int8 quantization; optional
    stochastic rounding keeps E[dequant] = x."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    if rng is not None:
        y = jnp.floor(y + jax.random.uniform(rng, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str, rng=None):
    """Quantize -> psum over `axis_name` -> dequantize (inside shard_map).

    The int8 payload is what crosses the link; the psum accumulates in int32
    to avoid overflow across pods (<=2^23 pods of headroom)."""
    q, scale = quantize_int8(x, rng)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)  # conservative shared scale
    n = jax.lax.psum(1, axis_name)
    avg_scale = ssum / n
    return dequantize_int8(
        jnp.clip(qsum, -127 * n, 127 * n).astype(jnp.int32),
        avg_scale, x.shape, x.dtype)
