"""LR schedules (warmup + cosine / linear / constant)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lr_schedule"]


def lr_schedule(step, kind: str = "cosine", warmup: int = 100,
                total: int = 10000, min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    w = jnp.minimum(s / max(warmup, 1), 1.0)
    if kind == "constant":
        return w
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    if kind == "linear":
        decay = 1.0 - (1.0 - min_ratio) * frac
    else:  # cosine
        decay = min_ratio + (1.0 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return w * decay
