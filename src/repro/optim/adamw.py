"""AdamW with f32 master weights, ZeRO-style sharded states, global-norm
clipping.  States inherit each parameter's sharding (FSDP over the data axis
x TP over model), so optimizer memory scales 1/(data*model) — the ZeRO-3
posture under GSPMD.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_f32: bool = True     # keep f32 master copy of bf16 params


def adamw_init(params, cfg: AdamWConfig):
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }
    if cfg.master_f32:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_state_shapes(param_specs, cfg: AdamWConfig):
    """Spec tree mirroring adamw_init (for the dry-run's in_shardings)."""
    from repro.models.layers import Spec

    def f32(s):
        return Spec(s.shape, jnp.float32, getattr(s, "axes", (None,) * len(s.shape)))

    state = {
        "step": Spec((), jnp.int32, ()),
        "m": jax.tree.map(f32, param_specs),
        "v": jax.tree.map(f32, param_specs),
    }
    if cfg.master_f32:
        state["master"] = jax.tree.map(f32, param_specs)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cfg.lr * lr_scale

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        pm = p_master.astype(jnp.float32)
        pm = pm - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pm)
        return pm, m, v

    out = jax.tree.map(upd, masters, grads, state["m"], state["v"])
    new_master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda pm, p: pm.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
