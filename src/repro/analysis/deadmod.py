"""Dead-module report: import-graph reachability over ``repro``.

The seed dropped ~90 files into ``src/repro``; the storage/serving PRs
since then built on a subset.  Anything not importable from the roots —
``repro/__init__``, the test suite, the benchmarks, the scripts — is
dead weight that masks real dead code in review.  This pass parses the
imports of every ``.py`` file (AST only, nothing is executed), resolves
``repro.*`` absolute and relative imports to files, and BFSes from the
roots.  Unreached ``src/repro`` modules are reported; known seed
leftovers live in an explicit allowlist (quarantined, reported but not
failing) so a *new* module going dark is always a hard finding.
"""

from __future__ import annotations

import ast
import os

# Seed leftovers that are knowingly unreferenced.  Anything matching one
# of these prefixes (module path form, e.g. "repro/models") is reported
# as quarantined instead of failing the report.  Trim this list as the
# modules are either deleted or wired back in.
DEAD_MODULE_ALLOWLIST: tuple = (
    # per-arch config modules are loaded dynamically by
    # repro.configs.base.get_config via importlib — invisible to the
    # static import graph, exercised by tests/test_archs_smoke.py
    "repro/configs",
    # `python -m` CLI entrypoints from the seed's training substrate;
    # nothing imports them (dryrun is spawned by scripts/make_experiments
    # as a subprocess) and the serving stack has superseded them
    "repro/launch/dryrun",
    "repro/launch/serve",
    "repro/launch/train",
)


def _module_name(relpath: str) -> str:
    """src/repro/a/b.py -> repro.a.b ; packages use their __init__."""
    p = relpath.replace(os.sep, "/")
    if p.endswith("/__init__.py"):
        p = p[: -len("/__init__.py")]
    elif p.endswith(".py"):
        p = p[:-3]
    return p.replace("/", ".")


def _iter_py(root, sub):
    base = os.path.join(root, sub)
    if not os.path.isdir(base):
        return
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = sorted(d for d in dirnames
                             if not d.startswith(".") and d != "__pycache__")
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def _imports_of(path: str, modname: str):
    """Absolute module names this file imports (repro.* resolved, incl.
    relative imports and `from pkg import name` where name is a module)."""
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read())
    except (OSError, SyntaxError):
        return []
    out = []
    pkg_parts = modname.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.append(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                # containing package, then (level-1) more hops up
                pkg = pkg_parts if path.endswith("__init__.py") \
                    else pkg_parts[:-1]
                base = pkg[: len(pkg) - (node.level - 1)]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if mod:
                out.append(mod)
                for alias in node.names:
                    out.append(f"{mod}.{alias.name}")
    return out


def dead_module_report(root: str, allowlist=DEAD_MODULE_ALLOWLIST) -> dict:
    """Compute reachability.  Returns ``{"dead": [...], "quarantined":
    [...], "reachable": int, "roots": int}`` with module names relative
    to ``src`` (e.g. ``repro.models.resnet``)."""
    src = os.path.join(root, "src")
    modules: dict[str, str] = {}      # module name -> file path
    for path in _iter_py(root, "src"):
        modules[_module_name(os.path.relpath(path, src))] = path

    # roots: the package itself + every test/bench/script/example file
    root_files = []
    for sub in ("tests", "benchmarks", "scripts", "examples"):
        root_files.extend(_iter_py(root, sub))

    reached: set = set()
    queue: list = []

    def reach(mod: str):
        """Mark mod and its package __init__ chain reached."""
        parts = mod.split(".")
        for i in range(1, len(parts) + 1):
            name = ".".join(parts[:i])
            if name in modules and name not in reached:
                reached.add(name)
                queue.append(name)

    reach("repro")
    for path in root_files:
        modname = "__root__." + _module_name(
            os.path.relpath(path, root)).replace(os.sep, ".")
        for imp in _imports_of(path, modname):
            if imp.split(".")[0] == "repro":
                reach(imp)

    while queue:
        mod = queue.pop()
        path = modules[mod]
        for imp in _imports_of(path, mod):
            if imp.split(".")[0] == "repro":
                reach(imp)

    dead, quarantined = [], []
    for mod in sorted(modules):
        if mod in reached:
            continue
        slashed = mod.replace(".", "/")
        if any(slashed == al or slashed.startswith(al + "/")
               for al in allowlist):
            quarantined.append(mod)
        else:
            dead.append(mod)
    return {"dead": dead, "quarantined": quarantined,
            "reachable": len(reached), "total": len(modules),
            "roots": len(root_files)}
