"""HOTSYNC — no blocking device→host transfers on registered hot paths.

The paper's §4–§5 point is that learned-index lookup wins are measured
in microseconds; one stray `np.asarray(device_value)` forces the JAX
async dispatch queue to drain and erases them.  PR 5 split lookup into
dispatch/resolve halves precisely so the only blocking sync is the one
inside ``resolve_get``; this rule pins that property statically.

Model: a simple per-function taint pass.  Values produced by ``jnp.*``
/ ``jax.*`` calls, by configured producer calls (``lookup_async``,
``device_view``, …) or configured device-attribute reads (``.f_dev``,
``._pos_dev``, …) are *device-tainted*; taint propagates through
assignments (incl. tuple unpacking).  Inside a registered hot function,

* ``jax.device_get(...)`` and ``.block_until_ready()`` are flagged
  unconditionally, and
* ``np.asarray`` / ``np.array`` / ``float()`` / ``int()`` / ``.item()``
  are flagged only when their argument is tainted — host-side numpy math
  on the hot path is fine and common.

``resolve_*`` functions are the designated sync point for their pending
argument: transfers whose argument is (an attribute/subscript of) the
first non-self parameter are permitted there.
"""

from __future__ import annotations

import ast

from .core import Finding, Rule, SourceFile, dotted, match_hot

# (class_glob, func_glob) pairs — the registered hot paths from the
# issue: engine dispatch, store/sharded dispatch+resolve, server tick,
# tracer handles, cache probe/fill.
DEFAULT_HOT_FUNCTIONS = (
    ("LookupEngine", "lookup_async"),
    ("LookupEngine", "filter_probe"),
    ("*", "dispatch_*"),
    ("*", "resolve_*"),
    ("*Server", "tick"),
    ("StageHandle", "begin"),
    ("StageHandle", "end"),
    ("HotKeyCache", "lookup"),
    ("HotKeyCache", "fill"),
    # host I/O plane (repro.io + group-commit WAL): these run on, or are
    # waited on by, the tick loop — a blocking device transfer inside any
    # of them would serialize the exact overlap they exist to create
    ("IOPool", "submit"),
    ("GroupCommitWAL", "append"),
    ("GroupCommitWAL", "sync"),
    ("ValueFetch", "wait"),
    ("*", "wal_sync"),
)

# calls whose result lives on device
DEFAULT_DEVICE_PRODUCERS = (
    "lookup_async", "device_view", "device_state", "_dist_dispatch",
    "device_put", "filter_probe",
)

# attribute names that hold device arrays in this codebase
DEFAULT_DEVICE_ATTRS = (
    "f_dev", "v_dev", "probe_split_acc", "filter_stats_acc",
    "_pos_dev", "_neg_dev",
)

# transfer sinks gated on taint (jnp.asarray is host->device, not here)
_TAINT_SINKS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "float", "int"}
# sinks that block no matter what they're applied to
_ALWAYS_SINKS = {"jax.device_get"}


class HotSyncRule(Rule):
    id = "HOTSYNC"
    description = ("blocking device-to-host transfer inside a registered "
                   "hot-path function")

    def __init__(self, hot_functions=DEFAULT_HOT_FUNCTIONS,
                 device_producers=DEFAULT_DEVICE_PRODUCERS,
                 device_attrs=DEFAULT_DEVICE_ATTRS,
                 sync_arg_ok=("resolve_*",)) -> None:
        self.hot_functions = tuple(hot_functions)
        self.device_producers = tuple(device_producers)
        self.device_attrs = tuple(device_attrs)
        # func_globs whose first non-self parameter is the designated
        # sync payload (transfers of it are the point of the function)
        self.sync_arg_ok = tuple(sync_arg_ok)

    def check(self, sf: SourceFile) -> list:
        from .core import walk_functions
        import fnmatch
        findings: list[Finding] = []
        for qual, classname, fn in walk_functions(sf.tree):
            if not match_hot(self.hot_functions, classname, fn.name):
                continue
            sync_param = None
            if any(fnmatch.fnmatch(fn.name, g) for g in self.sync_arg_ok):
                params = [a.arg for a in fn.args.args
                          if a.arg not in ("self", "cls")]
                if params:
                    sync_param = params[0]
            findings.extend(self._check_fn(sf, qual, fn, sync_param))
        return findings

    # ------------------------------------------------------------- taint

    def _is_device_expr(self, node, tainted: set) -> bool:
        if isinstance(node, ast.Name):
            return node.id in tainted
        if isinstance(node, ast.Attribute):
            if node.attr in self.device_attrs:
                return True
            return self._is_device_expr(node.value, tainted)
        if isinstance(node, ast.Subscript):
            return self._is_device_expr(node.value, tainted)
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            last = name.rsplit(".", 1)[-1]
            if name.startswith(("jnp.", "jax.")):
                return True
            if last in self.device_producers:
                return True
            # method on a device value stays on device (e.g. x.sum())
            if isinstance(node.func, ast.Attribute):
                return self._is_device_expr(node.func.value, tainted)
            return False
        if isinstance(node, (ast.BinOp,)):
            return (self._is_device_expr(node.left, tainted)
                    or self._is_device_expr(node.right, tainted))
        if isinstance(node, ast.UnaryOp):
            return self._is_device_expr(node.operand, tainted)
        if isinstance(node, ast.IfExp):
            return (self._is_device_expr(node.body, tainted)
                    or self._is_device_expr(node.orelse, tainted))
        return False

    def _from_sync_param(self, node, sync_param) -> bool:
        """True when ``node`` is the sync parameter or an attribute /
        subscript chain rooted at it (``pb``, ``pb.f_dev``, ``pb.x[:n]``)."""
        if sync_param is None:
            return False
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return isinstance(node, ast.Name) and node.id == sync_param

    def _check_fn(self, sf, qual, fn, sync_param):
        findings: list[Finding] = []
        tainted: set = set()

        def note(node, msg):
            findings.append(Finding(self.id, sf.relpath, node.lineno,
                                    node.col_offset, msg, symbol=qual))

        def taint_target(tgt, is_dev):
            if isinstance(tgt, ast.Name):
                if is_dev:
                    tainted.add(tgt.id)
                else:
                    tainted.discard(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    taint_target(el, is_dev)

        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                is_dev = self._is_device_expr(node.value, tainted)
                for tgt in node.targets:
                    taint_target(tgt, is_dev)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                taint_target(node.target,
                             self._is_device_expr(node.value, tainted))
            elif isinstance(node, ast.Call):
                name = dotted(node.func)
                last = name.rsplit(".", 1)[-1] if name else ""
                if name in _ALWAYS_SINKS:
                    note(node, f"{name}() blocks until the device queue "
                               f"drains; hot paths must stay async")
                    continue
                if last == "block_until_ready" or (
                        isinstance(node.func, ast.Attribute)
                        and node.func.attr == "block_until_ready"):
                    note(node, ".block_until_ready() on the hot path "
                               "forces a device sync")
                    continue
                if last == "item" and isinstance(node.func, ast.Attribute) \
                        and self._is_device_expr(node.func.value, tainted) \
                        and not self._from_sync_param(node.func.value,
                                                      sync_param):
                    note(node, ".item() on a device value is a blocking "
                               "transfer")
                    continue
                if name in _TAINT_SINKS and node.args:
                    arg = node.args[0]
                    if self._is_device_expr(arg, tainted) \
                            and not self._from_sync_param(arg, sync_param):
                        note(node, f"{name}() on a device value is a "
                                   f"blocking device-to-host transfer")
        return findings
