"""PAIRING — every dispatch has a resolve; every cache fill is epoch-stamped.

PR 5 split the read path into ``dispatch_get`` (enqueue device work,
return a pending handle) and ``resolve_get`` (the single blocking sync).
A dispatched handle that is dropped on some control-flow path leaks the
in-flight batch: the device work still runs, the value-log readers hold
their segments, and the epoch-barrier logic in the pipelined server
counts an in-flight entry that will never retire.  Separately, the
epoch-invalidated ``HotKeyCache`` is only correct if every ``fill``
carries the owning shard epochs — a fill without the stamp resurrects
stale values after a write barrier.

Checks:

* every handle-returning call site must *consume* its result on all
  control-flow paths before the function returns: pass it onward
  (``resolve_get(pb)``, ``wait_all(futs)``, any call argument, a
  constructor), store it (``self._inflight.append``, subscript/attribute
  store), or return it.  An ``if`` consumes only when both branches
  consume; merely *testing* the handle (``pb.epochs != ...``) does not.
  A bare handle-returning expression statement is always a leak.  The
  tracked producers are ``*.dispatch_get(...)`` (pending device batch),
  ``*.resolve_get_async(...)`` (in-flight :class:`ValueFetch` — dropping
  it silently skips the value materialization), and ``<pool-ish
  receiver>.submit(...)`` (an :class:`~repro.io.IOFuture` that parks its
  task's exception until ``result()`` — dropped, the failure vanishes).
  ``submit`` is only tracked when the receiver name contains ``pool`` or
  ``io``, so the request queue's and engine's unrelated ``submit``
  methods stay out of scope.
* ``.fill(...)`` on a cache-like receiver (name contains ``cache``) must
  pass ≥ 4 positional args or an ``epochs=`` keyword — the epoch stamp
  is the 4th parameter of ``HotKeyCache.fill``.
"""

from __future__ import annotations

import ast

from .core import Finding, Rule, SourceFile, dotted, walk_functions


def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class PairingRule(Rule):
    id = "PAIRING"
    description = ("dispatch_get result must reach resolve_get/escape on "
                   "all paths; cache fills must carry epoch stamps")

    def check(self, sf: SourceFile) -> list:
        findings: list[Finding] = []
        for qual, _cls, fn in walk_functions(sf.tree):
            findings.extend(self._check_dispatch(sf, qual, fn))
            findings.extend(self._check_fill(sf, qual, fn))
        return findings

    # ------------------------------------------------------ dispatch_get

    def _check_dispatch(self, sf, qual, fn):
        findings: list[Finding] = []
        self._scan_stmts(sf, qual, fn.body, findings)
        return findings

    def _scan_stmts(self, sf, qual, stmts, findings, tail=()):
        for i, st in enumerate(stmts):
            rest = stmts[i + 1:] + list(tail)
            self._check_stmt(sf, qual, st, rest, findings)
            # recurse into nested blocks; code after the block is still a
            # place the handle can be consumed, so thread it through
            for blk in self._blocks(st):
                self._scan_stmts(sf, qual, blk, findings, tail=rest)

    @staticmethod
    def _blocks(st):
        blocks = []
        for attr in ("body", "orelse", "finalbody"):
            b = getattr(st, attr, None)
            if isinstance(b, list) and b and isinstance(b[0], ast.stmt):
                blocks.append(b)
        for h in getattr(st, "handlers", ()):
            blocks.append(h.body)
        return blocks

    def _dispatch_calls(self, node):
        for sub in ast.walk(node):
            if not (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)):
                continue
            attr = sub.func.attr
            if attr in ("dispatch_get", "resolve_get_async"):
                yield sub
            elif attr == "submit":
                # only I/O-pool submits return trackable futures; the
                # request queue's / engine's submit methods do not
                recv = dotted(sub.func.value).lower()
                if "pool" in recv or "io" in recv:
                    yield sub

    def _check_stmt(self, sf, qual, st, rest, findings):
        # 1. discarded:  store.dispatch_get(...)  as a bare statement
        if isinstance(st, ast.Expr):
            for call in self._dispatch_calls(st.value):
                if not self._nested_in_consumer(st.value, call):
                    findings.append(Finding(
                        self.id, sf.relpath, call.lineno, call.col_offset,
                        f"{call.func.attr} result discarded: the pending "
                        f"handle is never resolved/joined", symbol=qual))
            return
        # 2. assigned:  pb = store.dispatch_get(...)
        if isinstance(st, (ast.Assign, ast.AnnAssign)):
            value = st.value
            if value is None:
                return
            calls = list(self._dispatch_calls(value))
            if not calls:
                return
            targets = st.targets if isinstance(st, ast.Assign) else [st.target]
            if any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in targets):
                return   # stored into an object/container: escaped
            names = set()
            for t in targets:
                names |= _names_in(t)
            if not names:
                return
            if not self._consumed(names, rest):
                call = calls[0]
                findings.append(Finding(
                    self.id, sf.relpath, call.lineno, call.col_offset,
                    f"{call.func.attr} result "
                    f"{'/'.join(sorted(names))} does not reach a "
                    f"resolve/join/escape on every following path",
                    symbol=qual))

    @staticmethod
    def _nested_in_consumer(root, call):
        """dispatch_get directly nested in another call's arguments —
        ``resolve_get(store.dispatch_get(...))`` — is consumed."""
        for sub in ast.walk(root):
            if isinstance(sub, ast.Call) and sub is not call:
                for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                    for inner in ast.walk(arg):
                        if inner is call:
                            return True
        return False

    # -------------------------------------- definite-consumption analysis

    def _consumed(self, names: set, stmts) -> bool:
        """True if every path through ``stmts`` consumes one of ``names``.

        Consumption = the name used as a call argument / receiver of a
        method call, returned, yielded, stored into a container/attr, or
        re-assigned wholesale to something else (ownership moved).  A
        reference inside an ``if`` *test* is not consumption."""
        for i, st in enumerate(stmts):
            rest = stmts[i + 1:]
            if isinstance(st, (ast.Return, ast.Raise)):
                return self._expr_consumes(getattr(st, "value", None) or
                                           getattr(st, "exc", None), names)
            if isinstance(st, ast.If):
                then_ok = self._consumed(names, list(st.body) + rest)
                else_ok = self._consumed(names, list(st.orelse) + rest)
                return then_ok and else_ok
            if isinstance(st, ast.Try):
                # the happy path must consume; handlers are error paths
                return self._consumed(names, list(st.body)
                                      + list(st.orelse) + rest)
            if isinstance(st, ast.With):
                return self._consumed(names, list(st.body) + rest)
            if isinstance(st, (ast.For, ast.While)):
                # loops may run zero times: only the code after the loop
                # (or an unconditional consume inside we can't prove)
                continue
            if isinstance(st, ast.Expr):
                if self._expr_consumes(st.value, names):
                    return True
            elif isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if st.value is not None \
                        and self._expr_consumes(st.value, names):
                    return True
                # wholesale re-assignment of the name drops the old
                # handle — that's a *new* handle, old one leaked; keep
                # scanning (conservative: not consumption)
        return False

    def _expr_consumes(self, node, names: set) -> bool:
        if node is None:
            return False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                # receiver:  pb.resolve()  /  name in any arg position
                recv = sub.func
                if isinstance(recv, ast.Attribute):
                    for inner in ast.walk(recv.value):
                        if isinstance(inner, ast.Name) and inner.id in names:
                            return True
                for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                    for inner in ast.walk(arg):
                        if isinstance(inner, ast.Name) and inner.id in names:
                            return True
            elif isinstance(sub, (ast.Tuple, ast.List, ast.Dict)):
                for inner in ast.walk(sub):
                    if isinstance(inner, ast.Name) and inner.id in names:
                        return True
            elif isinstance(sub, ast.Name) and sub.id in names \
                    and isinstance(node, (ast.Name, ast.Attribute,
                                          ast.Await)):
                # bare `return pb` / `return pb.x`
                return True
        return False

    # ------------------------------------------------------------- fills

    def _check_fill(self, sf, qual, fn):
        findings: list[Finding] = []
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "fill"):
                continue
            recv = dotted(node.func.value).lower()
            if "cache" not in recv:
                continue
            has_epoch_kw = any(kw.arg == "epochs" for kw in node.keywords)
            if len(node.args) < 4 and not has_epoch_kw:
                findings.append(Finding(
                    self.id, sf.relpath, node.lineno, node.col_offset,
                    "cache fill without an epoch stamp: stale values can "
                    "survive a write barrier (pass epochs as the 4th arg)",
                    symbol=qual))
        return findings
