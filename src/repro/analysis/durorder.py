"""DURORDER — durability ordering in the storage layer.

The storage engine's crash-safety argument (storage/README) rests on a
strict publish protocol: write to a temp file, ``flush`` + ``fsync`` the
data, ``os.replace`` into place, then ``fsync_dir`` the directory so the
rename itself is durable; the WAL appends frame → flush → fsync; and
CURRENT flips via ``set_current`` only after the manifest is durable.
A missing step is invisible until a crash at exactly the wrong moment.

This rule is a per-function *line-ordering* check — intentionally
coarser than a real dataflow pass, tuned to this repo's idioms:

* **TMPRENAME** — a function calling ``os.replace``/``os.rename`` that
  also opens a file for writing must ``.flush()`` and ``os.fsync(`` at
  earlier lines (under fsync mode the data must be durable before it is
  published).
* **CREATENOSYNC** — an ``open()`` in a creating mode (``w``/``a``/
  ``x``/``+``) inside an fsync-aware function (its source mentions
  ``fsync``) must be followed by ``fsync_dir(`` or ``set_current(`` so
  the new directory entry survives a crash.  Temp files that are later
  ``os.replace``d are exempt (the rename target's durability is the
  replace's job), as are paths matching ``ignore_path_substrings``.
* **REPLACENODIR** — ``os.replace`` in an fsync-aware function must be
  followed by ``fsync_dir(``/``set_current(`` at an equal-or-later line.
* **FSYNCNOFLUSH** — ``os.fsync(x.fileno())`` needs a ``.flush()`` at an
  earlier line: fsyncing an unflushed buffered file persists nothing.
  (The ``os.open`` fd form used by ``fsync_dir`` itself has no buffer
  and is exempt.)
"""

from __future__ import annotations

import ast

from .core import Finding, Rule, SourceFile, dotted, walk_functions

DEFAULT_SCOPES = ("repro/storage", "repro/distributed")
DEFAULT_IGNORE_PATH_SUBSTRINGS = ("LOCK",)


def _call_lines(fn):
    """Map of interesting call kinds -> sorted line numbers within fn."""
    lines = {"replace": [], "flush": [], "fsync": [], "fsync_dir": [],
             "set_current": [], "fsync_fileno": []}
    opens = []   # (node, mode, path_expr)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        last = name.rsplit(".", 1)[-1] if name else ""
        if name in ("os.replace", "os.rename"):
            lines["replace"].append((node.lineno, node))
        elif last == "flush":
            lines["flush"].append((node.lineno, node))
        elif name == "os.fsync":
            lines["fsync"].append((node.lineno, node))
            if node.args and isinstance(node.args[0], ast.Call) \
                    and isinstance(node.args[0].func, ast.Attribute) \
                    and node.args[0].func.attr == "fileno":
                lines["fsync_fileno"].append((node.lineno, node))
        elif last == "fsync_dir":
            lines["fsync_dir"].append((node.lineno, node))
        elif last == "set_current":
            lines["set_current"].append((node.lineno, node))
        elif name == "open" and node.args:
            mode = ""
            if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
                mode = str(node.args[1].value)
            for kw in node.keywords:
                if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                    mode = str(kw.value.value)
            # "r+" updates in place — no new directory entry to sync
            if any(c in mode for c in "wax"):
                opens.append((node, mode, node.args[0]))
    return lines, opens


def _expr_names(node) -> str:
    """Flat text of names/attrs/constants in an expression, for matching
    a path variable against os.replace sources."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.append(sub.id)
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.append(sub.value)
    return " ".join(out)


class DurabilityOrderRule(Rule):
    id = "DURORDER"
    description = ("storage publish/append ordering: flush+fsync before "
                   "rename, fsync_dir after create/replace")

    def __init__(self, scopes=DEFAULT_SCOPES,
                 ignore_path_substrings=DEFAULT_IGNORE_PATH_SUBSTRINGS):
        self.scopes = tuple(scopes)
        self.ignore_path_substrings = tuple(ignore_path_substrings)

    def check(self, sf: SourceFile) -> list:
        if not any(s in sf.relpath for s in self.scopes):
            return []
        findings: list[Finding] = []
        for qual, _cls, fn in walk_functions(sf.tree):
            findings.extend(self._check_fn(sf, qual, fn))
        return findings

    def _check_fn(self, sf, qual, fn):
        findings: list[Finding] = []
        lines, opens = _call_lines(fn)
        src_segment = ast.get_source_segment(sf.text, fn) or ""
        fsync_aware = "fsync" in src_segment

        def note(node, msg):
            findings.append(Finding(self.id, sf.relpath, node.lineno,
                                    node.col_offset, msg, symbol=qual))

        replace_lines = [ln for ln, _ in lines["replace"]]
        durdir_lines = [ln for ln, _ in lines["fsync_dir"]] + \
                       [ln for ln, _ in lines["set_current"]]

        # TMPRENAME: data durable before publish
        if replace_lines and opens and fsync_aware:
            first_replace = min(replace_lines)
            has_flush = any(ln <= first_replace for ln, _ in lines["flush"])
            has_fsync = any(ln <= first_replace for ln, _ in lines["fsync"])
            if not (has_flush and has_fsync):
                _, node = min(lines["replace"])
                note(node, "os.replace publishes a file written in this "
                           "function without a preceding flush+os.fsync "
                           "(torn data can be renamed into place)")

        # REPLACENODIR: rename durable in the directory
        if fsync_aware:
            for ln, node in lines["replace"]:
                if not any(d >= ln for d in durdir_lines):
                    note(node, "os.replace without a following fsync_dir/"
                               "set_current: the rename itself is not "
                               "durable after a crash")

        # CREATENOSYNC: new directory entries need fsync_dir
        if fsync_aware:
            # path exprs fed to os.replace as the *source* (tmp files)
            replace_srcs = [_expr_names(n.args[0])
                            for _, n in lines["replace"]
                            if isinstance(n, ast.Call) and n.args]
            for node, mode, path_expr in opens:
                names = _expr_names(path_expr)
                if any(s in names for s in self.ignore_path_substrings):
                    continue
                if any(names and names == src for src in replace_srcs):
                    continue    # tmp file: replace owns its durability
                if not any(d >= node.lineno for d in durdir_lines):
                    note(node, f"open(mode={mode!r}) creates/extends a "
                               f"file in an fsync-aware function with no "
                               f"following fsync_dir/set_current")

        # FSYNCNOFLUSH: buffered fsync without flush
        for ln, node in lines["fsync_fileno"]:
            if not any(fl <= ln for fl, _ in lines["flush"]):
                note(node, "os.fsync(f.fileno()) without an earlier "
                           "f.flush(): buffered data is not persisted")
        return findings
