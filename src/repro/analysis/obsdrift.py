"""OBSDRIFT — metric call sites must match the obs plane's declarations.

`repro.obs` centralizes naming (obs/README.md): layer prefixes, the
``_total`` counter suffix, a closed label vocabulary, and the canonical
``READ_STAGES`` tuple.  Nothing enforces any of it — a typo'd stage name
or an off-vocabulary label silently forks a new series and every
dashboard aggregation quietly misses it.  This rule parses the *actual*
declarations (the ``READ_STAGES`` tuple from ``repro/obs/__init__.py``
and the prefix/label tables from ``obs/README.md``) at construction and
checks every literal-named metric call site against them:

* ``counter/gauge/histogram`` first-arg literals (including through
  function-local aliases like ``c = reg.counter``) must be snake_case
  with a declared layer prefix; counters must end ``_total``; gauges and
  histograms must not.
* literal keyword labels must be in the declared label vocabulary.
* ``.stage("...")`` literals must be members of ``READ_STAGES``.
* ``publish_stats(reg, "<prefix>", ...)`` literal prefixes must be
  declared prefixes.
* the README's stage table and the code's ``READ_STAGES`` must agree
  (checked once, reported against the obs ``__init__``).
* causal-tracing call sites (``repro.obs.trace``): literal
  ``begin_span("...")`` / ``_new_span("...")`` first args must be
  members of ``SPAN_NAMES``, literal ``end_span(..., stage="...")``
  kwargs must be members of ``CRITICAL_STAGES``, and the README's
  "Causal tracing" span/segment tables must agree with the tuples in
  ``obs/trace.py`` (reported once, against ``trace.py``).

Dynamic name arguments are skipped — the registry's own plumbing and the
tracer's ``self._registry.histogram(self._family, stage=name)`` are not
call sites this rule can or should judge.
"""

from __future__ import annotations

import ast
import os
import re

from .core import Finding, Rule, SourceFile, dotted, walk_functions

# fallbacks when the obs sources are unavailable (fixture tests)
FALLBACK_PREFIXES = ("server", "cache", "store", "engine", "fleet", "obs")
FALLBACK_LABELS = ("shard", "level", "stage", "path", "key", "index")
FALLBACK_STAGES = ("admission", "coalesce", "cache_probe", "dispatch",
                   "compute", "resolve", "value_fetch")
FALLBACK_SPANS = ("request", "queue_wait", "batch", "dispatch",
                  "shard_probe", "device_compute", "io_task",
                  "value_fetch", "write_apply", "wal_append",
                  "wal_commit", "wal_sync", "maintenance")
FALLBACK_CRITICAL = ("queue_wait", "dispatch", "device_compute",
                     "value_fetch", "wal_fsync")

_SNAKE = re.compile(r"^[a-z][a-z0-9_]*$")
_METHODS = ("counter", "gauge", "histogram")
_SPAN_METHODS = ("begin_span", "_new_span")


def _tuple_from_source(path: str, name: str):
    """Parse a module-level tuple-of-str assignment out of a source
    file via ast (``READ_STAGES``, ``SPAN_NAMES``, ``CRITICAL_STAGES``)."""
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read())
    except (OSError, SyntaxError):
        return None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name \
                        and isinstance(node.value, (ast.Tuple, ast.List)):
                    vals = [el.value for el in node.value.elts
                            if isinstance(el, ast.Constant)
                            and isinstance(el.value, str)]
                    return tuple(vals)
    return None


def _read_stages_from_init(path: str):
    """Parse the READ_STAGES tuple out of repro/obs/__init__.py via ast."""
    return _tuple_from_source(path, "READ_STAGES")


def _marked_table_from_readme(path: str, marker: str):
    """First-column backticked entries of the markdown table that
    follows the first line mentioning ``marker`` (at most one blank
    line between them)."""
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return None
    m = re.search(marker + r"[^\n]*\n(?:\s*\n)?((?:\|.*\n)+)", text)
    if not m:
        return None
    rows = re.findall(r"^\|\s*`([a-z_]+)`\s*\|", m.group(1), re.M)
    return tuple(rows) or None


def _tables_from_readme(path: str):
    """Prefixes (`server_*` style), label names (`| \\`shard=\\` |` rows)
    and stage-table entries from obs/README.md."""
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return None, None, None
    prefixes = tuple(dict.fromkeys(re.findall(r"`([a-z][a-z0-9]*)_\*`",
                                              text)))
    labels = tuple(dict.fromkeys(re.findall(r"\|\s*`([a-z_]+)=`\s*\|",
                                            text)))
    stages = None
    m = re.search(r"READ_STAGES.*?\n((?:\|.*\n)+)", text)
    if m:
        rows = re.findall(r"^\|\s*`([a-z_]+)`\s*\|", m.group(1), re.M)
        if rows:
            stages = tuple(rows)
    return prefixes or None, labels or None, stages


class ObsDriftRule(Rule):
    id = "OBSDRIFT"
    description = ("metric name/label/stage literal drifts from the obs "
                   "plane's declared conventions")

    def __init__(self, obs_init: str | None = None,
                 obs_readme: str | None = None,
                 obs_trace: str | None = None,
                 prefixes=None, labels=None, stages=None,
                 spans=None, critical=None) -> None:
        readme_prefixes = readme_labels = readme_stages = None
        readme_spans = readme_critical = None
        if obs_readme:
            readme_prefixes, readme_labels, readme_stages = \
                _tables_from_readme(obs_readme)
            readme_spans = _marked_table_from_readme(obs_readme,
                                                     "SPAN_NAMES")
            readme_critical = _marked_table_from_readme(obs_readme,
                                                        "CRITICAL_STAGES")
        init_stages = _read_stages_from_init(obs_init) if obs_init else None
        trace_spans = trace_critical = None
        if obs_trace:
            trace_spans = _tuple_from_source(obs_trace, "SPAN_NAMES")
            trace_critical = _tuple_from_source(obs_trace,
                                                "CRITICAL_STAGES")
        self.prefixes = tuple(prefixes or readme_prefixes
                              or FALLBACK_PREFIXES)
        self.labels = tuple(labels or readme_labels or FALLBACK_LABELS)
        self.stages = tuple(stages or init_stages or FALLBACK_STAGES)
        self.spans = tuple(spans or trace_spans or FALLBACK_SPANS)
        self.critical = tuple(critical or trace_critical
                              or FALLBACK_CRITICAL)
        # code-vs-README stage agreement, reported once against __init__
        self._stage_drift = None
        if init_stages is not None and readme_stages is not None \
                and tuple(init_stages) != tuple(readme_stages):
            self._stage_drift = (obs_init, init_stages, readme_stages)
        self._obs_init = obs_init
        # code-vs-README span/segment agreement, reported against trace.py
        self._trace_drift = []
        if trace_spans is not None and readme_spans is not None \
                and tuple(trace_spans) != tuple(readme_spans):
            self._trace_drift.append(
                ("SPAN_NAMES", trace_spans, readme_spans))
        if trace_critical is not None and readme_critical is not None \
                and tuple(trace_critical) != tuple(readme_critical):
            self._trace_drift.append(
                ("CRITICAL_STAGES", trace_critical, readme_critical))
        self._obs_trace = obs_trace

    @classmethod
    def from_root(cls, root: str) -> "ObsDriftRule":
        return cls(
            obs_init=os.path.join(root, "src/repro/obs/__init__.py"),
            obs_readme=os.path.join(root, "src/repro/obs/README.md"),
            obs_trace=os.path.join(root, "src/repro/obs/trace.py"))

    # ------------------------------------------------------------------

    def check(self, sf: SourceFile) -> list:
        findings: list[Finding] = []
        if self._stage_drift is not None and self._obs_init \
                and os.path.abspath(sf.path) == \
                os.path.abspath(self._obs_init):
            _, code, readme = self._stage_drift
            findings.append(Finding(
                self.id, sf.relpath, 1, 0,
                f"READ_STAGES in code {list(code)} disagrees with the "
                f"obs README stage table {list(readme)}"))
        if self._trace_drift and self._obs_trace \
                and os.path.abspath(sf.path) == \
                os.path.abspath(self._obs_trace):
            for name, code, readme in self._trace_drift:
                findings.append(Finding(
                    self.id, sf.relpath, 1, 0,
                    f"{name} in code {list(code)} disagrees with the "
                    f"obs README causal-tracing table {list(readme)}"))
        for qual, _cls, fn in walk_functions(sf.tree):
            findings.extend(self._check_fn(sf, qual, fn))
        return findings

    def _check_fn(self, sf, qual, fn):
        findings: list[Finding] = []

        def note(node, msg):
            findings.append(Finding(self.id, sf.relpath, node.lineno,
                                    node.col_offset, msg, symbol=qual))

        # function-local aliases:  c = reg.counter
        aliases: dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Attribute) \
                    and node.value.attr in _METHODS:
                aliases[node.targets[0].id] = node.value.attr

        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            kind = None
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _METHODS:
                kind = node.func.attr
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in aliases:
                kind = aliases[node.func.id]
            if kind is not None:
                self._check_metric(note, node, kind)
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SPAN_METHODS:
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str) \
                        and node.args[0].value not in self.spans:
                    note(node, f"span {node.args[0].value!r} is not in "
                               f"SPAN_NAMES {list(self.spans)}")
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "end_span":
                for kw in node.keywords:
                    if kw.arg == "stage" \
                            and isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, str) \
                            and kw.value.value not in self.critical:
                        note(node, f"critical-path stage "
                                   f"{kw.value.value!r} is not in "
                                   f"CRITICAL_STAGES "
                                   f"{list(self.critical)}")
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "stage" and node.args \
                    and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                name = node.args[0].value
                if name not in self.stages:
                    note(node, f"stage {name!r} is not in READ_STAGES "
                               f"{list(self.stages)}")
                continue
            fname = dotted(node.func).rsplit(".", 1)[-1]
            if fname == "publish_stats" and len(node.args) >= 2 \
                    and isinstance(node.args[1], ast.Constant) \
                    and isinstance(node.args[1].value, str):
                prefix = node.args[1].value
                if prefix not in self.prefixes:
                    note(node, f"publish_stats prefix {prefix!r} is not a "
                               f"declared layer prefix "
                               f"{list(self.prefixes)}")
        return findings

    def _check_metric(self, note, node, kind):
        if not node.args or not isinstance(node.args[0], ast.Constant) \
                or not isinstance(node.args[0].value, str):
            return      # dynamic name: registry plumbing, skip
        name = node.args[0].value
        if not _SNAKE.match(name):
            note(node, f"metric name {name!r} is not snake_case")
        elif name.split("_", 1)[0] not in self.prefixes:
            note(node, f"metric {name!r} lacks a declared layer prefix "
                       f"({'/'.join(p + '_' for p in self.prefixes)})")
        if kind == "counter" and not name.endswith("_total"):
            note(node, f"counter {name!r} must end in '_total'")
        if kind in ("gauge", "histogram") and name.endswith("_total"):
            note(node, f"{kind} {name!r} must not end in '_total' "
                       f"(reserved for counters)")
        for kw in node.keywords:
            if kw.arg is None:     # **labels: dynamic, skip
                continue
            if kw.arg not in self.labels:
                note(node, f"label {kw.arg!r} on {name!r} is not in the "
                           f"declared label vocabulary {list(self.labels)}")
