"""bourbonlint core: findings, suppressions, baselines, and the runner.

The framework is deliberately small: a :class:`Rule` is an object with an
``id`` and a ``check(SourceFile) -> list[Finding]`` method over the
parsed ``ast``; everything else here is the plumbing every rule shares —

* **suppressions** — ``# bourbonlint: allow[RULE] -- justification`` on
  (or immediately above) the offending line.  The justification text is
  mandatory: an allow without one does not suppress anything and instead
  raises a ``SUPPRESS`` finding, so "silenced because annoying" can't
  land without review seeing why.
* **baseline** — a checked-in JSON file of grandfathered findings keyed
  by (rule, path, symbol, message) with a count, never by line number,
  so unrelated edits don't churn it.  New findings fail the lint; fixed
  ones show up as *expired* entries to prune with ``--update-baseline``.
* **runner** — walks ``.py`` files, parses once, fans out to the rules,
  and applies suppression/baseline state to the combined findings.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import json
import os
import re
import tokenize

__all__ = ["Finding", "SourceFile", "Rule", "run_lint", "iter_py_files",
           "load_baseline", "save_baseline", "make_baseline",
           "apply_baseline", "dotted", "walk_functions", "SUPPRESS"]

SUPPRESS = "SUPPRESS"   # pseudo-rule for malformed allow comments

_ALLOW_RE = re.compile(
    r"bourbonlint:\s*allow\[([^\]]*)\]\s*(?:--\s*(\S.*))?$")


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location.

    ``symbol`` is the enclosing function's qualname (or "" at module
    scope); the baseline identity is (rule, path, symbol, message) so a
    grandfathered finding survives the file shifting under it."""
    rule: str
    path: str                 # root-relative, posix separators
    line: int
    col: int
    message: str
    symbol: str = ""
    suppressed: bool = False
    baselined: bool = False

    def key(self) -> tuple:
        return (self.rule, self.path, self.symbol, self.message)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message, "suppressed": self.suppressed,
                "baselined": self.baselined}

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}{sym}")


@dataclasses.dataclass
class _Allow:
    line: int
    rules: tuple
    justification: str | None


class SourceFile:
    """A parsed source file plus its suppression comments."""

    def __init__(self, path: str, relpath: str, text: str) -> None:
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.allows, self.bad_allows = self._parse_allows(text)

    @classmethod
    def load(cls, path: str, root: str) -> "SourceFile":
        with open(path, encoding="utf-8") as f:
            text = f.read()
        return cls(path, os.path.relpath(path, root), text)

    def _parse_allows(self, text: str):
        allows: dict[int, list[_Allow]] = {}
        bad: list[Finding] = []
        try:
            tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
        except tokenize.TokenError:
            tokens = []
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _ALLOW_RE.search(tok.string)
            if m is None:
                if "bourbonlint" in tok.string:
                    bad.append(Finding(
                        SUPPRESS, self.relpath, tok.start[0], tok.start[1],
                        "unrecognized bourbonlint comment (expected "
                        "'bourbonlint: allow[RULE] -- justification')"))
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",")
                          if r.strip())
            just = m.group(2)
            if not rules:
                bad.append(Finding(
                    SUPPRESS, self.relpath, tok.start[0], tok.start[1],
                    "allow[] names no rule"))
                continue
            if not (just and just.strip()):
                # a justification-free allow suppresses NOTHING
                bad.append(Finding(
                    SUPPRESS, self.relpath, tok.start[0], tok.start[1],
                    f"allow[{','.join(rules)}] is missing its justification "
                    f"('-- why this is safe')"))
                continue
            allows.setdefault(tok.start[0], []).append(
                _Allow(tok.start[0], rules, just.strip()))
        return allows, bad

    def allowed(self, rule: str, line: int) -> bool:
        """True when a justified allow for ``rule`` sits on ``line`` or
        the line directly above it (the standalone-comment idiom)."""
        for ln in (line, line - 1):
            for al in self.allows.get(ln, ()):
                if rule in al.rules:
                    return True
        return False


class Rule:
    """Base class: subclasses set ``id``/``description`` and implement
    ``check``.  A rule returning findings for code it cannot prove safe
    should say so in the message — suppressions exist for the remainder."""

    id = "RULE"
    description = ""

    def check(self, sf: SourceFile) -> list:
        raise NotImplementedError


# ---------------------------------------------------------------- ast helpers

def dotted(node) -> str:
    """Dotted name of an expression ("os.replace", "self.cache.fill"),
    or "" when it isn't a plain Name/Attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def walk_functions(tree):
    """Yield (qualname, classname, funcdef) for every function in the
    module, depth-first, tracking the enclosing class."""
    def visit(node, classname, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child.name,
                                 f"{prefix}{child.name}.")
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield f"{prefix}{child.name}", classname, child
                yield from visit(child, classname,
                                 f"{prefix}{child.name}.")
            else:
                yield from visit(child, classname, prefix)
    yield from visit(tree, "", "")


def match_hot(patterns, classname: str, funcname: str) -> bool:
    """fnmatch (class_glob, func_glob) pairs; module-level functions have
    classname "" and are matched by class_glob "*" or ""."""
    for cg, fg in patterns:
        if fnmatch.fnmatch(classname or "", cg or "*") \
                and fnmatch.fnmatch(funcname, fg):
            return True
    return False


# -------------------------------------------------------------------- runner

def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if not d.startswith(".")
                                 and d != "__pycache__")
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)


def run_lint(paths, rules, root: str | None = None) -> list:
    """Run ``rules`` over every .py file under ``paths``.  Returns all
    findings with ``suppressed`` already applied (the caller filters);
    malformed suppressions surface as SUPPRESS findings."""
    root = root or os.getcwd()
    findings: list[Finding] = []
    for path in iter_py_files(paths):
        try:
            sf = SourceFile.load(path, root)
        except SyntaxError as e:
            findings.append(Finding("PARSE", os.path.relpath(path, root),
                                    e.lineno or 1, 0,
                                    f"file does not parse: {e.msg}"))
            continue
        file_findings: list[Finding] = []
        for rule in rules:
            file_findings.extend(rule.check(sf))
        for f in file_findings:
            # SUPPRESS findings are not themselves suppressible
            if f.rule != SUPPRESS and sf.allowed(f.rule, f.line):
                f.suppressed = True
        findings.extend(file_findings)
        findings.extend(sf.bad_allows)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


# ------------------------------------------------------------------ baseline

BASELINE_VERSION = 1


def load_baseline(path: str) -> dict:
    if not os.path.exists(path):
        return {"version": BASELINE_VERSION, "findings": []}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version "
                         f"{data.get('version')!r}")
    return data


def save_baseline(path: str, baseline: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(baseline, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def make_baseline(findings) -> dict:
    """Baseline covering every live (non-suppressed) finding, counted per
    (rule, path, symbol, message) identity."""
    counts: dict[tuple, int] = {}
    for f in findings:
        if f.suppressed or f.rule == SUPPRESS:
            continue
        counts[f.key()] = counts.get(f.key(), 0) + 1
    entries = [{"rule": r, "path": p, "symbol": s, "message": m, "count": c}
               for (r, p, s, m), c in sorted(counts.items())]
    return {"version": BASELINE_VERSION, "findings": entries}


def apply_baseline(findings, baseline: dict) -> list:
    """Mark findings covered by the baseline as ``baselined`` (first
    ``count`` matches per identity).  Returns the *expired* baseline
    entries — grandfathered findings that no longer occur and should be
    pruned (``--update-baseline``)."""
    budget = {(e["rule"], e["path"], e["symbol"], e["message"]): e["count"]
              for e in baseline.get("findings", [])}
    used: dict[tuple, int] = {}
    for f in findings:
        if f.suppressed or f.rule == SUPPRESS:
            continue
        k = f.key()
        if used.get(k, 0) < budget.get(k, 0):
            used[k] = used.get(k, 0) + 1
            f.baselined = True
    expired = []
    for e in baseline.get("findings", []):
        k = (e["rule"], e["path"], e["symbol"], e["message"])
        if used.get(k, 0) < e["count"]:
            expired.append({**e, "count": e["count"] - used.get(k, 0)})
    return expired
