"""bourbonlint — static invariant checks for the Bourbon reproduction.

``python scripts/lint.py`` (or ``python -m repro.analysis``) runs the
five rules over ``src/repro``; see README.md in this package for the
rule table and the suppression/baseline workflow.
"""

from .core import (Finding, Rule, SourceFile, apply_baseline, load_baseline,
                   make_baseline, run_lint, save_baseline, SUPPRESS)
from .deadmod import DEAD_MODULE_ALLOWLIST, dead_module_report
from .durorder import DurabilityOrderRule
from .hotsync import HotSyncRule
from .jitdisc import JitDisciplineRule
from .obsdrift import ObsDriftRule
from .pairing import PairingRule

ALL_RULES = ("HOTSYNC", "DURORDER", "JITDISC", "PAIRING", "OBSDRIFT")

__all__ = ["Finding", "Rule", "SourceFile", "run_lint", "default_rules",
           "ALL_RULES", "load_baseline", "save_baseline", "make_baseline",
           "apply_baseline", "dead_module_report", "DEAD_MODULE_ALLOWLIST",
           "HotSyncRule", "DurabilityOrderRule", "JitDisciplineRule",
           "PairingRule", "ObsDriftRule", "SUPPRESS"]


def default_rules(root: str, only=None):
    """The production rule set, calibrated against this repo (OBSDRIFT
    reads the live declarations under ``root``).  ``only`` filters by
    rule id."""
    rules = [
        HotSyncRule(),
        DurabilityOrderRule(),
        JitDisciplineRule(),
        PairingRule(),
        ObsDriftRule.from_root(root),
    ]
    if only:
        wanted = {r.upper() for r in only}
        rules = [r for r in rules if r.id in wanted]
    return rules
