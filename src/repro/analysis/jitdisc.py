"""JITDISC — jit compilation discipline.

PR 5's ``trace_count`` counter catches retraces at runtime; this rule
catches the three patterns that cause them at review time:

1. **jit-in-loop** — ``jax.jit(...)`` (or ``partial(jax.jit, ...)``)
   called inside a ``for``/``while`` body builds a fresh compiled
   callable (and cache entry) per iteration.
2. **mutable-self capture** — a jit-wrapped lambda / local ``def`` whose
   body reads ``self.<attr>``: the closure captures the *object*, so a
   later attribute mutation silently changes semantics without a
   retrace, or — if the attr feeds shapes — retraces every call.
3. **tracer truthiness** — a plain ``if``/``while`` on a value that is a
   tracer inside a traced function burns the branch into the compiled
   graph (or raises ``TracerBoolConversionError``).  Static arguments
   (``static_argnums``/``static_argnames``), parameters annotated with
   Python scalar/str/tuple types, and anything derived from ``.shape`` /
   ``.ndim`` / ``.dtype`` / ``len()`` / ``range()`` are exempt.

Traced functions are: functions decorated with ``jax.jit`` /
``partial(jax.jit, ...)``, local defs passed to ``jax.jit(name)``, plus
configured ``extra_traced`` qualname globs for functions that are only
ever called from inside a jitted wrapper (the engine's ``_lookup_impl``
family).  ``jax.jit(<call>(...))`` is skipped — the callee isn't
resolvable statically.
"""

from __future__ import annotations

import ast
import fnmatch

from .core import Finding, Rule, SourceFile, dotted

# functions jitted indirectly (called only under an outer jit) — the
# truthiness check applies inside them too
DEFAULT_EXTRA_TRACED = (
    "LookupEngine._lookup_impl",
    "LookupEngine._probe_file_baseline",
    "LookupEngine._probe_file_model",
    "LookupEngine._probe_level_via_model",
    "LookupEngine._find_file",
    "binsearch_rows",
    "count_le_rows",
    "bloom_probe_rows",
)

_STATIC_ANNOTATIONS = {"str", "int", "bool", "float", "tuple", "bytes"}
_TAINT_KILLERS = {"shape", "ndim", "dtype", "size"}


def _is_jit_expr(node) -> bool:
    """True for ``jax.jit`` / ``jit`` names and ``partial(jax.jit, ...)``."""
    name = dotted(node)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call) and dotted(node.func) in (
            "partial", "functools.partial"):
        return bool(node.args) and _is_jit_expr(node.args[0])
    return False


def _jit_call(node):
    """If ``node`` is a Call invoking jax.jit (directly or via partial),
    return it, else None."""
    if isinstance(node, ast.Call) and _is_jit_expr(node.func):
        return node
    return None


def _static_params(fn, jit_call) -> set:
    """Parameter names made static by static_argnums/static_argnames on
    the jit call/decorator, plus scalar-annotated and literal-default
    parameters."""
    static: set = set()
    args = fn.args
    posnames = [a.arg for a in args.posonlyargs + args.args]
    if jit_call is not None:
        for kw in jit_call.keywords:
            if kw.arg == "static_argnames":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value,
                                                                   str):
                        static.add(el.value)
            elif kw.arg == "static_argnums":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value,
                                                                   int):
                        if 0 <= el.value < len(posnames):
                            static.add(posnames[el.value])
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        ann = a.annotation
        if ann is not None:
            ann_name = dotted(ann)
            if isinstance(ann, ast.Subscript):
                ann_name = dotted(ann.value)
            if ann_name.rsplit(".", 1)[-1].lower() in _STATIC_ANNOTATIONS:
                static.add(a.arg)
    defaults = args.defaults
    for a, d in zip(args.args[len(args.args) - len(defaults):], defaults):
        if isinstance(d, ast.Constant):
            static.add(a.arg)
    return static


class JitDisciplineRule(Rule):
    id = "JITDISC"
    description = ("jax.jit callable defined in a loop, closing over "
                   "mutable self state, or branching on a tracer")

    def __init__(self, extra_traced=DEFAULT_EXTRA_TRACED) -> None:
        self.extra_traced = tuple(extra_traced)

    # ----------------------------------------------------------- checks

    def check(self, sf: SourceFile) -> list:
        findings: list[Finding] = []
        findings.extend(self._check_jit_sites(sf))
        findings.extend(self._check_truthiness(sf))
        return findings

    def _check_jit_sites(self, sf: SourceFile) -> list:
        from .core import walk_functions
        findings: list[Finding] = []
        # map local function name -> def node, per module (for
        # jax.jit(name) resolution)
        local_defs: dict[str, ast.AST] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                local_defs[node.name] = node

        # 1. jit calls inside loop bodies
        for qual, _cls, fn in walk_functions(sf.tree):
            for loop in ast.walk(fn):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for sub in ast.walk(loop):
                    call = _jit_call(sub)
                    if call is not None:
                        findings.append(Finding(
                            self.id, sf.relpath, sub.lineno, sub.col_offset,
                            "jax.jit called inside a loop body compiles a "
                            "fresh callable every iteration; hoist it",
                            symbol=qual))

        # 2. jit-wrapped callables reading self.<attr>
        for node in ast.walk(sf.tree):
            call = _jit_call(node)
            if call is None or not call.args:
                continue
            target = call.args[0]
            body = None
            if isinstance(target, ast.Lambda):
                body = target
            elif isinstance(target, ast.Name) and target.id in local_defs:
                body = local_defs[target.id]
            elif isinstance(target, ast.Call):
                continue    # jax.jit(make_fn(...)) — not resolvable
            if body is None:
                continue
            attrs = sorted({
                d for sub in ast.walk(body)
                if isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "self"
                for d in (sub.attr,)})
            if attrs:
                findings.append(Finding(
                    self.id, sf.relpath, call.lineno, call.col_offset,
                    f"jit-wrapped callable closes over mutable self state "
                    f"({', '.join('self.' + a for a in attrs)}); pass it as "
                    f"an argument or bind immutable locals",
                ))
        return findings

    # ------------------------------------------------- tracer truthiness

    def _traced_functions(self, sf: SourceFile):
        """Yield (qualname, fn, jit_call_or_None) for every function whose
        body executes under jax tracing."""
        from .core import walk_functions
        jitted_names: dict[str, ast.Call] = {}
        for node in ast.walk(sf.tree):
            call = _jit_call(node)
            if call is not None and call.args \
                    and isinstance(call.args[0], ast.Name):
                jitted_names[call.args[0].id] = call
        for qual, _cls, fn in walk_functions(sf.tree):
            jit_call = None
            traced = False
            for dec in fn.decorator_list:
                if _is_jit_expr(dec):
                    traced = True
                    if isinstance(dec, ast.Call):
                        jit_call = dec
                    break
            if not traced and fn.name in jitted_names:
                traced, jit_call = True, jitted_names[fn.name]
            if not traced and any(fnmatch.fnmatch(qual, g) or
                                  fnmatch.fnmatch(fn.name, g)
                                  for g in self.extra_traced):
                traced = True
            if traced:
                yield qual, fn, jit_call

    def _check_truthiness(self, sf: SourceFile) -> list:
        findings: list[Finding] = []
        for qual, fn, jit_call in self._traced_functions(sf):
            static = _static_params(fn, jit_call)
            self._scan_body(sf, qual, fn, set(static), findings)
        return findings

    def _scan_body(self, sf, qual, fn, static, findings, seed_dynamic=()):
        """Walk statements in order, tracking which names are static."""

        def expr_static(node) -> bool:
            if isinstance(node, ast.Constant):
                return True
            if isinstance(node, ast.Name):
                return node.id in static or node.id not in assigned_dynamic
            if isinstance(node, ast.Attribute):
                if node.attr in _TAINT_KILLERS:
                    return True
                return expr_static(node.value)
            if isinstance(node, ast.Subscript):
                return expr_static(node.value)
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                last = name.rsplit(".", 1)[-1]
                if last in ("len", "range", "isinstance", "hasattr", "zip",
                            "enumerate", "tuple", "sorted"):
                    return True
                if name.startswith(("jnp.", "jax.", "lax.")):
                    return False
                # method on a static value (e.g. mode.startswith) is static
                if isinstance(node.func, ast.Attribute):
                    return expr_static(node.func.value)
                return False
            if isinstance(node, ast.Compare):
                return expr_static(node.left) and all(
                    expr_static(c) for c in node.comparators)
            if isinstance(node, ast.BoolOp):
                return all(expr_static(v) for v in node.values)
            if isinstance(node, ast.UnaryOp):
                return expr_static(node.operand)
            if isinstance(node, ast.BinOp):
                return expr_static(node.left) and expr_static(node.right)
            if isinstance(node, (ast.Tuple, ast.List)):
                return all(expr_static(e) for e in node.elts)
            return False

        # names assigned from dynamic (array-typed) expressions; every
        # parameter not proven static starts dynamic — unannotated params
        # of a jitted function are exactly the tracers
        assigned_dynamic: set = set(seed_dynamic)
        a = fn.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg not in static and p.arg not in ("self", "cls"):
                assigned_dynamic.add(p.arg)

        def mark_assign(tgt, is_static):
            if isinstance(tgt, ast.Name):
                if is_static:
                    static.add(tgt.id)
                    assigned_dynamic.discard(tgt.id)
                else:
                    static.discard(tgt.id)
                    assigned_dynamic.add(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    mark_assign(el, is_static)

        def visit(stmts):
            for st in stmts:
                if isinstance(st, ast.Assign):
                    s = expr_static(st.value)
                    for tgt in st.targets:
                        mark_assign(tgt, s)
                elif isinstance(st, ast.AnnAssign) and st.value is not None:
                    mark_assign(st.target, expr_static(st.value))
                elif isinstance(st, ast.AugAssign):
                    pass
                elif isinstance(st, (ast.If, ast.While)):
                    if not expr_static(st.test):
                        findings.append(Finding(
                            self.id, sf.relpath, st.lineno, st.col_offset,
                            "python truthiness branch on a traced value "
                            "inside a jitted function; use lax.cond/"
                            "jnp.where or make the operand static",
                            symbol=qual))
                    visit(st.body)
                    visit(st.orelse)
                elif isinstance(st, ast.For):
                    # range()/static iterables unroll fine; iterating a
                    # tracer raises at trace time anyway
                    mark_assign(st.target, True)
                    visit(st.body)
                    visit(st.orelse)
                elif isinstance(st, (ast.With,)):
                    visit(st.body)
                elif isinstance(st, ast.Try):
                    visit(st.body)
                    for h in st.handlers:
                        visit(h.body)
                    visit(st.orelse)
                    visit(st.finalbody)
                elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # nested defs trace under the same jit; they see the
                    # outer static env plus their own annotations
                    inner_static = set(static) | _static_params(st, None)
                    self._scan_body_nested(sf, qual, st, inner_static,
                                           assigned_dynamic, findings)

        visit(fn.body)

    def _scan_body_nested(self, sf, qual, fn, static, outer_dynamic,
                          findings):
        # reuse the same machinery with the combined closure environment
        self._scan_body(sf, f"{qual}.{fn.name}", fn, set(static), findings,
                        seed_dynamic=outer_dynamic)
