"""Durable storage for BourbonStore: WAL, on-disk SSTables with persisted
PLR models, MANIFEST version edits, and a segmented value log with
WiscKey-style garbage collection.  See README.md in this directory for the
file formats and the recovery/GC protocols."""

from .engine import StorageEngine
from .manifest import (ManifestState, ManifestWriter, checkpoint_edit,
                       read_manifest, set_current)
from .recovery import load_tables
from .sstable_io import (append_model, load_level_filter, load_level_model,
                         load_sstable, write_level_filter, write_level_model,
                         write_sstable)
from .vlog import DurableValueLog
from .wal import WALWriter, replay_wal

__all__ = [
    "StorageEngine", "ManifestState", "ManifestWriter", "checkpoint_edit",
    "read_manifest", "set_current", "load_tables", "append_model",
    "load_sstable", "write_sstable", "load_level_model", "write_level_model",
    "load_level_filter", "write_level_filter",
    "DurableValueLog", "WALWriter", "replay_wal",
]
