"""Recovery: MANIFEST replay -> live SSTables (mmap) -> WAL re-ingestion.

MANIFEST replay is checkpoint-then-tail: after a checkpoint compaction the
file named by CURRENT starts with one edit holding the entire folded state
(live files, counters, reclaimed segments, per-segment dead-entry
estimates), followed by whatever edits appended since — replaying in order
needs no special casing.  Orphan numbered manifests from a crash
mid-checkpoint are swept by ``StorageEngine`` before the writer reopens.

``load_tables`` turns a replayed :class:`ManifestState` into per-level
lists of mmap-backed :class:`SSTable` objects, with their persisted PLR
models reconstructed (no retraining — the whole point of serializing the
segments into the table files).  Unreferenced ``.sst`` files (a crash
between file write and manifest edit) are deleted as garbage.

The store drives the rest of the protocol: it re-ingests the old WAL's
batches through its normal write path (so they land in the fresh WAL and,
if the memtable fills, in new sstables), restores the value log's GC
bookkeeping (``vlog_removed``, ``vlog_dead``), then calls
``StorageEngine.finish_recovery``.
"""

from __future__ import annotations

import os

from repro.core.lsm import N_LEVELS
from repro.core.sstable import SSTable, advance_file_ids

from .format import sst_path
from .manifest import ManifestState
from .sstable_io import load_sstable

__all__ = ["load_tables"]


def load_tables(dirpath: str, state: ManifestState,
                verify: bool = True) -> list[list[SSTable]]:
    """Returns levels[0..N_LEVELS-1] rebuilt from the manifest's live set.

    L0 is ordered newest-first (higher file_id = later flush); deeper
    levels are sorted by min_key (disjoint ranges).
    """
    levels: list[list[SSTable]] = [[] for _ in range(N_LEVELS)]
    for fid, level in state.live.items():
        t = load_sstable(sst_path(dirpath, fid), verify=verify)
        if t.level != level or t.file_id != fid:
            raise ValueError(
                f"manifest/file mismatch for {fid}: "
                f"file says (id={t.file_id}, level={t.level}), "
                f"manifest says level {level}")
        levels[level].append(t)
    levels[0].sort(key=lambda t: t.file_id, reverse=True)
    for li in range(1, N_LEVELS):
        levels[li].sort(key=lambda t: t.min_key)
    if state.live:
        advance_file_ids(max(state.live) + 1)

    # sweep unreferenced table files (crash between write and manifest
    # edit), orphaned .tmp files (crash before the atomic os.replace), and
    # level-model sidecars the manifest no longer names (superseded epoch,
    # or an lmodel edit that tore before acknowledging the file)
    for name in os.listdir(dirpath):
        if name.endswith(".tmp"):
            os.unlink(os.path.join(dirpath, name))
        elif name.endswith(".sst"):
            fid = int(name.split(".")[0])
            if fid not in state.live:
                os.unlink(os.path.join(dirpath, name))
        elif name.startswith("lm-") and name.endswith(".plm"):
            level, epoch = (int(p) for p in name[3:-4].split("-"))
            if state.level_models.get(level) != epoch:
                os.unlink(os.path.join(dirpath, name))
        elif name.startswith("flt-") and name.endswith(".bf"):
            level, epoch = (int(p) for p in name[4:-3].split("-"))
            if state.filters.get(level) != epoch:
                os.unlink(os.path.join(dirpath, name))
    return levels
