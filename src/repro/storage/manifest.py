"""MANIFEST: a version-edit log tracking live files across flushes,
compactions, and value-log GC (LevelDB-style, one JSON edit per frame).

Each edit may carry::

    add       [[file_id, level], ...]   tables that became live
    del       [file_id, ...]            tables retired by compaction
    wal       int                       current WAL number after rotation
    seq       int                       next sequence number high-water mark
    clock     float                     virtual-clock high-water mark
    vhead     int                       value-log head (next global slot)
    vlog_rm   [segment_id, ...]         value-log segments reclaimed by GC
    vsize     int                       value size (fixed at creation)
    vslots    int                       value-log slots per segment
    pdelta    int                       PLR error bound models were fit with
    vdead     {seg: n_dead}             dead-entry estimates, full snapshot
                                        (replaces; checkpoint edits only)
    vdead_d   {seg: n_dead}             dead-entry estimates, delta (merges
                                        absolute per-segment counts — keeps
                                        ordinary edits O(changed), not
                                        O(total segments))
    lmodel    {level: epoch}            level-granularity PLR model published
                                        for a level; the segments live in the
                                        ``lm-<level>-<epoch>.plm`` sidecar.
                                        Any add/del touching a level drops its
                                        record first (a structural change
                                        invalidates the model), so replay
                                        order alone decides validity.
    filter    {level: epoch}            level bloom filter published for a
                                        level; the bits live in the
                                        ``flt-<level>-<epoch>.bf`` sidecar.
                                        Same touched-level invalidation rule
                                        as lmodel.

``CURRENT`` names the live manifest file.  Replaying the edits in order
yields the exact live-file set and counters; frames use the shared
crc-framed encoding, so a torn final edit is dropped (its files were
written with ``os.replace`` and simply become unreferenced garbage).

The edit log is folded once it grows past a threshold
(:func:`checkpoint_edit` + ``StorageEngine.checkpoint``): the live state
becomes the single first edit of ``MANIFEST-<no+1>``, CURRENT switches
atomically, and the old file is deleted.  Recovery is unchanged — it
replays checkpoint-then-tail like any other edit sequence.
"""

from __future__ import annotations

import dataclasses
import json
import os

from .format import (CURRENT, FRAME_HDR_SIZE, fsync_dir, manifest_name,
                     read_frames, valid_frames_end, write_frame)

__all__ = ["ManifestState", "ManifestWriter", "read_manifest",
           "checkpoint_edit", "set_current"]


@dataclasses.dataclass
class ManifestState:
    live: dict          # file_id -> level
    wal_no: int = 0
    seq: int = 0
    clock: float = 0.0
    vhead: int = 0
    vlog_removed: set = dataclasses.field(default_factory=set)
    vlog_dead: dict = dataclasses.field(default_factory=dict)  # seg -> n_dead
    value_size: int | None = None   # vlog entry geometry, fixed at creation
    seg_slots: int | None = None
    plr_delta: int | None = None    # error bound the persisted models carry
    level_models: dict = dataclasses.field(default_factory=dict)  # lvl -> epoch
    filters: dict = dataclasses.field(default_factory=dict)       # lvl -> epoch

    def apply(self, edit: dict) -> None:
        if "vsize" in edit:
            self.value_size = edit["vsize"]
        if "vslots" in edit:
            self.seg_slots = edit["vslots"]
        if "pdelta" in edit:
            self.plr_delta = edit["pdelta"]
        # a structural change at a level invalidates its persisted level
        # model; resolve deleted files to levels BEFORE popping them
        touched = {self.live[fid] for fid in edit.get("del", [])
                   if fid in self.live}
        touched |= {level for _, level in edit.get("add", [])}
        for fid in edit.get("del", []):
            self.live.pop(fid, None)
        for fid, level in edit.get("add", []):
            self.live[fid] = level
        for level in touched:
            self.level_models.pop(level, None)
            self.filters.pop(level, None)
        # applied after the invalidation so a checkpoint edit carrying both
        # the full live set and the lmodel/filter records keeps them
        for level, epoch in edit.get("lmodel", {}).items():
            self.level_models[int(level)] = int(epoch)
        for level, epoch in edit.get("filter", {}).items():
            self.filters[int(level)] = int(epoch)
        if "wal" in edit:
            self.wal_no = edit["wal"]
        if "seq" in edit:
            self.seq = max(self.seq, edit["seq"])
        if "clock" in edit:
            self.clock = max(self.clock, edit["clock"])
        if "vhead" in edit:
            self.vhead = max(self.vhead, edit["vhead"])
        if "vdead" in edit:   # full snapshot, not a delta: last edit wins
            self.vlog_dead = {int(s): int(c)
                              for s, c in edit["vdead"].items()}
        for s, c in edit.get("vdead_d", {}).items():   # delta: merge
            self.vlog_dead[int(s)] = int(c)
        for seg in edit.get("vlog_rm", []):   # reclaimed: estimate retired
            self.vlog_removed.add(seg)
            self.vlog_dead.pop(seg, None)


class ManifestWriter:
    def __init__(self, dirpath: str, no: int = 1, fsync: bool = False,
                 publish: bool = True) -> None:
        self.path = os.path.join(dirpath, manifest_name(no))
        self.no = no
        self.fsync = fsync
        # drop a crash-torn trailing frame before appending: edits written
        # after garbage bytes would be invisible to every future replay
        end = valid_frames_end(self.path)
        if os.path.exists(self.path) and os.path.getsize(self.path) != end:
            with open(self.path, "r+b") as f:
                f.truncate(end)
        self._size = end
        self.base = 0   # bytes at the last checkpoint (tail = size - base)
        self._f = open(self.path, "ab")
        # publish=False: checkpoint writers stay unreferenced until their
        # checkpoint edit is durable, then set_current switches atomically
        if publish and not os.path.exists(os.path.join(dirpath, CURRENT)):
            set_current(dirpath, no, fsync)

    def append(self, edit: dict) -> None:
        payload = json.dumps(edit, sort_keys=True).encode()
        write_frame(self._f, payload)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._size += FRAME_HDR_SIZE + len(payload)

    def size(self) -> int:
        """Bytes of valid edit log (drives checkpoint scheduling)."""
        return self._size

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def set_current(dirpath: str, no: int, fsync: bool = False) -> None:
    """Atomically point CURRENT at MANIFEST-<no> (write-tmp + rename)."""
    current = os.path.join(dirpath, CURRENT)
    tmp = current + ".tmp"
    with open(tmp, "w") as f:
        f.write(manifest_name(no))
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, current)
    if fsync:
        fsync_dir(dirpath)


def checkpoint_edit(state: ManifestState) -> dict:
    """One edit that replays to exactly ``state`` from an empty log."""
    edit = {
        "add": sorted([fid, lvl] for fid, lvl in state.live.items()),
        "wal": state.wal_no, "seq": state.seq, "clock": state.clock,
        "vhead": state.vhead, "vlog_rm": sorted(state.vlog_removed),
        "vdead": {str(s): c for s, c in sorted(state.vlog_dead.items())},
    }
    if state.value_size is not None:
        edit.update(vsize=state.value_size, vslots=state.seg_slots,
                    pdelta=state.plr_delta)
    if state.level_models:
        edit["lmodel"] = {str(l): e
                          for l, e in sorted(state.level_models.items())}
    if state.filters:
        edit["filter"] = {str(l): e for l, e in sorted(state.filters.items())}
    return edit


def read_manifest(dirpath: str) -> tuple[ManifestState, int] | None:
    """Replay the manifest named by CURRENT; None if the dir is fresh."""
    current = os.path.join(dirpath, CURRENT)
    if not os.path.exists(current):
        return None
    with open(current) as f:
        name = f.read().strip()
    no = int(name.rsplit("-", 1)[1])
    path = os.path.join(dirpath, name)
    if not os.path.exists(path):
        # dangling CURRENT must be an error, never an empty store: replaying
        # "no frames" here would make recovery sweep every live file as
        # unreferenced garbage — silent total data loss
        raise FileNotFoundError(
            f"CURRENT names {name!r} but it does not exist in {dirpath!r}")
    state = ManifestState(live={})
    for payload in read_frames(path):
        state.apply(json.loads(payload.decode()))
    return state, no
