"""MANIFEST: a version-edit log tracking live files across flushes,
compactions, and value-log GC (LevelDB-style, one JSON edit per frame).

Each edit may carry::

    add       [[file_id, level], ...]   tables that became live
    del       [file_id, ...]            tables retired by compaction
    wal       int                       current WAL number after rotation
    seq       int                       next sequence number high-water mark
    clock     float                     virtual-clock high-water mark
    vhead     int                       value-log head (next global slot)
    vlog_rm   [segment_id, ...]         value-log segments reclaimed by GC
    vsize     int                       value size (fixed at creation)
    vslots    int                       value-log slots per segment
    pdelta    int                       PLR error bound models were fit with

``CURRENT`` names the live manifest file.  Replaying the edits in order
yields the exact live-file set and counters; frames use the shared
crc-framed encoding, so a torn final edit is dropped (its files were
written with ``os.replace`` and simply become unreferenced garbage).
"""

from __future__ import annotations

import dataclasses
import json
import os

from .format import (CURRENT, fsync_dir, manifest_name, read_frames,
                     valid_frames_end, write_frame)

__all__ = ["ManifestState", "ManifestWriter", "read_manifest"]


@dataclasses.dataclass
class ManifestState:
    live: dict          # file_id -> level
    wal_no: int = 0
    seq: int = 0
    clock: float = 0.0
    vhead: int = 0
    vlog_removed: set = dataclasses.field(default_factory=set)
    value_size: int | None = None   # vlog entry geometry, fixed at creation
    seg_slots: int | None = None
    plr_delta: int | None = None    # error bound the persisted models carry

    def apply(self, edit: dict) -> None:
        if "vsize" in edit:
            self.value_size = edit["vsize"]
        if "vslots" in edit:
            self.seg_slots = edit["vslots"]
        if "pdelta" in edit:
            self.plr_delta = edit["pdelta"]
        for fid in edit.get("del", []):
            self.live.pop(fid, None)
        for fid, level in edit.get("add", []):
            self.live[fid] = level
        if "wal" in edit:
            self.wal_no = edit["wal"]
        if "seq" in edit:
            self.seq = max(self.seq, edit["seq"])
        if "clock" in edit:
            self.clock = max(self.clock, edit["clock"])
        if "vhead" in edit:
            self.vhead = max(self.vhead, edit["vhead"])
        for seg in edit.get("vlog_rm", []):
            self.vlog_removed.add(seg)


class ManifestWriter:
    def __init__(self, dirpath: str, no: int = 1, fsync: bool = False) -> None:
        self.path = os.path.join(dirpath, manifest_name(no))
        self.fsync = fsync
        # drop a crash-torn trailing frame before appending: edits written
        # after garbage bytes would be invisible to every future replay
        end = valid_frames_end(self.path)
        if os.path.exists(self.path) and os.path.getsize(self.path) != end:
            with open(self.path, "r+b") as f:
                f.truncate(end)
        self._f = open(self.path, "ab")
        current = os.path.join(dirpath, CURRENT)
        if not os.path.exists(current):
            tmp = current + ".tmp"
            with open(tmp, "w") as f:
                f.write(manifest_name(no))
                if fsync:
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, current)
            if fsync:
                fsync_dir(dirpath)

    def append(self, edit: dict) -> None:
        write_frame(self._f, json.dumps(edit, sort_keys=True).encode())
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def read_manifest(dirpath: str) -> tuple[ManifestState, int] | None:
    """Replay the manifest named by CURRENT; None if the dir is fresh."""
    current = os.path.join(dirpath, CURRENT)
    if not os.path.exists(current):
        return None
    with open(current) as f:
        name = f.read().strip()
    no = int(name.rsplit("-", 1)[1])
    state = ManifestState(live={})
    for payload in read_frames(os.path.join(dirpath, name)):
        state.apply(json.loads(payload.decode()))
    return state, no
