"""Write-ahead log: memtable contents survive a crash.

One frame per ingested sub-batch: ``[op u8][count u32]`` followed by the
raw ``keys/seqs/vptrs`` int64 arrays.  Tombstones ride as ordinary records
with ``vptr == -1``, so a single record type covers puts and deletes.

The WAL is rotated at every flush: once the drained memtable is durable as
an SSTable (and the MANIFEST edit recording it is on disk), a fresh
``wal-<n+1>.log`` starts and the old file is deleted.  Replay therefore
only ever concerns records newer than the last flush.

Two writers share the frame format:

* :class:`WALWriter` — per-append durability: every ``append`` flushes
  (and fsyncs when enabled) before returning.
* :class:`GroupCommitWAL` — group commit: ``append`` only *enqueues* the
  frame (acknowledged-but-not-yet-durable); when ``sync()`` sets the
  durability barrier, a background committer thread writes every queued
  frame in append order under ONE flush+fsync, so N producer batches
  amortize into one disk sync.  ``sync()`` is the durability point; the
  commit contract is documented in ``src/repro/storage/README.md``.
"""

from __future__ import annotations

import os
import struct
import threading
import time

import numpy as np

from repro.obs import NULL_CTRACE

from .format import fsync_dir, read_frames, write_frame

__all__ = ["GroupCommitWAL", "WALWriter", "replay_wal"]

_REC_HDR = struct.Struct("<BI")
_OP_PUT = 1


def _pack_frame(keys: np.ndarray, seqs: np.ndarray,
                vptrs: np.ndarray) -> bytes:
    return (_REC_HDR.pack(_OP_PUT, keys.shape[0])
            + np.ascontiguousarray(keys, np.int64).tobytes()
            + np.ascontiguousarray(seqs, np.int64).tobytes()
            + np.ascontiguousarray(vptrs, np.int64).tobytes())


class WALWriter:
    def __init__(self, path: str, fsync: bool = False) -> None:
        self.path = path
        self.fsync = fsync
        self.appends = 0
        self.fsyncs = 0
        self.commits = 0     # disk syncs (flush groups); == appends here
        # causal tracer (repro.obs.trace): set by the engine when the
        # store attaches an obs plane; one attribute read per append
        # while no traced write is in flight
        self.tracer = NULL_CTRACE
        created = not os.path.exists(path)
        self._f = open(path, "ab")
        if fsync and created:
            fsync_dir(os.path.dirname(path))  # the new entry must persist

    def append(self, keys: np.ndarray, seqs: np.ndarray,
               vptrs: np.ndarray) -> None:
        # per-append durability: the append span covers its own commit
        tsp = self.tracer.wal_append()
        write_frame(self._f, _pack_frame(keys, seqs, vptrs))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
            self.fsyncs += 1
        self.appends += 1
        self.commits += 1
        self.tracer.end_span(tsp, stage="wal_fsync")

    def sync(self) -> None:
        """Per-append durability means there is nothing left to wait for
        — kept so callers hold one WAL interface across both writers."""

    def drain_batch_sizes(self) -> list[int]:
        return []

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class GroupCommitWAL:
    """Group-commit WAL writer (leader/follower collapsed into one
    dedicated committer thread).

    ``append`` packs the frame and enqueues it — the write is then
    *acknowledged* (ordered, will be replayed after any crash that
    happens once it is synced) but not yet durable.  The committer is
    **sync-driven**: it stays idle until a ``sync()`` barrier arrives
    (or ``group_cap`` frames pile up — the memory bound), then drains
    **everything** queued, writes the frames in append order, and issues
    one ``flush`` (+``fsync`` when enabled) for the whole group.  Every
    append between two sync barriers therefore lands in the same commit
    — the coalesce factor equals the producer's batching, not scheduler
    luck.  ``sync()`` blocks until every frame enqueued before the call
    is durable.

    A crash loses at most the un-synced suffix: frames hit the file
    strictly in append order, so the on-disk WAL is always a clean
    prefix of the acknowledged stream (``replay_wal`` already tolerates
    a torn trailing frame).  ``crash()`` simulates exactly that for the
    recovery tests — queued frames are dropped, the file is abandoned
    as-is.
    """

    def __init__(self, path: str, fsync: bool = False,
                 group_cap: int = 256) -> None:
        self.path = path
        self.fsync = fsync
        self.group_cap = group_cap        # commit early past this many frames
        self.appends = 0
        self.fsyncs = 0
        self.commits = 0                  # commit groups written
        # causal tracer (repro.obs.trace): set by the engine at obs
        # attach; wal_append() is one attribute read when untraced
        self.tracer = NULL_CTRACE
        created = not os.path.exists(path)
        self._f = open(path, "ab")
        if fsync and created:
            fsync_dir(os.path.dirname(path))
        self._cv = threading.Condition()
        self._pending: list[bytes] = []
        # wal_append spans of the frames in _pending (traced writes only;
        # drained with the batch so each commit group ends exactly the
        # appends it made durable)
        self._trace_appends: list = []
        self._enqueued = 0
        self._durable = 0
        self._sync_upto = 0               # highest sync barrier requested
        self._closing = False
        self._crashed = False
        self._hold = False                # test hook: freeze the committer
        self._batch_sizes: list[int] = []  # drained by the obs collector
        self._exc: BaseException | None = None
        self._thread = threading.Thread(target=self._run,
                                        name="wal-commit", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- producers
    def append(self, keys: np.ndarray, seqs: np.ndarray,
               vptrs: np.ndarray) -> None:
        payload = _pack_frame(keys, seqs, vptrs)
        tsp = self.tracer.wal_append()    # enqueue->durable span, or None
        with self._cv:
            if self._exc is not None:
                raise self._exc
            if self._closing:
                raise RuntimeError("append on a closed GroupCommitWAL")
            self._pending.append(payload)
            if tsp is not None:
                self._trace_appends.append(tsp)
            self._enqueued += 1
            self.appends += 1
            self._cv.notify_all()

    def sync(self) -> None:
        """Block until everything enqueued so far is durable.  A commit
        I/O error surfaces here (and on the next append) instead of
        vanishing in the committer thread."""
        with self._cv:
            target = self._enqueued
            self._sync_upto = max(self._sync_upto, target)
            self._cv.notify_all()          # wake the committer: barrier set
            while self._durable < target and self._exc is None:
                self._cv.wait()
            if self._exc is not None:
                raise self._exc

    # ------------------------------------------------------------- committer
    def _run(self) -> None:
        while True:
            with self._cv:
                # sync-driven: sleep until a sync barrier wants frames
                # committed, or the pending group hits the memory cap, or
                # lifecycle (close drains, crash stops)
                while not self._closing and not self._crashed and (
                        self._hold
                        or not self._pending
                        or (self._sync_upto <= self._durable
                            and len(self._pending) < self.group_cap)):
                    self._cv.wait()
                if self._crashed:
                    return
                if self._closing and not self._pending:
                    return
                batch = self._pending
                self._pending = []
                tspans = self._trace_appends
                self._trace_appends = []
            t_commit = time.perf_counter()
            try:
                for payload in batch:
                    write_frame(self._f, payload)
                self._f.flush()
                if self.fsync:
                    os.fsync(self._f.fileno())
            except BaseException as exc:   # park it; sync/append re-raise
                with self._cv:
                    self._exc = exc
                    self._cv.notify_all()
                return
            if tspans:
                # fan-in: M appends -> one commit group.  Ends each append
                # span at durability (crediting wal_fsync) BEFORE _durable
                # moves, so a sync()ing producer reads quiesced segments
                self.tracer.wal_commit(tspans, t_commit)
            with self._cv:
                self._durable += len(batch)
                self.commits += 1
                if self.fsync:
                    self.fsyncs += 1
                if len(self._batch_sizes) < 4096:  # bounded: obs drains it
                    self._batch_sizes.append(len(batch))
                self._cv.notify_all()

    # ------------------------------------------------------------- lifecycle
    def drain_batch_sizes(self) -> list[int]:
        """Hand the accumulated per-commit group sizes to the caller (the
        obs collector's fsync-batch-size histogram) and reset the list."""
        with self._cv:
            out = self._batch_sizes
            self._batch_sizes = []
        return out

    def close(self) -> None:
        """Quiesce: drain every queued frame (one final group commit),
        stop the committer, close the file.  Rotation and clean shutdown
        go through here, so a rotated-away WAL never strands frames."""
        with self._cv:
            if self._closing or self._crashed:
                return
            self._hold = False
            self._closing = True
            self._cv.notify_all()
        self._thread.join()
        if self._exc is None:
            self._durable = self._enqueued
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def crash(self) -> None:
        """Crash injection (tests): drop the queued un-synced frames and
        abandon the file exactly as a power loss mid-coalesce would —
        the on-disk WAL keeps only the already-committed prefix."""
        with self._cv:
            self._crashed = True
            self._pending = []
            self._trace_appends = []
            self._cv.notify_all()
        self._thread.join()
        if not self._f.closed:
            # nothing un-committed is buffered in the file object (frames
            # wait in _pending until a commit group writes AND flushes
            # them), so closing here leaks no extra bytes to disk
            self._f.close()


def replay_wal(path: str) -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Return the complete (keys, seqs, vptrs) batches in append order.

    Torn tails (partial frame / bad crc) end the log silently — those
    records were never acknowledged.
    """
    out = []
    for payload in read_frames(path):
        op, count = _REC_HDR.unpack_from(payload, 0)
        if op != _OP_PUT:
            break  # unknown record type: treat as corruption, stop replay
        body = payload[_REC_HDR.size:]
        if len(body) != 3 * 8 * count:
            break
        arr = np.frombuffer(body, np.int64)
        out.append((arr[:count].copy(), arr[count:2 * count].copy(),
                    arr[2 * count:].copy()))
    return out
