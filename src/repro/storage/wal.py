"""Write-ahead log: memtable contents survive a crash.

One frame per ingested sub-batch: ``[op u8][count u32]`` followed by the
raw ``keys/seqs/vptrs`` int64 arrays.  Tombstones ride as ordinary records
with ``vptr == -1``, so a single record type covers puts and deletes.

The WAL is rotated at every flush: once the drained memtable is durable as
an SSTable (and the MANIFEST edit recording it is on disk), a fresh
``wal-<n+1>.log`` starts and the old file is deleted.  Replay therefore
only ever concerns records newer than the last flush.
"""

from __future__ import annotations

import os
import struct

import numpy as np

from .format import fsync_dir, read_frames, write_frame

__all__ = ["WALWriter", "replay_wal"]

_REC_HDR = struct.Struct("<BI")
_OP_PUT = 1


class WALWriter:
    def __init__(self, path: str, fsync: bool = False) -> None:
        self.path = path
        self.fsync = fsync
        created = not os.path.exists(path)
        self._f = open(path, "ab")
        if fsync and created:
            fsync_dir(os.path.dirname(path))  # the new entry must persist

    def append(self, keys: np.ndarray, seqs: np.ndarray,
               vptrs: np.ndarray) -> None:
        payload = (_REC_HDR.pack(_OP_PUT, keys.shape[0])
                   + np.ascontiguousarray(keys, np.int64).tobytes()
                   + np.ascontiguousarray(seqs, np.int64).tobytes()
                   + np.ascontiguousarray(vptrs, np.int64).tobytes())
        write_frame(self._f, payload)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def replay_wal(path: str) -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Return the complete (keys, seqs, vptrs) batches in append order.

    Torn tails (partial frame / bad crc) end the log silently — those
    records were never acknowledged.
    """
    out = []
    for payload in read_frames(path):
        op, count = _REC_HDR.unpack_from(payload, 0)
        if op != _OP_PUT:
            break  # unknown record type: treat as corruption, stop replay
        body = payload[_REC_HDR.size:]
        if len(body) != 3 * 8 * count:
            break
        arr = np.frombuffer(body, np.int64)
        out.append((arr[:count].copy(), arr[count:2 * count].copy(),
                    arr[2 * count:].copy()))
    return out
