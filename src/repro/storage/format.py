"""On-disk format primitives shared by the storage engine.

Every durable structure is built from two primitives:

* **frames** — `[u32 length][u32 crc32][payload]` records appended to a
  log file (WAL, MANIFEST).  Readers stop cleanly at a torn tail: a short
  read or crc mismatch ends replay without error, which is exactly the
  crash-consistency contract (anything past the last complete frame was
  never acknowledged).
* **sections** — raw little-endian numpy arrays at 8-byte-aligned offsets
  inside a fixed-layout file (SSTable, value-log segment), so loading is
  ``np.frombuffer`` over an ``mmap`` — zero-copy back into the int64/u64
  arrays the :class:`LookupEngine` stacks onto device.

File naming lives here too so every module agrees on it.
"""

from __future__ import annotations

import os
import struct
import zlib

__all__ = [
    "MAGIC_SST", "MAGIC_MODEL", "MAGIC_FILTER", "crc32", "write_frame",
    "read_frames", "valid_frames_end", "fsync_dir", "sst_path", "wal_path",
    "vlog_path", "lmodel_path", "filter_path", "manifest_name", "CURRENT",
    "FRAME_HDR_SIZE",
]

MAGIC_SST = b"BRBNSST1"
MAGIC_MODEL = b"BRBNPLR1"
MAGIC_FILTER = b"BRBNFLT1"
CURRENT = "CURRENT"

_FRAME_HDR = struct.Struct("<II")
FRAME_HDR_SIZE = _FRAME_HDR.size


def crc32(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def fsync_dir(dirpath: str) -> None:
    """fsync a directory so created/renamed entries survive power loss
    (the LevelDB/SQLite pattern; no-op value for OS-crash-only safety)."""
    fd = os.open(dirpath, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_frame(f, payload: bytes) -> None:
    f.write(_FRAME_HDR.pack(len(payload), crc32(payload)))
    f.write(payload)


def read_frames(path: str):
    """Yield complete frame payloads; stop silently at a torn tail."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off + _FRAME_HDR.size <= len(data):
        length, crc = _FRAME_HDR.unpack_from(data, off)
        body_off = off + _FRAME_HDR.size
        if body_off + length > len(data):
            return  # torn tail: incomplete payload
        payload = data[body_off: body_off + length]
        if crc32(payload) != crc:
            return  # torn tail: bad checksum
        yield payload
        off = body_off + length


def valid_frames_end(path: str) -> int:
    """Byte offset just past the last valid frame.  A writer reopening a
    frame log for append MUST truncate to this first — appending after a
    torn frame would make every later frame invisible to replay."""
    return sum(_FRAME_HDR.size + len(p) for p in read_frames(path))


def sst_path(dirpath: str, file_id: int) -> str:
    return os.path.join(dirpath, f"{file_id:06d}.sst")


def wal_path(dirpath: str, wal_no: int) -> str:
    return os.path.join(dirpath, f"wal-{wal_no:06d}.log")


def vlog_path(dirpath: str, seg: int) -> str:
    return os.path.join(dirpath, f"vlog-{seg:06d}.seg")


def lmodel_path(dirpath: str, level: int, epoch: int) -> str:
    """Sidecar holding a persisted level-granularity PLR model; the
    MANIFEST ``lmodel`` record names the (level, epoch) pair that is live."""
    return os.path.join(dirpath, f"lm-{level}-{epoch:06d}.plm")


def filter_path(dirpath: str, level: int, epoch: int) -> str:
    """Sidecar holding a persisted level bloom filter; the MANIFEST
    ``filter`` record names the (level, epoch) pair that is live."""
    return os.path.join(dirpath, f"flt-{level}-{epoch:06d}.bf")


def manifest_name(no: int) -> str:
    return f"MANIFEST-{no:06d}"
