"""StorageEngine: directory layout + durability orchestration.

Owns the WAL writer, the MANIFEST writer, and the SSTable files for one
store directory::

    <dir>/CURRENT           name of the live MANIFEST
    <dir>/MANIFEST-000001   crc-framed JSON version edits
    <dir>/wal-0000NN.log    crc-framed memtable records (rotated per flush)
    <dir>/0000NN.sst        sstables (keys/seqs/vptrs/bloom/fences/model)
    <dir>/vlog-0000NN.seg   value-log segments (owned by DurableValueLog)

Commit ordering per flush: table files first (atomic ``os.replace``), then
the MANIFEST edit that references them together with the post-rotation WAL
number, then the new WAL is opened and the old one deleted.  A crash
between any two steps leaves either unreferenced files (garbage, cleaned
lazily) or a WAL that fully re-derives the memtable — never a referenced
file that doesn't exist.
"""

from __future__ import annotations

import fcntl
import os

import numpy as np

from .format import (filter_path, fsync_dir, lmodel_path, manifest_name,
                     sst_path, wal_path)
from .manifest import (ManifestState, ManifestWriter, checkpoint_edit,
                       read_manifest, set_current)
from .sstable_io import (append_model, write_level_filter, write_level_model,
                         write_sstable)
from .wal import GroupCommitWAL, WALWriter, replay_wal

__all__ = ["StorageEngine"]


class StorageEngine:
    def __init__(self, dirpath: str, fsync: bool = False,
                 group_commit: bool = False) -> None:
        os.makedirs(dirpath, exist_ok=True)
        self.dir = dirpath
        self.fsync = fsync
        # group_commit swaps the WAL writer for the coalescing one: puts
        # acknowledge before they are durable and wal_sync() is the
        # durability point (storage README, "WAL commit contract")
        self.group_commit = group_commit
        self.persisted_models: set[int] = set()
        # WAL accounting survives rotation: writer instances are recreated
        # per flush, so their counters are folded in here before hand-off
        self._wal_appends = 0
        self._wal_fsyncs = 0
        self._wal_commits = 0
        self._wal_batch_tail: list[int] = []
        # one writer per directory: flock dies with the process, so a
        # crashed holder never wedges the store
        self._lock_f = open(os.path.join(dirpath, "LOCK"), "w")
        try:
            fcntl.flock(self._lock_f, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            self._lock_f.close()
            raise RuntimeError(
                f"store at {dirpath!r} is already open in another process")
        try:
            self._init_logs(dirpath, fsync)
        except BaseException:
            # release the flock: a failed construction (e.g. corrupt
            # CURRENT) must not wedge the next open in this process
            fcntl.flock(self._lock_f, fcntl.LOCK_UN)
            self._lock_f.close()
            raise

    def _init_logs(self, dirpath: str, fsync: bool) -> None:
        existing = read_manifest(dirpath)
        if existing is None:
            self.state = ManifestState(live={})
            self.manifest = ManifestWriter(dirpath, 1, fsync)
            self.wal_no = 1
            self.old_wal_no = self.wal_no
            edit = {"wal": self.wal_no}
            self.manifest.append(edit)
            self.state.apply(edit)
            self.recovered = False
        else:
            self.state, manifest_no = existing
            # sweep manifests CURRENT doesn't name: a crash mid-checkpoint
            # leaves either an unpublished new file or an unretired old one
            live_manifest = manifest_name(manifest_no)
            for name in os.listdir(dirpath):
                if name.startswith("MANIFEST-") and name != live_manifest:
                    os.unlink(os.path.join(dirpath, name))
            self.manifest = ManifestWriter(dirpath, manifest_no, fsync)
            # open is a fold point: tail bytes count from here, else a
            # manifest whose folded state exceeds the threshold would
            # re-checkpoint on the first tick of every session
            self.manifest.base = self.manifest.size()
            self.recovered = True
            # Recovery WAL protocol: never append to the pre-crash WAL.
            # Its records are re-ingested into a fresh wal-<n+1>; only after
            # that does a manifest edit acknowledge the new number and the
            # old file get deleted (finish_recovery).  Stray WALs from a
            # crashed recovery hold duplicates of acknowledged records —
            # remove them before they can be appended to.
            self.old_wal_no = self.state.wal_no
            for name in os.listdir(dirpath):
                if (name.startswith("wal-") and
                        name != os.path.basename(
                            wal_path(dirpath, self.old_wal_no))):
                    os.unlink(os.path.join(dirpath, name))
            self.wal_no = self.old_wal_no + 1
        # while True, the WAL is neither rotated nor acknowledged in the
        # manifest: a crash mid-recovery must re-derive everything from the
        # still-referenced pre-crash WAL
        self.in_recovery = self.recovered
        self.wal = self._new_wal(wal_path(dirpath, self.wal_no))

    def _new_wal(self, path: str):
        w = (GroupCommitWAL(path, self.fsync) if self.group_commit
             else WALWriter(path, self.fsync))
        # carry the causal tracer across rotation: a traced write must be
        # able to land in whichever writer is current
        ct = getattr(self, "_tracer", None)
        if ct is not None:
            w.tracer = ct
        return w

    def set_tracer(self, ct) -> None:
        """Wire the causal tracer (repro.obs.trace) into the WAL writer —
        and every writer a future rotation creates."""
        self._tracer = ct
        self.wal.tracer = ct

    def ensure_format(self, value_size: int, seg_slots: int,
                      plr_delta: int) -> None:
        """Record the store geometry at creation; refuse to open with a
        different one.  Wrong entry size would destroy the segment files;
        wrong plr_delta would silently shrink the model-path search window
        below the persisted models' error bound and lose reads."""
        if self.state.value_size is None:
            edit = {"vsize": value_size, "vslots": seg_slots,
                    "pdelta": plr_delta}
            self.manifest.append(edit)
            self.state.apply(edit)
            return
        want = (value_size, seg_slots, plr_delta)
        have = (self.state.value_size, self.state.seg_slots,
                self.state.plr_delta)
        if have != want:
            raise ValueError(
                f"store was created with (value_size, vlog_seg_slots, "
                f"plr_delta)={have}; refusing to open with {want}")

    # ------------------------------------------------------------------- wal
    def wal_append(self, keys: np.ndarray, seqs: np.ndarray,
                   vptrs: np.ndarray) -> None:
        self.wal.append(keys, seqs, vptrs)

    def wal_sync(self) -> None:
        """Durability barrier: returns once every acknowledged WAL append
        is on disk.  Per-append writers make this a no-op; under group
        commit it waits for (at most) one coalesced flush+fsync."""
        self.wal.sync()

    def wal_stats(self) -> dict:
        """Lifetime WAL accounting across rotations.  ``commits`` counts
        disk flush groups, so appends/commits is the coalesce factor the
        group-commit benchmark reports."""
        return {"appends": self._wal_appends + self.wal.appends,
                "fsyncs": self._wal_fsyncs + self.wal.fsyncs,
                "commits": self._wal_commits + self.wal.commits,
                "group_commit": self.group_commit}

    def drain_wal_batch_sizes(self) -> list[int]:
        """Per-commit group sizes since the last drain (rotated writers'
        tails included) — feeds the fsync-batch-size histogram."""
        out = self._wal_batch_tail
        self._wal_batch_tail = []
        out.extend(self.wal.drain_batch_sizes())
        return out

    def replay_old_wal(self):
        """Batches from the pre-crash WAL (recovery re-ingests them into a
        fresh WAL before ``finish_recovery`` removes this one)."""
        return replay_wal(wal_path(self.dir, self.old_wal_no))

    def finish_recovery(self, seq: int, clock: float, vhead: int,
                        rotate: bool = False) -> None:
        """Acknowledge the recovery WAL in the manifest, drop the old one.
        Only now may flushes rotate the WAL again.

        ``rotate=True`` when the replay flushed everything to sstables
        (memtable empty): the recovery WAL's records are all redundant, so
        a fresh empty WAL replaces it — otherwise each reopen cycle would
        re-flush the same records into duplicate tables."""
        ack_wal = self.wal_no + 1 if rotate else self.wal_no
        edit = {"wal": ack_wal, "seq": seq, "clock": clock, "vhead": vhead}
        self.manifest.append(edit)
        self.state.apply(edit)
        self.in_recovery = False
        if rotate:
            self.drop_old_wal(self._rotate_wal())
        self.drop_old_wal(self.old_wal_no)

    def drop_old_wal(self, old_no: int) -> None:
        if old_no != self.wal_no:
            path = wal_path(self.dir, old_no)
            if os.path.exists(path):
                os.unlink(path)

    def _rotate_wal(self) -> int:
        """Close the current WAL, open the next; returns the old number.
        Callers must only rotate when the memtable is empty (post-flush)
        and AFTER a manifest edit acknowledging wal_no+1 is durable — a
        manifest pointing at a not-yet-created WAL replays as empty, which
        is correct; the reverse order would let acknowledged writes land
        in a WAL the next recovery's stray sweep deletes.  ``close()``
        quiesces a group-commit writer (drains + final sync), so a
        rotated-away WAL never strands queued frames — redundant here
        anyway, since rotation only happens once the flush covered them."""
        self.wal.close()
        self._wal_appends += self.wal.appends
        self._wal_fsyncs += self.wal.fsyncs
        self._wal_commits += self.wal.commits
        self._wal_batch_tail.extend(self.wal.drain_batch_sizes())
        old = self.wal_no
        self.wal_no += 1
        self.wal = self._new_wal(wal_path(self.dir, self.wal_no))
        return old

    # ------------------------------------------------------------- checkpoint
    def manifest_bytes(self) -> int:
        """Total size of the live manifest file (reporting)."""
        return self.manifest.size()

    def manifest_tail_bytes(self) -> int:
        """Edit bytes appended since the last checkpoint — the scheduling
        signal.  Comparing *total* size would loop forever once the folded
        state itself outgrew the threshold: every fold would immediately
        re-trigger.  Tail bytes go to zero after each fold by construction."""
        return self.manifest.size() - self.manifest.base

    def checkpoint(self) -> int:
        """Fold the live state into a single checkpoint edit in a new
        numbered MANIFEST and atomically retire the old one.

        Ordering: the new file is fully written (and fsync'd when enabled)
        *before* CURRENT switches, and the old file is deleted only after.
        A crash at any point leaves CURRENT naming a complete manifest;
        the other file is an orphan the next open sweeps.  Returns the
        size of the edit log that was folded away."""
        folded = self.manifest.size()
        new_no = self.manifest.no + 1
        target = os.path.join(self.dir, manifest_name(new_no))
        if os.path.exists(target):
            # leftover from a failed checkpoint earlier this session (the
            # orphan sweep only runs at open): appending after its stale
            # checkpoint edit would resurrect since-deleted files on replay
            os.unlink(target)
        w = ManifestWriter(self.dir, new_no, self.fsync, publish=False)
        w.append(checkpoint_edit(self.state))
        w.base = w.size()
        if self.fsync:
            # the new file's directory entry must be durable BEFORE CURRENT
            # names it — dir-entry writeback is unordered, and a CURRENT
            # that survives power loss pointing at a missing file would
            # otherwise be the store's only record
            fsync_dir(self.dir)
        set_current(self.dir, new_no, self.fsync)   # the atomic switch
        old_path = self.manifest.path
        self.manifest.close()
        self.manifest = w
        os.unlink(old_path)
        return folded

    @staticmethod
    def _vdead_field(edit: dict, vdead: dict | None) -> dict:
        """Attach a dead-estimate *delta* (segments changed since the last
        persist).  Full snapshots ride only in checkpoint edits, so an
        ordinary edit stays O(changed segments)."""
        if vdead:
            edit["vdead_d"] = {str(s): int(c) for s, c in vdead.items()}
        return edit

    # ----------------------------------------------------------------- flush
    def persist_flush(self, add_tables: list, delete_ids: list,
                      seq: int, clock: float, vhead: int,
                      vdead: dict | None = None) -> None:
        """Durably commit one flush/compaction batch and rotate the WAL.

        During recovery the rotation (and the manifest's WAL field) is
        withheld: un-replayed batches may still live only in the pre-crash
        WAL, and acknowledging a newer number would let the next recovery's
        stray-WAL sweep delete them."""
        for t in add_tables:
            write_sstable(self.dir, t, self.fsync)
            if t.model is not None:
                self.persisted_models.add(t.file_id)
        edit = self._vdead_field({
            "add": [[t.file_id, t.level] for t in add_tables],
            "del": [fid for fid in delete_ids if fid in self.state.live],
            "seq": seq, "clock": clock, "vhead": vhead,
        }, vdead)
        if not self.in_recovery:
            edit["wal"] = self.wal_no + 1
        self.manifest.append(edit)
        self.state.apply(edit)
        for fid in edit["del"]:
            self.persisted_models.discard(fid)
            path = sst_path(self.dir, fid)
            if os.path.exists(path):
                os.unlink(path)
        if not self.in_recovery:
            self.drop_old_wal(self._rotate_wal())

    # ----------------------------------------------------------------- model
    def persist_model(self, table) -> None:
        if table.file_id in self.persisted_models:
            return
        if table.file_id not in self.state.live:
            return  # died before its model landed; nothing on disk to patch
        append_model(sst_path(self.dir, table.file_id), table.model,
                     self.fsync)
        self.persisted_models.add(table.file_id)

    def persist_level_model(self, level: int, model) -> None:
        """Durably publish a level-granularity model (§4.3): the sidecar
        file is fully written first, then the MANIFEST ``lmodel`` edit
        names it — so a torn edit leaves an orphan sidecar (swept on the
        next open) rather than a referenced-but-missing model.  The
        superseded sidecar is deleted only after the new edit landed."""
        epoch = int(model.epoch)
        write_level_model(lmodel_path(self.dir, level, epoch), model,
                          self.fsync)
        old = self.state.level_models.get(level)
        edit = {"lmodel": {str(level): epoch}}
        self.manifest.append(edit)
        self.state.apply(edit)
        if old is not None and old != epoch:
            self.drop_level_model(level, old)

    def drop_level_model(self, level: int, epoch: int) -> None:
        """Remove a superseded/invalidated sidecar.  The manifest stopped
        referencing it already (new lmodel edit, or the add/del edit whose
        replay drops the record), so this is pure garbage collection — a
        crash beforehand just leaves a file the next open sweeps."""
        path = lmodel_path(self.dir, level, epoch)
        if os.path.exists(path):
            os.unlink(path)

    # ---------------------------------------------------------------- filters
    def persist_level_filter(self, level: int, flt) -> None:
        """Durably publish a level bloom filter, same sidecar-first
        protocol as :meth:`persist_level_model`: bits file fully written
        (and renamed) before the MANIFEST ``filter`` edit names it, so a
        torn edit leaves an orphan sidecar the next open sweeps."""
        epoch = int(flt.epoch)
        write_level_filter(filter_path(self.dir, level, epoch), flt,
                           self.fsync)
        old = self.state.filters.get(level)
        edit = {"filter": {str(level): epoch}}
        self.manifest.append(edit)
        self.state.apply(edit)
        if old is not None and old != epoch:
            self.drop_level_filter(level, old)

    def drop_level_filter(self, level: int, epoch: int) -> None:
        """Remove a superseded/invalidated filter sidecar (the manifest
        already stopped referencing it — pure garbage collection)."""
        path = filter_path(self.dir, level, epoch)
        if os.path.exists(path):
            os.unlink(path)

    # -------------------------------------------------------------------- gc
    def persist_gc(self, removed_segs: list[int], seq: int, clock: float,
                   vhead: int, vdead: dict | None = None) -> None:
        edit = self._vdead_field(
            {"vlog_rm": list(removed_segs), "seq": seq, "clock": clock,
             "vhead": vhead}, vdead)
        self.manifest.append(edit)
        self.state.apply(edit)

    # ----------------------------------------------------------------- close
    def close(self, seq: int, clock: float, vhead: int,
              vdead: dict | None = None) -> None:
        self.manifest.append(self._vdead_field(
            {"seq": seq, "clock": clock, "vhead": vhead}, vdead))
        self.abort()

    def abort(self) -> None:
        """Release handles and the directory lock without a final edit —
        used when open() fails after the engine was constructed."""
        self.manifest.close()
        self.wal.close()
        if not self._lock_f.closed:
            fcntl.flock(self._lock_f, fcntl.LOCK_UN)
            self._lock_f.close()
