"""SSTable file format: keys/seqs/vptrs + bloom + fences + learned model.

Layout (all offsets 8-byte aligned, little endian)::

    header (72 B): magic, file_id, level, bloom_k, n, n_blocks,
                   bloom_words, created_at, base_crc, model_offset
    keys   [n]        int64
    seqs   [n]        int64
    vptrs  [n]        int64
    fences [n_blocks] int64
    bloom  [W]        uint64
    model block (optional, appended when the file is learned):
        magic, n_segments, delta, crc, then starts/slopes/intercepts [ns] f64

Persisting the PLR segments *inside* the table file is the Bourbon move
(§4.2 "integrate the learned index with the storage format"): a reopened
store serves model-path lookups immediately, no retraining.  Because
learning is asynchronous, the model block is appended after the fact —
``append_model`` writes the block at EOF and patches ``model_offset`` in
the header (a single 8-byte in-place update, crash-safe: a torn patch
leaves offset 0 = "no model", never a dangling pointer, since the offset
is only written after the block itself is flushed).

Loading maps the file with ``np.memmap`` and returns array views over it
(zero-copy); the engine's device stacking copies out of these views.
"""

from __future__ import annotations

import os
import struct

import jax.numpy as jnp
import numpy as np

from repro.core.plr import PLRModel
from repro.core.sstable import FileStats, SSTable

from .format import (MAGIC_FILTER, MAGIC_MODEL, MAGIC_SST, crc32, fsync_dir,
                     sst_path)

__all__ = ["write_sstable", "append_model", "load_sstable",
           "write_level_model", "load_level_model",
           "write_level_filter", "load_level_filter"]

_HDR = struct.Struct("<8sqiiqqqdIxxxxq")
HEADER_SIZE = _HDR.size          # 72, a multiple of 8
_MODEL_HDR = struct.Struct("<8siiIxxxx")  # 24 bytes, multiple of 8
_FILTER_HDR = struct.Struct("<8sqqiiIxxxx")  # 40 bytes, multiple of 8
_MODEL_OFF_POS = HEADER_SIZE - 8  # model_offset is the last header field


def _sections(table: SSTable) -> bytes:
    return (np.ascontiguousarray(table.keys, np.int64).tobytes()
            + np.ascontiguousarray(table.seqs, np.int64).tobytes()
            + np.ascontiguousarray(table.vptrs, np.int64).tobytes()
            + np.ascontiguousarray(table.fences, np.int64).tobytes()
            + np.ascontiguousarray(table.bloom, np.uint64).tobytes())


def _model_block(model: PLRModel) -> bytes:
    ns = int(model.n_segments)
    arrays = (np.asarray(model.starts, np.float64)[:ns].tobytes()
              + np.asarray(model.slopes, np.float64)[:ns].tobytes()
              + np.asarray(model.intercepts, np.float64)[:ns].tobytes())
    return _MODEL_HDR.pack(MAGIC_MODEL, ns, model.delta,
                           crc32(arrays)) + arrays


def write_sstable(dirpath: str, table: SSTable, fsync: bool = False) -> str:
    """Write a complete table file (including its model, if already fit)."""
    path = sst_path(dirpath, table.file_id)
    body = _sections(table)
    model_offset = 0
    model = b""
    if table.model is not None:
        model_offset = HEADER_SIZE + len(body)
        model = _model_block(table.model)
    hdr = _HDR.pack(MAGIC_SST, table.file_id, table.level, table.bloom_k,
                    table.n, table.fences.shape[0], table.bloom.shape[0],
                    table.created_at, crc32(body), model_offset)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(hdr)
        f.write(body)
        f.write(model)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic: readers never see a partial table
    if fsync:
        fsync_dir(dirpath)  # the rename itself must survive power loss
    return path


def append_model(path: str, model: PLRModel, fsync: bool = False) -> None:
    """Persist a just-learned model into an existing table file."""
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        offset = f.tell()
        f.write(_model_block(model))
        f.flush()
        if fsync:
            os.fsync(f.fileno())
        f.seek(_MODEL_OFF_POS)
        f.write(struct.pack("<q", offset))
        f.flush()
        if fsync:
            os.fsync(f.fileno())


def write_level_model(path: str, model: PLRModel, fsync: bool = False) -> None:
    """Persist a level-granularity model as a standalone sidecar file —
    the same model-block encoding that rides inside sstables, written via
    tmp + ``os.replace`` so a reader never sees a partial file."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_model_block(model))
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        # the rename itself must be durable before the MANIFEST edit that
        # references this sidecar can be written
        fsync_dir(os.path.dirname(path) or ".")


def load_level_model(path: str, verify: bool = True) -> PLRModel | None:
    """Load a level-model sidecar; returns None when the file is missing,
    torn, or fails its checksum — a level model is always recomputable, so
    the caller falls back to relearning instead of refusing to open."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return None
    if len(data) < _MODEL_HDR.size:
        return None
    magic, ns, delta, mcrc = _MODEL_HDR.unpack_from(data, 0)
    arrays = data[_MODEL_HDR.size: _MODEL_HDR.size + 3 * 8 * ns]
    if (magic != MAGIC_MODEL or len(arrays) < 3 * 8 * ns
            or (verify and crc32(arrays) != mcrc)):
        return None
    starts = np.frombuffer(arrays, np.float64, count=ns)
    slopes = np.frombuffer(arrays, np.float64, count=ns, offset=8 * ns)
    icepts = np.frombuffer(arrays, np.float64, count=ns, offset=16 * ns)
    return PLRModel(jnp.asarray(starts), jnp.asarray(slopes),
                    jnp.asarray(icepts), jnp.asarray(ns, jnp.int32),
                    delta=delta)


def write_level_filter(path: str, flt, fsync: bool = False) -> None:
    """Persist a level bloom filter as a standalone sidecar file —
    same tmp + ``os.replace`` publish discipline as level models, so a
    reader never sees a partial filter and the rename is durable before
    the MANIFEST ``filter`` record that points at it."""
    words = np.ascontiguousarray(flt.bits, np.uint64).tobytes()
    hdr = _FILTER_HDR.pack(MAGIC_FILTER, int(flt.n_keys), int(flt.n_words),
                           int(flt.k_hashes), int(flt.bits_per_key),
                           crc32(words))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(hdr)
        f.write(words)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        fsync_dir(os.path.dirname(path) or ".")


def load_level_filter(path: str, verify: bool = True):
    """Load a filter sidecar; returns None when the file is missing, torn,
    or fails its checksum — a filter is always recomputable from the level's
    keys, so the caller rebuilds lazily instead of refusing to open."""
    from repro.core.filters import LevelFilter
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return None
    if len(data) < _FILTER_HDR.size:
        return None
    magic, n_keys, n_words, k_hashes, bpk, fcrc = _FILTER_HDR.unpack_from(
        data, 0)
    words = data[_FILTER_HDR.size: _FILTER_HDR.size + 8 * n_words]
    if (magic != MAGIC_FILTER or len(words) < 8 * n_words
            or (verify and crc32(words) != fcrc)):
        return None
    bits = np.frombuffer(words, np.uint64, count=n_words).copy()
    return LevelFilter(bits=bits, n_words=n_words, k_hashes=k_hashes,
                       bits_per_key=bpk, n_keys=n_keys)


def load_sstable(path: str, verify: bool = True) -> SSTable:
    """mmap the file and return an SSTable whose arrays view it zero-copy."""
    mm = np.memmap(path, dtype=np.uint8, mode="r")
    (magic, file_id, level, bloom_k, n, n_blocks, n_words, created_at,
     base_crc, model_offset) = _HDR.unpack_from(mm[:HEADER_SIZE].tobytes(), 0)
    if magic != MAGIC_SST:
        raise ValueError(f"{path}: bad sstable magic {magic!r}")

    off = HEADER_SIZE

    def view(count, dtype):
        nonlocal off
        arr = np.frombuffer(mm, dtype, count=count, offset=off)
        off += count * arr.dtype.itemsize
        return arr

    keys = view(n, np.int64)
    seqs = view(n, np.int64)
    vptrs = view(n, np.int64)
    fences = view(n_blocks, np.int64)
    bloom = view(n_words, np.uint64)
    if verify and crc32(mm[HEADER_SIZE:off].tobytes()) != base_crc:
        raise ValueError(f"{path}: sstable body checksum mismatch")

    model = None
    if model_offset:
        mh = mm[model_offset: model_offset + _MODEL_HDR.size].tobytes()
        mmagic, ns, delta, mcrc = _MODEL_HDR.unpack(mh)
        if mmagic != MAGIC_MODEL:
            raise ValueError(f"{path}: bad model magic {mmagic!r}")
        aoff = model_offset + _MODEL_HDR.size
        if verify and crc32(mm[aoff: aoff + 3 * 8 * ns].tobytes()) != mcrc:
            raise ValueError(f"{path}: model checksum mismatch")
        starts = np.frombuffer(mm, np.float64, count=ns, offset=aoff)
        slopes = np.frombuffer(mm, np.float64, count=ns, offset=aoff + 8 * ns)
        icepts = np.frombuffer(mm, np.float64, count=ns, offset=aoff + 16 * ns)
        model = PLRModel(jnp.asarray(starts), jnp.asarray(slopes),
                         jnp.asarray(icepts), jnp.asarray(ns, jnp.int32),
                         delta=delta)

    return SSTable(keys=keys, seqs=seqs, vptrs=vptrs, fences=fences,
                   bloom=bloom, bloom_k=bloom_k, level=level, file_id=file_id,
                   created_at=created_at, model=model,
                   learn_submitted=model is not None,
                   stats=FileStats())
