"""Durable segmented value log with WiscKey-style garbage collection.

Entries are ``(key i64, seq i64, value u8[value_size])`` — the key and
sequence ride with the value (WiscKey §4.2) so GC can ask the LSM whether
an entry is still referenced without any extra index.  The *logical*
address space stays flat: global slot ``p`` lives in segment
``p // seg_slots`` at in-file offset ``(p % seg_slots) * entry_size``, so
value pointers stored in sstables keep working as plain arena indices and
``device_view`` remains the zero-copy (head, value_size) device array.

GC drops whole sealed segments: live entries are first relocated (appended
at the head with fresh seqs, pointers updated through the LSM by the
store), then the segment file is deleted and its arena rows zeroed.  The
reclaimed segment ids are recorded in the MANIFEST so recovery skips (and
cleans up) their files.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.valuelog import ValueLog

from .format import fsync_dir, vlog_path

__all__ = ["DurableValueLog"]


class DurableValueLog(ValueLog):
    def __init__(self, value_size: int, dirpath: str, seg_slots: int = 1 << 12,
                 capacity: int = 1 << 16, fsync: bool = False) -> None:
        super().__init__(value_size, capacity)
        self.dir = dirpath
        self.seg_slots = seg_slots
        self.fsync = fsync
        self.entry_size = 16 + value_size
        self.removed: set[int] = set()
        # incremental dead-entry estimate per segment (maintained by the
        # store's write path via note_dead, persisted in the MANIFEST):
        # GC candidacy reads this instead of scanning the log
        self.dead_by_seg: dict[int, int] = {}
        self.dead_dirty: set[int] = set()  # changed since last persist
        self.dead_version = 0              # bumps on any estimate change
        self._entry_dt = np.dtype([("key", "<i8"), ("seq", "<i8"),
                                   ("val", "u1", (value_size,))])
        self._head_f = None
        self._head_seg = -1

    # ----------------------------------------------------------------- write
    def append_kv(self, keys: np.ndarray, seqs: np.ndarray,
                  values: np.ndarray) -> np.ndarray:
        ptrs = super().append_batch(values)
        if ptrs.shape[0] == 0:
            return ptrs
        rec = np.empty(ptrs.shape[0], self._entry_dt)
        rec["key"] = keys
        rec["seq"] = seqs
        rec["val"] = values
        segs = ptrs // self.seg_slots
        off = 0
        while off < ptrs.shape[0]:
            seg = int(segs[off])
            end = off + int(np.searchsorted(segs[off:], seg, side="right"))
            self._writer(seg).write(rec[off:end].tobytes())
            off = end
        self._head_f.flush()
        if self.fsync:
            os.fsync(self._head_f.fileno())
        return ptrs

    def _writer(self, seg: int):
        if seg != self._head_seg:
            if self._head_f is not None:
                self._close_handle(self._head_f)
            path = vlog_path(self.dir, seg)
            created = not os.path.exists(path)
            self._head_f = open(path, "ab")
            if self.fsync and created:
                fsync_dir(self.dir)  # the new entry must persist
            self._head_seg = seg
        return self._head_f

    def _close_handle(self, f) -> None:
        f.flush()
        if self.fsync:   # sealed segments must hit disk, not just the OS
            os.fsync(f.fileno())
        f.close()

    # -------------------------------------------------------------------- gc
    def note_dead(self, ptrs: np.ndarray) -> None:
        ptrs = np.asarray(ptrs, np.int64)
        ptrs = ptrs[ptrs >= 0]
        if ptrs.shape[0] == 0:
            return
        self.dead_entries += int(ptrs.shape[0])
        segs, counts = np.unique(ptrs // self.seg_slots, return_counts=True)
        for seg, c in zip(segs.tolist(), counts.tolist()):
            self.dead_by_seg[seg] = self.dead_by_seg.get(seg, 0) + c
            self.dead_dirty.add(seg)
        self.dead_version += 1

    def dead_ratio_est(self, seg: int) -> float:
        """Estimated dead fraction of a sealed segment — no file I/O."""
        return min(1.0, self.dead_by_seg.get(seg, 0) / self.seg_slots)

    def dead_delta(self) -> dict[int, int]:
        """Per-segment counts changed since the last persist (MANIFEST
        edits carry this delta; only checkpoints carry the full map)."""
        return {s: self.dead_by_seg.get(s, 0) for s in self.dead_dirty}

    def clear_dead_dirty(self) -> None:
        self.dead_dirty.clear()

    def sealed_segments(self) -> list[int]:
        """Fully-written, not-yet-reclaimed segments (GC candidates)."""
        n_sealed = self._head // self.seg_slots
        return [s for s in range(n_sealed) if s not in self.removed]

    def read_segment(self, seg: int, with_values: bool = True):
        """Returns (ptrs, keys, seqs, values) for a segment's complete
        entries — a torn trailing entry (crash mid-append) is ignored.
        ``with_values=False`` skips only the materialized payload *copy*
        (entries are interleaved, so the file bytes are read either way);
        the GC liveness pass needs just keys and pointers."""
        with open(vlog_path(self.dir, seg), "rb") as f:
            raw = f.read()
        count = len(raw) // self.entry_size
        rec = np.frombuffer(raw, dtype=self._entry_dt, count=count)
        ptrs = seg * self.seg_slots + np.arange(count, dtype=np.int64)
        vals = rec["val"].copy() if with_values else None
        return ptrs, rec["key"].copy(), rec["seq"].copy(), vals

    def drop_segment(self, seg: int) -> int:
        """Delete a reclaimed (sealed) segment's file; returns bytes freed."""
        if seg >= self._head // self.seg_slots:
            raise ValueError("cannot drop an unsealed segment")
        if seg == self._head_seg:
            # head sits exactly on the segment boundary: the last-written
            # file is sealed and droppable, but its handle is still open
            self._close_handle(self._head_f)
            self._head_f = None
            self._head_seg = -1
        path = vlog_path(self.dir, seg)
        freed = os.path.getsize(path) if os.path.exists(path) else 0
        if os.path.exists(path):
            os.unlink(path)
        self.removed.add(seg)
        self.dead_entries -= self.dead_by_seg.pop(seg, 0)
        self.dead_dirty.discard(seg)
        self.dead_version += 1
        lo, hi = seg * self.seg_slots, (seg + 1) * self.seg_slots
        self._buf[lo: min(hi, self._buf.shape[0])] = 0
        self._device = None
        return freed

    def close(self) -> None:
        if self._head_f is not None and not self._head_f.closed:
            self._close_handle(self._head_f)

    def disk_bytes(self) -> int:
        total = 0
        for name in os.listdir(self.dir):
            if name.startswith("vlog-"):
                total += os.path.getsize(os.path.join(self.dir, name))
        return total

    # --------------------------------------------------------------- recover
    @classmethod
    def open(cls, dirpath: str, value_size: int, seg_slots: int,
             removed: set[int], vhead: int = 0, fsync: bool = False,
             dead_by_seg: dict[int, int] | None = None) -> "DurableValueLog":
        vlog = cls(value_size, dirpath, seg_slots, fsync=fsync)
        vlog.removed = set(removed)
        if dead_by_seg:
            # restore the persisted dead estimates, minus anything a
            # crashed GC already reclaimed (vlog_rm wins over vdead)
            vlog.dead_by_seg = {s: c for s, c in dead_by_seg.items()
                                if s not in vlog.removed}
            vlog.dead_entries = sum(vlog.dead_by_seg.values())
        head = vhead
        segs = []
        for name in sorted(os.listdir(dirpath)):
            if not name.startswith("vlog-"):
                continue
            seg = int(name.split("-")[1].split(".")[0])
            if seg in vlog.removed:
                os.unlink(os.path.join(dirpath, name))  # GC'd then crashed
                continue
            segs.append(seg)
        for seg in segs:
            ptrs, _, _, vals = vlog.read_segment(seg)
            # truncate a torn trailing entry so later appends stay aligned
            path = vlog_path(dirpath, seg)
            want = ptrs.shape[0] * vlog.entry_size
            if os.path.getsize(path) != want:
                with open(path, "r+b") as f:
                    f.truncate(want)
            if ptrs.shape[0] == 0:
                continue
            hi = int(ptrs[-1]) + 1
            while hi > vlog._buf.shape[0]:
                vlog._buf = np.concatenate(
                    [vlog._buf, np.zeros_like(vlog._buf)], axis=0)
            vlog._buf[ptrs[0]: hi] = vals
            head = max(head, hi)
        vlog._head = head
        # if the manifest's vhead ran ahead of the head segment's file (OS
        # lost an unsynced tail), pad the file with dead zero entries so
        # future appends keep slot == file_offset/entry_size aligned —
        # otherwise GC would misattribute pointers and drop live data
        head_seg = head // seg_slots
        used = head - head_seg * seg_slots
        if used:
            path = vlog_path(dirpath, head_seg)
            created = not os.path.exists(path)
            have = 0 if created else os.path.getsize(path)
            want = used * vlog.entry_size
            if have < want:
                with open(path, "ab") as f:
                    f.write(b"\x00" * (want - have))
                    f.flush()
                    if fsync:
                        os.fsync(f.fileno())
                if fsync and created:
                    fsync_dir(dirpath)
        return vlog
