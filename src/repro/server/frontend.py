"""BourbonServer — the batched request-serving front end.

The tick loop (modeled on the admission loop of
``repro.serving.engine``, applied to the key-value plane):

    clients --submit--> RequestQueue --Batcher--> coalesced batch
        GET:  HotKeyCache probe -> ShardedStore.get_batch (one
              snapshot-consistent multi-get per batch) -> cache fill
              -> scatter results back to each request
        PUT/DELETE: ShardedStore write batch -> cache invalidation
    then one FleetMaintenanceCoordinator round (budgeted, staggered)

Snapshot consistency: a read batch is answered by exactly one
epoch-versioned device state — ``ShardedStore.get_batch`` resolves the
whole coalesced key set against one ``device_state()`` (plus the
per-shard memtable overlays), so two requests coalesced into the same
batch can never observe different snapshots of the same shard.  Cache
hits are values read under the *current* epoch vector (stale epochs
miss), so they are consistent with what the store would answer now.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.io import IOPool
from repro.obs import NULL_CTRACE, NULL_TRACER, Obs, ObsConfig, publish_stats

from .admission import Batch, Batcher, RequestQueue, ServerRequest
from .cache import HotKeyCache
from .coordinator import CoordinatorConfig, FleetMaintenanceCoordinator

__all__ = ["ServerConfig", "BourbonServer"]


@dataclasses.dataclass
class ServerConfig:
    max_batch_keys: int = 1024      # coalesced keys per store batch
    max_wait_ticks: int = 2         # ticks a partial batch may wait
    queue_capacity: int = 256       # requests; full queue = backpressure
    max_batches_per_tick: int = 4   # queue drains per tick (reads+writes)
    # virtual μs an *idle* tick represents: with no requests to serve,
    # shard clocks still move, so ski-rental T_waits (learning and GC
    # candidacy) expire instead of freezing with the workload
    idle_tick_us: float = 64.0
    cache_slots: int = 4096         # 0 disables the HotKeyCache
    # host I/O pool workers (repro.io.IOPool): 0 keeps every fetch, write
    # fan-out, and WAL sync inline (the seed behavior); N > 0 overlaps
    # value-log reads with device compute and runs per-shard dispatch
    # concurrently.  Results are bit-identical for any value (the
    # determinism gate in scripts/ci.sh holds us to it)
    io_workers: int = 0
    coordinate_maintenance: bool = True
    coordinator: CoordinatorConfig = dataclasses.field(
        default_factory=CoordinatorConfig)
    # observability plane (repro.obs): the server owns one Obs bundle,
    # attaches the whole store fleet to it, and times the read-path
    # stages through pre-bound handles.  enabled=False skips everything
    # (null objects on the hot path — the obs-off bench arm)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)


class BourbonServer:
    def __init__(self, store, cfg: ServerConfig | None = None) -> None:
        self.store = store
        self.cfg = cfg if cfg is not None else ServerConfig()
        self.queue = RequestQueue(self.cfg.queue_capacity)
        self.batcher = Batcher(self.cfg.max_batch_keys,
                               self.cfg.max_wait_ticks)
        self.cache = (HotKeyCache(self.cfg.cache_slots)
                      if self.cfg.cache_slots else None)
        self.coordinator = (
            FleetMaintenanceCoordinator(store, self.cfg.coordinator)
            if self.cfg.coordinate_maintenance else None)
        self.ticks = 0
        self.completed = 0
        self.served_from_cache = 0   # keys answered without a store probe
        self.store_probe_keys = 0    # keys that did reach the store
        # fleet-stall metric, valid with OR without the coordinator: the
        # largest maintenance charge observed within one server tick
        self.max_maintenance_tick_us = 0.0
        self._maint_us_seen = store.maintenance_us()
        self._value_size = store.shards[0].cfg.value_size
        # host I/O plane: the server owns the pool (like the Obs bundle)
        # and joins the whole store fleet to it; shutdown() closes it
        self.io = IOPool(self.cfg.io_workers) if self.cfg.io_workers else None
        if self.io is not None:
            store.attach_io(self.io)
        else:
            store.detach_io()   # a pool a previous server attached
        # observability: one Obs bundle per server; stage handles are
        # pre-bound here so the per-batch cost is attribute reads only.
        # Obs-off servers hold the null tracer — same call sites, no
        # branches, (near-)zero cost: the bench's obs-off arm
        self.obs = Obs(self.cfg.obs) if self.cfg.obs.enabled else None
        tr = self.obs.tracer if self.obs is not None else NULL_TRACER
        self._tr = tr
        # causal tracer: one identity test per call site when tracing is
        # off (NULL_CTRACE) or the request is unsampled (trace is None)
        self._ct = self.obs.ctrace if self.obs is not None else NULL_CTRACE
        self._wal_parent = None    # last traced write batch span this tick
        self._st_admission = tr.stage("admission")
        self._st_coalesce = tr.stage("coalesce")
        self._st_cache = tr.stage("cache_probe")
        self._st_dispatch = tr.stage("dispatch")
        self._st_compute = tr.stage("compute")
        self._st_resolve = tr.stage("resolve")
        if self.obs is not None:
            store.attach_obs(self.obs)
            self.obs.registry.register_collector("server",
                                                 self._collect_obs)
            if self.io is not None:
                self.obs.registry.register_collector("io_pool",
                                                     self._collect_io_obs)
        else:
            # an obs-off server must serve a truly uninstrumented store,
            # even one a previous (obs-on) server attached: the overhead
            # bench compares clean arms
            store.detach_obs()

    def shutdown(self) -> None:
        """Release the host I/O plane: detach the fleet and stop the pool
        workers.  Idempotent; the store itself stays open (a closed pool
        would run any straggler inline, so this is always safe)."""
        if self.io is not None:
            self.store.detach_io()
            self.io.close()

    # ------------------------------------------------------------ admission
    def submit(self, req: ServerRequest) -> bool:
        """Enqueue a request; False means the queue is full (backpressure —
        retry after a tick)."""
        t0 = self._st_admission.begin()
        ok = self.queue.submit(req, self.ticks)
        if ok and req.trace is None:
            # mint the causal trace at admission (countdown-sampled; a
            # backpressured retry keeps its original trace)
            req.trace = self._ct.admit(self.ticks)
        self._st_admission.end(t0)
        return ok

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[ServerRequest]:
        """One server iteration: drain up to ``max_batches_per_tick``
        coalesced batches, then run one maintenance-coordination round.
        Returns the requests completed this tick."""
        done: list[ServerRequest] = []
        tick_no = self._tr.begin_tick()
        wrote = False
        for _ in range(self.cfg.max_batches_per_tick):
            t0 = self._st_coalesce.begin()
            batch = self.batcher.next_batch(self.queue, self.ticks)
            self._st_coalesce.end(t0)
            if batch is None:
                break
            if batch.op == "get":
                self._serve_reads(batch)
            else:
                self._apply_writes(batch)
                wrote = True
            done.extend(batch.requests)
        if wrote:
            # durability barrier before acknowledging: all write batches
            # applied this tick coalesce into ONE group-commit sync per
            # shard (no-op under the per-append writer) — the WAL commit
            # contract's sync point
            wsp = self._ct.begin_span("wal_sync", self._wal_parent)
            self.store.wal_sync()
            self._ct.end_span(wsp)
            self._wal_parent = None
        if not done:
            # an idle tick is still the passage of (virtual) time: advance
            # the shard clocks so T_waits (learning and GC candidacy)
            # expire instead of freezing with the workload
            for sh in self.store.shards:
                sh.clock.advance(self.cfg.idle_tick_us)
        # every tick gives the stores their own tick: the learning
        # executor progresses (and, when no coordinator owns maintenance,
        # the shards self-drive GC/checkpointing) under any load shape —
        # _maintenance_tick no-ops on deferred shards, so this never
        # bypasses the coordinator's budget
        msp = self._ct.begin_maintenance(self.ticks, kind="tick")
        for sh in self.store.shards:
            sh._tick()
        if self.coordinator is not None:
            self.coordinator.tick()
        self._ct.end_maintenance(msp)
        m = self.store.maintenance_us()
        self.max_maintenance_tick_us = max(self.max_maintenance_tick_us,
                                           m - self._maint_us_seen)
        self._maint_us_seen = m
        for r in done:
            r.completed_tick = self.ticks
            r.done = True
            self._ct.complete(r.trace, tick=self.ticks)
        self.completed += len(done)
        self._tr.end_tick(tick_no)
        self.ticks += 1
        return done

    def run_until_drained(self, max_ticks: int = 100000
                          ) -> list[ServerRequest]:
        out: list[ServerRequest] = []
        for _ in range(max_ticks):
            if not len(self.queue):
                break
            out.extend(self.tick())
        return out

    # ----------------------------------------------------------------- reads
    def _serve_reads(self, batch: Batch) -> None:
        uniq = batch.keys
        bt = self._ct.join_batch(batch.requests)
        vals = np.zeros((uniq.shape[0], self._value_size), np.uint8)
        found = np.zeros(uniq.shape[0], bool)
        if self.cache is not None:
            # the epoch vector is stable across the whole read path (only
            # writes flush/compact), so one capture stamps both the cache
            # probe and the fill below
            epochs = self.store.shard_epochs()
            t0 = self._st_cache.begin()
            hit = self.cache.lookup(uniq, epochs, vals)
            self._st_cache.end(t0)
            found |= hit
            self.served_from_cache += int(hit.sum())
        else:
            hit = np.zeros(uniq.shape[0], bool)
            epochs = None                  # no cache: _fill_cache no-ops
        miss = ~hit
        if miss.any():
            # the synchronous path still splits dispatch from resolve so
            # the stage breakdown is comparable with the pipelined
            # server's; "compute" here is the whole dispatch->resolve
            # span (nothing overlaps it)
            tc = self._st_compute.begin()
            csp = self._ct.begin_span("device_compute", bt)
            t0 = self._st_dispatch.begin()
            dsp = self._ct.begin_span("dispatch", bt)
            pb = self.store.dispatch_get(uniq[miss], with_values=True,
                                         trace=dsp)
            self._ct.end_span(dsp, stage="dispatch")
            self._st_dispatch.end(t0)
            t0 = self._st_resolve.begin()
            vsp = self._ct.begin_span("value_fetch", bt)
            f, v = self.store.resolve_get(pb)
            self._ct.end_span(vsp, stage="value_fetch")
            self._st_resolve.end(t0)
            self._ct.end_span(csp, stage="device_compute")
            self._st_compute.end(tc)
            found[miss] = f
            vals[miss] = v
            self.store_probe_keys += int(miss.sum())
            self._charge_read_clocks(self.store.shard_of(uniq[miss]))
            pos = np.nonzero(miss)[0][f]
            self._fill_cache(uniq[pos], vals[pos], epochs)
        for req, idx in zip(batch.requests, batch.scatter):
            req.found = found[idx]
            req.result = vals[idx]
        self._ct.end_span(bt)

    def _charge_read_clocks(self, owners_probed: np.ndarray) -> None:
        """Charge read service time to the owning shards' virtual clocks
        (ShardedStore.get_batch itself charges nothing), so sustained
        read-only load still moves time forward and maintenance/learning
        deadlines keep becoming due."""
        for i, sh in enumerate(self.store.shards):
            n_i = int((owners_probed == i).sum())
            if n_i:
                sh.clock.advance(n_i * sh.cfg.costs.t_pm)

    def _fill_cache(self, keys: np.ndarray, vals: np.ndarray,
                    epochs: tuple) -> None:
        """Admit found keys read under ``epochs`` into the HotKeyCache."""
        if self.cache is not None and keys.shape[0]:
            self.cache.fill(keys, vals, self.store.shard_of(keys), epochs)

    # ---------------------------------------------------------------- writes
    def _apply_writes(self, batch: Batch) -> None:
        bt = self._ct.join_batch(batch.requests, kind="write")
        # arm the ambient write span: WAL appends issued while applying
        # this batch parent under it (ended by the commit group's fsync)
        self._ct.set_write(bt)
        if batch.op == "put":
            self.store.put_batch(batch.keys, batch.values)
        else:
            self.store.delete_batch(batch.keys)
        self._ct.set_write(None)
        if self.cache is not None:
            self.cache.invalidate(batch.keys)
        self._ct.end_span(bt)
        if bt is not None:
            self._wal_parent = bt

    # ------------------------------------------------------------------- obs
    def _collect_obs(self, reg) -> None:
        """Snapshot-time collector: curated monotonic counters for the
        serving totals, then the whole layered ``stats()`` dict (minus
        the store subtree, which the store/fleet collectors already
        publish under their own shard labels) flattened into gauges."""
        c = reg.counter
        c("server_submitted_total").observe_total(self.queue.submitted)
        c("server_rejected_total").observe_total(self.queue.rejected)
        c("server_completed_total").observe_total(self.completed)
        c("server_ticks_total").observe_total(self.ticks)
        c("server_batches_total").observe_total(self.batcher.batches)
        c("server_served_from_cache_total").observe_total(
            self.served_from_cache)
        c("server_store_probe_keys_total").observe_total(
            self.store_probe_keys)
        if self.cache is not None:
            cs = self.cache.stats()
            for k in ("hits", "misses", "fills", "evictions",
                      "inval_epoch", "inval_write"):
                c(f"cache_{k}_total").observe_total(cs[k])
        s = {k: v for k, v in self.stats().items() if k != "store"}
        publish_stats(reg, "server", s)

    def _collect_io_obs(self, reg) -> None:
        """Host I/O pool health: queue depth says whether the workers keep
        up (a persistently deep queue means fetches are backing up behind
        too few workers); tasks_total is the lifetime submit count."""
        ps = self.io.stats()
        g = reg.gauge
        g("io_pool_workers").set(ps["workers"])
        g("io_pool_queue_depth").set(ps["depth"])
        g("io_pool_max_depth").set(ps["max_depth"])
        reg.counter("io_pool_tasks_total").observe_total(ps["submitted"])

    # ----------------------------------------------------------------- stats
    def stats(self) -> dict:
        b = self.batcher
        return {
            "ticks": self.ticks,
            "submitted": self.queue.submitted,
            "rejected": self.queue.rejected,
            "completed": self.completed,
            "queued": len(self.queue),
            "batches": b.batches,
            "coalesced_requests": b.coalesced_requests,
            "request_keys": b.request_keys,
            "batch_keys": b.batch_keys,
            "held": b.held,
            "served_from_cache": self.served_from_cache,
            "store_probe_keys": self.store_probe_keys,
            "max_maintenance_tick_us": self.max_maintenance_tick_us,
            "cache": self.cache.stats() if self.cache is not None else None,
            "io": self.io.stats() if self.io is not None else None,
            "coordinator": (self.coordinator.stats()
                            if self.coordinator is not None else None),
            "store": self.store.stats(),
        }
