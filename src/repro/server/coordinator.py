"""FleetMaintenanceCoordinator — staggered, budgeted background work.

Left alone, every shard's :class:`~repro.core.cba.MaintenanceScheduler`
fires value-log GC and MANIFEST checkpoints from its own write ticks —
independently, so a fleet-wide overwrite burst can put *every* shard
into GC in the same instant and stall the whole front end (the ROADMAP
per-shard-GC open item).  The coordinator closes it:

* on attach, every shard defers its self-driven maintenance
  (``maintenance_deferred = True``) — the coordinator is the only thing
  that ticks the schedulers from then on;
* each server tick offers a shared virtual-clock budget
  (``budget_us_per_tick``) to at most ``max_shards_per_tick`` shards,
  visiting shards **round-robin from a rotating cursor** so collections
  stagger across the fleet instead of synchronizing;
* each shard's :meth:`~repro.core.store.BourbonStore.run_maintenance`
  spends only what fits in the budget it is handed (candidate picking is
  cost-capped inside the CBA), so no single server tick can charge more
  maintenance than the budget — work that didn't fit stays queued on the
  shard's estimates and is re-offered on a later visit.
"""

from __future__ import annotations

import dataclasses

__all__ = ["CoordinatorConfig", "FleetMaintenanceCoordinator"]


@dataclasses.dataclass
class CoordinatorConfig:
    # fleet-wide virtual μs per tick; None = auto (the fleet's atomic
    # unit of work: the worst-case cost of collecting one fully-live
    # value-log segment, the smallest budget that cannot starve)
    budget_us_per_tick: float | None = None
    max_shards_per_tick: int = 1         # at most k shards maintain at once


class FleetMaintenanceCoordinator:
    def __init__(self, store, cfg: CoordinatorConfig | None = None) -> None:
        self.store = store
        self.cfg = cfg if cfg is not None else CoordinatorConfig()
        # GC is atomic per segment: a budget below the worst-case cost of
        # one segment would defer every candidate forever (silent
        # starvation — the estimates grow, nothing ever fits).  Refuse it
        # loudly; with no budget given, the atomic cost IS the budget.
        atomic = max(sh.cfg.costs.t_gc(sh.cfg.vlog_seg_slots,
                                       sh.cfg.vlog_seg_slots)
                     for sh in store.shards)
        if self.cfg.budget_us_per_tick is None:
            self.budget_us = atomic
        elif self.cfg.budget_us_per_tick < atomic:
            raise ValueError(
                f"budget_us_per_tick={self.cfg.budget_us_per_tick:.0f} is "
                f"below the fleet's atomic maintenance unit ({atomic:.0f} "
                f"virtual us to collect one fully-live segment): every "
                f"candidate would be deferred forever.  Raise the budget "
                f"or shrink StoreConfig.vlog_seg_slots")
        else:
            self.budget_us = self.cfg.budget_us_per_tick
        store.set_maintenance_deferred(True)
        self._cursor = 0
        self.ticks = 0
        self.runs = 0                    # shard rounds that did real work
        self.spent_us = 0.0
        self.max_tick_us = 0.0
        self.budget_exhausted = 0        # ticks that hit the budget wall
        self.per_shard_us = [0.0] * store.n_shards
        self.per_shard_runs = [0] * store.n_shards

    def tick(self) -> float:
        """One coordination round; returns the virtual μs spent."""
        n = self.store.n_shards
        spent = 0.0
        active = 0
        last = self._cursor
        for j in range(n):
            if active >= self.cfg.max_shards_per_tick:
                break
            remaining = self.budget_us - spent
            if remaining <= 0.0:
                self.budget_exhausted += 1
                break
            i = (self._cursor + j) % n
            used = self.store.run_shard_maintenance(i, budget_us=remaining)
            if used > 0.0:
                active += 1
                self.runs += 1
                self.per_shard_us[i] += used
                self.per_shard_runs[i] += 1
                spent += used
                last = i
        # resume after the last shard that worked: the next tick's budget
        # goes to the shards this one starved
        self._cursor = (last + 1) % n
        self.ticks += 1
        self.spent_us += spent
        self.max_tick_us = max(self.max_tick_us, spent)
        return spent

    def detach(self) -> None:
        """Hand maintenance back to the shards' own ticks."""
        self.store.set_maintenance_deferred(False)

    def stats(self) -> dict:
        return {
            "ticks": self.ticks,
            "runs": self.runs,
            "spent_us": self.spent_us,
            "max_tick_us": self.max_tick_us,
            "budget_us_per_tick": self.budget_us,
            "max_shards_per_tick": self.cfg.max_shards_per_tick,
            "budget_exhausted": self.budget_exhausted,
            "per_shard_us": list(self.per_shard_us),
            "per_shard_runs": list(self.per_shard_runs),
            "gc_deferred": sum(st.cba.gc_deferred
                               for st in self.store.shards),
        }
