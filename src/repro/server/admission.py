"""Request admission: bounded queue + coalescing batcher.

Many concurrent clients each submit small GET/PUT/DELETE requests; the
Pallas lookup kernels want few large batches.  The :class:`RequestQueue`
is the bounded front door (a full queue rejects the submit — closed-loop
clients retry next tick, which is the backpressure), and the
:class:`Batcher` turns the queue's front run of same-op requests into one
fixed-size key batch:

* GET runs are **deduplicated** — a key requested by five clients is
  probed once and fanned back to all five via per-request scatter maps;
* write runs are concatenated **in submission order** (the store's seq
  numbers make the last write win, exactly as if the clients had called
  the store back-to-back);
* a batch is dispatched when it reaches ``max_batch_keys``, when the
  oldest member has waited ``max_wait_ticks`` server ticks, or when a
  different-op request is queued behind the run (ops never reorder
  around each other, so GETs always see every earlier write).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

__all__ = ["ServerRequest", "RequestQueue", "Batch", "Batcher"]

OPS = ("get", "put", "delete")


@dataclasses.dataclass
class ServerRequest:
    """One client request.  The server fills the result fields and flips
    ``done``; closed-loop clients poll it."""
    rid: int
    op: str                            # get | put | delete
    keys: np.ndarray                   # (K,) int64
    values: np.ndarray | None = None   # (K, value_size) uint8, puts only
    done: bool = False
    found: np.ndarray | None = None    # (K,) bool, GETs only
    result: np.ndarray | None = None   # (K, value_size) uint8, GETs only
    submitted_tick: int = -1
    completed_tick: int = -1
    # the single per-shard epoch vector the GET was answered under (set by
    # the pipelined server; None when the cache answered every key — cache
    # entries are themselves epoch-stamped)
    epochs_served: tuple | None = None
    # causal-tracing context minted at admission for sampled requests
    # (a repro.obs.trace.TraceContext); None for the unsampled many
    trace: object | None = None

    def __post_init__(self) -> None:
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        self.keys = np.asarray(self.keys, np.int64)
        if self.values is not None:
            self.values = np.asarray(self.values, np.uint8)
            if self.values.shape[0] != self.keys.shape[0]:
                raise ValueError("values must align with keys")

    @property
    def latency_ticks(self) -> int:
        return self.completed_tick - self.submitted_tick


class RequestQueue:
    """Bounded FIFO.  ``submit`` returns False (and counts the rejection)
    when the queue is at capacity — the server never buffers unboundedly,
    clients feel the backpressure immediately."""

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        self._q: deque[ServerRequest] = deque()
        self.submitted = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def submit(self, req: ServerRequest, tick: int) -> bool:
        if len(self._q) >= self.capacity:
            self.rejected += 1
            return False
        req.submitted_tick = tick
        self._q.append(req)
        self.submitted += 1
        return True

    def head(self) -> ServerRequest | None:
        return self._q[0] if self._q else None

    def pop_n(self, n: int) -> list[ServerRequest]:
        return [self._q.popleft() for _ in range(n)]


@dataclasses.dataclass
class Batch:
    op: str
    requests: list
    keys: np.ndarray                # GETs: deduped; writes: concatenated
    values: np.ndarray | None       # puts only
    scatter: list | None            # GETs: per-request indices into keys


class Batcher:
    def __init__(self, max_batch_keys: int = 1024,
                 max_wait_ticks: int = 2) -> None:
        self.max_batch_keys = int(max_batch_keys)
        self.max_wait_ticks = int(max_wait_ticks)
        self.batches = 0
        self.coalesced_requests = 0
        self.request_keys = 0       # keys before dedup
        self.batch_keys = 0         # keys actually dispatched
        self.held = 0               # ticks spent waiting for a fuller batch

    def next_batch(self, queue: RequestQueue, tick: int) -> Batch | None:
        """Form (or hold) one batch from the queue front.  Returns None
        when the queue is empty or the front run is worth waiting on."""
        head = queue.head()
        if head is None:
            return None
        run: list[ServerRequest] = []
        total = 0
        for req in queue:
            if req.op != head.op:
                break
            # puts with and without explicit values cannot share one
            # store call — cut the run at the boundary (order preserved)
            if (head.op == "put"
                    and (req.values is None) != (head.values is None)):
                break
            if run and total + req.keys.shape[0] > self.max_batch_keys:
                break   # an oversized single request still forms a batch
            run.append(req)
            total += req.keys.shape[0]
            if total >= self.max_batch_keys:
                break
        whole_queue = len(run) == len(queue)
        waited = tick - head.submitted_tick
        if (whole_queue and total < self.max_batch_keys
                and waited < self.max_wait_ticks):
            self.held += 1
            return None
        queue.pop_n(len(run))
        self.batches += 1
        self.coalesced_requests += len(run)
        self.request_keys += total
        if head.op == "get":
            concat = np.concatenate([r.keys for r in run])
            uniq, inverse = np.unique(concat, return_inverse=True)
            scatter = []
            off = 0
            for r in run:
                scatter.append(inverse[off: off + r.keys.shape[0]])
                off += r.keys.shape[0]
            self.batch_keys += int(uniq.shape[0])
            return Batch("get", run, uniq, None, scatter)
        keys = np.concatenate([r.keys for r in run])
        values = None
        if head.op == "put" and head.values is not None:
            values = np.concatenate([r.values for r in run])
        self.batch_keys += int(keys.shape[0])
        return Batch(head.op, run, keys, values, None)
