"""HotKeyCache — learned-path-aware read-through cache.

Caches (key -> value row) for keys the snapshot/memtable path already
answered, so a hot key skips the whole lookup stack on its next GET.
Correctness comes from two invalidation rules, both visible in
``stats()``:

* **epoch** — every entry is stamped with its owning shard's structural
  epoch (``ShardedStore.shard_epochs()``: the flush/compaction event
  count that also versions the device state).  A probe whose entry
  carries a stale epoch drops it and misses: any memtable roll or
  compaction on the shard — including one triggered by value-log GC —
  conservatively flushes that shard's cached keys.
* **write** — PUT/DELETE batches flowing through the server explicitly
  drop their keys (an overwrite that stays in the memtable bumps no
  epoch, so the epoch rule alone would serve stale data).

Only *positive* results are cached — a not-found is never remembered, so
a fresh insert can't be shadowed by a stale negative.  Writes that
bypass the server (direct store calls) are outside the contract: route
all writes through the front end.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

__all__ = ["HotKeyCache"]


class HotKeyCache:
    def __init__(self, slots: int = 4096) -> None:
        self.slots = int(slots)
        # key -> (shard, epoch-at-fill, value row); insertion order is the
        # LRU order (lookup hits move_to_end)
        self._d: OrderedDict[int, tuple[int, int, np.ndarray]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.fills = 0
        self.evictions = 0
        self.inval_epoch = 0
        self.inval_write = 0

    def __len__(self) -> int:
        return len(self._d)

    def lookup(self, keys: np.ndarray, epochs: tuple,
               out: np.ndarray) -> np.ndarray:
        """Probe the cache; hit rows are written into ``out`` in place.
        Returns the (B,) hit mask.  ``epochs`` is the fleet's current
        epoch vector — entries stamped under an older epoch are dropped
        here (lazy invalidation) and report as misses."""
        hit = np.zeros(keys.shape[0], bool)
        for i in range(keys.shape[0]):
            k = int(keys[i])
            ent = self._d.get(k)
            if ent is None:
                self.misses += 1
                continue
            shard, epoch, val = ent
            if epochs[shard] != epoch:
                del self._d[k]
                self.inval_epoch += 1
                self.misses += 1
                continue
            self._d.move_to_end(k)
            out[i] = val
            hit[i] = True
            self.hits += 1
        return hit

    def fill(self, keys: np.ndarray, values: np.ndarray,
             owners: np.ndarray, epochs: tuple) -> None:
        """Admit found (key, value) pairs read under ``epochs``."""
        for i in range(keys.shape[0]):
            k = int(keys[i])
            shard = int(owners[i])
            if k in self._d:
                self._d.move_to_end(k)
            self._d[k] = (shard, epochs[shard], values[i].copy())
            self.fills += 1
            if len(self._d) > self.slots:
                self._d.popitem(last=False)
                self.evictions += 1

    def invalidate(self, keys: np.ndarray) -> int:
        """Drop keys a write batch superseded; returns how many were
        actually cached."""
        n = 0
        for k in np.unique(np.asarray(keys, np.int64)):
            if self._d.pop(int(k), None) is not None:
                n += 1
        self.inval_write += n
        return n

    def stats(self) -> dict:
        probes = self.hits + self.misses
        return {
            "slots": self.slots,
            "entries": len(self._d),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / max(probes, 1),
            "fills": self.fills,
            "evictions": self.evictions,
            "inval_epoch": self.inval_epoch,
            "inval_write": self.inval_write,
        }
