"""HotKeyCache — learned-path-aware read-through cache.

Caches (key -> value row) for keys the snapshot/memtable path already
answered, so a hot key skips the whole lookup stack on its next GET.
Correctness comes from two invalidation rules, both visible in
``stats()``:

* **epoch** — every entry is stamped with its owning shard's structural
  epoch (``ShardedStore.shard_epochs()``: the flush/compaction event
  count that also versions the device state).  A probe whose entry
  carries a stale epoch drops it and misses: any memtable roll or
  compaction on the shard — including one triggered by value-log GC —
  conservatively flushes that shard's cached keys.
* **write** — PUT/DELETE batches flowing through the server explicitly
  drop their keys (an overwrite that stays in the memtable bumps no
  epoch, so the epoch rule alone would serve stale data).

Only *positive* results are cached — a not-found is never remembered, so
a fresh insert can't be shadowed by a stale negative.  Writes that
bypass the server (direct store calls) are outside the contract: route
all writes through the front end.

Storage is row-oriented numpy (one values matrix, parallel key/epoch/
shard/stamp vectors, a key->row dict for point addressing): probes and
fills are batched array ops, not per-key python — the cache sits on the
serving hot path, where the pipelined server overlaps host admission
with device compute, so its host cost must stay small.  Recency is
tracked with a per-batch clock stamp and eviction takes the
oldest-stamped rows in bulk (batch-granular LRU).
"""

from __future__ import annotations

import numpy as np

__all__ = ["HotKeyCache"]


class HotKeyCache:
    def __init__(self, slots: int = 4096) -> None:
        self.slots = int(slots)
        self._slot: dict[int, int] = {}          # key -> row
        self._key = np.full(self.slots, -1, np.int64)    # -1 = free row
        self._epoch = np.zeros(self.slots, np.int64)
        self._shard = np.zeros(self.slots, np.int32)
        self._stamp = np.zeros(self.slots, np.int64)
        self._vals: np.ndarray | None = None     # (slots, V), first fill
        self._free = list(range(self.slots - 1, -1, -1))
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.fills = 0
        self.evictions = 0
        self.inval_epoch = 0
        self.inval_write = 0

    def __len__(self) -> int:
        return len(self._slot)

    def _release(self, rows: np.ndarray) -> None:
        for row in rows:
            del self._slot[int(self._key[row])]
            self._key[row] = -1
            self._free.append(int(row))

    def lookup(self, keys: np.ndarray, epochs: tuple,
               out: np.ndarray) -> np.ndarray:
        """Probe the cache; hit rows are written into ``out`` in place.
        Returns the (B,) hit mask.  ``epochs`` is the fleet's current
        epoch vector — entries stamped under an older epoch are dropped
        here (lazy invalidation) and report as misses."""
        n = keys.shape[0]
        hit = np.zeros(n, bool)
        if self._vals is None:
            self.misses += n
            return hit
        get = self._slot.get
        rows = np.fromiter((get(int(k), -1) for k in keys), np.int64, n)
        have = rows >= 0
        if have.any():
            r = rows[have]
            fresh = (self._epoch[r]
                     == np.asarray(epochs, np.int64)[self._shard[r]])
            stale = r[~fresh]
            if stale.shape[0]:
                self._release(stale)
                self.inval_epoch += int(stale.shape[0])
            live = np.nonzero(have)[0][fresh]
            out[live] = self._vals[r[fresh]]
            hit[live] = True
            self._clock += 1
            self._stamp[r[fresh]] = self._clock
        n_hit = int(hit.sum())
        self.hits += n_hit
        self.misses += n - n_hit
        return hit

    def fill(self, keys: np.ndarray, values: np.ndarray,
             owners: np.ndarray, epochs: tuple) -> None:
        """Admit found (key, value) pairs read under ``epochs``.  Keys
        within one fill must be unique (the batcher dedups)."""
        n = keys.shape[0]
        if n == 0:
            return
        if n > self.slots:
            # a fill larger than the cache: only the last ``slots`` pairs
            # could survive anyway (sequential insertion would evict the
            # rest), so admit exactly those and count the drop
            self.evictions += n - self.slots
            self.fills += n - self.slots
            keys = keys[-self.slots:]
            values = values[-self.slots:]
            owners = owners[-self.slots:]
            n = self.slots
        if self._vals is None:
            self._vals = np.zeros((self.slots, values.shape[1]),
                                  values.dtype)
        self._clock += 1
        get = self._slot.get
        rows = np.fromiter((get(int(k), -1) for k in keys), np.int64, n)
        new = rows < 0
        n_new = int(new.sum())
        need = n_new - len(self._free)
        if need > 0:
            # bulk-evict the oldest-stamped live rows — but never a row
            # this very fill is updating (evicting it would hand the row
            # to a new key and then overwrite it with the old key's
            # value: wrong data served for the new key)
            used = np.nonzero(self._key >= 0)[0]
            if n_new < n:
                used = np.setdiff1d(used, rows[~new])
            oldest = used[np.argpartition(self._stamp[used], need - 1)[:need]]
            self._release(oldest)
            self.evictions += need
        if n_new:
            new_rows = [self._free.pop() for _ in range(n_new)]
            for k, row in zip(keys[new], new_rows):
                self._slot[int(k)] = row
            rows[new] = new_rows
            self._key[rows[new]] = keys[new]
        ep = np.asarray(epochs, np.int64)
        ow = np.asarray(owners, np.int64)
        self._vals[rows] = values
        self._shard[rows] = ow
        self._epoch[rows] = ep[ow]
        self._stamp[rows] = self._clock
        self.fills += n

    def invalidate(self, keys: np.ndarray) -> int:
        """Drop keys a write batch superseded; returns how many were
        actually cached."""
        n = 0
        pop = self._slot.pop
        for k in np.unique(np.asarray(keys, np.int64)):
            row = pop(int(k), None)
            if row is not None:
                self._key[row] = -1
                self._free.append(row)
                n += 1
        self.inval_write += n
        return n

    def stats(self) -> dict:
        probes = self.hits + self.misses
        return {
            "slots": self.slots,
            "entries": len(self._slot),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / max(probes, 1),
            "fills": self.fills,
            "evictions": self.evictions,
            "inval_epoch": self.inval_epoch,
            "inval_write": self.inval_write,
        }
