"""PipelinedServer — multi-batch in-flight request serving.

The synchronous :class:`~repro.server.frontend.BourbonServer` runs
admission -> multi-get -> host sync -> maintenance strictly in sequence:
every coalesced batch blocks the host (``np.asarray``) before the next
one can even be formed, and every tick pays a full maintenance round.
This server splits the read path into the store's *dispatch*/*resolve*
halves (``ShardedStore.dispatch_get`` / ``resolve_get``, JAX async
dispatch underneath) and keeps up to ``max_inflight`` read batches
outstanding, so the host admits, dedups, and cache-probes batch N+1
while the device computes batch N.

Pipeline rules (the invariants the tests assert):

* **one epoch per pipeline** — every in-flight batch is pinned to the
  single epoch-versioned device state that was current at its dispatch,
  and nothing between two barriers may move the epochs: writes drain the
  pipeline first, and maintenance (which can roll memtables through GC
  relocation) runs only in the bubble after a drain.  Each batch is
  answered under exactly one epoch vector — snapshot consistency per
  batch is preserved by construction, and ``epoch_violations`` counts
  (and a drain repairs) any dispatch that would break it.
* **writes are barriers** — a write run at the queue front retires every
  in-flight read (those were admitted earlier, so they legitimately see
  the pre-write snapshot), then applies, then invalidates the cache.  A
  GET submitted after a PUT can therefore never see the pre-PUT value:
  the batcher never reorders ops, and the read dispatches only after the
  write applied.
* **maintenance rides the bubble** — coordinator rounds and store
  learning ticks run when the pipeline is drained (after a write
  barrier, on idle, or at most every ``bubble_every_ticks`` ticks), not
  on every tick.  ``force_drain_ticks`` bounds maintenance staleness
  under sustained read load by forcing a drain when no bubble happened
  for that long.
* **backpressure** — a full pipeline admits no more read batches; the
  bounded queue then fills and rejects, exactly the closed-loop contract
  of the synchronous server.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from .admission import Batch, ServerRequest
from .frontend import BourbonServer, ServerConfig

__all__ = ["PipelineConfig", "PipelinedServer"]


@dataclasses.dataclass
class PipelineConfig(ServerConfig):
    # read batches allowed in flight at once; 1 degenerates to the
    # synchronous dispatch-then-resolve order (still async inside a tick)
    max_inflight: int = 4
    # batches carried in flight across the tick boundary (capped at
    # max_inflight - 1): a carried batch overlaps device compute with the
    # clients' submit phase and the next tick's admission, so its resolve
    # wait is ~zero.  0 = retire everything dispatched within its tick
    carry: int = 2
    # run the bubble work (store ticks + coordinator round) at most once
    # per this many ticks when drain points are frequent — the sync
    # server pays it every tick
    bubble_every_ticks: int = 8
    # under sustained read load the pipeline may never drain on its own;
    # force a drain (and a maintenance bubble) after this many ticks
    # without one, so GC/checkpointing is delayed, never starved
    force_drain_ticks: int = 64


@dataclasses.dataclass
class _InflightRead:
    """One read batch between dispatch and retire."""
    batch: Batch
    found: np.ndarray          # (U,) over the batch's deduped keys
    vals: np.ndarray           # (U, value_size), cache hits prefilled
    miss: np.ndarray           # (U,) keys the store is answering
    pending: object            # ShardPendingBatch (store dispatch handle)
    dispatch_tick: int
    # obs: wall stamp from the compute stage handle at dispatch (0.0 when
    # the tick is unsampled) — "compute" is the in-flight span, the time
    # the device had to finish the batch before resolve blocked on it
    t_dispatch: float = 0.0
    # ValueFetch handle between _begin_retire and _finish_retire: the
    # batch's value-log reads running on the I/O pool while later batches
    # begin their own retire (or the next dispatch proceeds)
    fetch: object = None
    # causal-tracing spans (None when no member request is sampled): the
    # fan-in batch span, and the open device_compute span that crosses
    # tick boundaries with the in-flight batch
    tr_batch: object = None
    tr_compute: object = None


class PipelinedServer(BourbonServer):
    """Drop-in sibling of ``BourbonServer`` with a pipelined read path.
    Same admission/batching/cache/coordinator machinery (inherited),
    same request objects — only the tick loop overlaps instead of
    serializing.  Submits feel backpressure one layer out: with the
    pipeline at ``max_inflight`` the queue stops draining and rejects."""

    def __init__(self, store, cfg: PipelineConfig | None = None) -> None:
        cfg = cfg if cfg is not None else PipelineConfig()
        if cfg.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        super().__init__(store, cfg)
        self._inflight: deque[_InflightRead] = deque()
        self._last_bubble = 0
        # pipeline accounting
        self.batches_dispatched = 0
        self.batches_retired = 0
        self.cache_only_batches = 0     # answered without a store dispatch
        self.write_barriers = 0
        self.bubbles = 0
        self.forced_drains = 0
        self.max_depth_seen = 0
        self.epoch_violations = 0       # dispatches that saw a moved epoch

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    # ----------------------------------------------------------------- tick
    def tick(self) -> list[ServerRequest]:
        """One pipelined iteration: fill the pipeline (dispatches are
        non-blocking), honor write barriers, then retire what the device
        finished — resolving only after all of this tick's admission work
        has been overlapped with the device compute.  Returns the
        requests completed this tick."""
        done: list[ServerRequest] = []
        tick_no = self._tr.begin_tick()
        # prefetch the blocking halves: every batch already in flight had
        # its device work dispatched on an earlier tick, so start each
        # one's resolve (device sync + merge + value fetch) on the I/O
        # pool now — the workers chew on batch N while this tick admits
        # and dispatches batch N+1.  Without a pool the ValueFetch defers
        # its task to wait(), reproducing the old serial order, and the
        # results are bit-identical either way.
        for fl in self._inflight:
            self._begin_retire(fl)
        admitted = 0
        wrote = False
        while admitted < self.cfg.max_batches_per_tick:
            head = self.queue.head()
            if head is None:
                break
            if head.op == "get" and len(self._inflight) >= self.cfg.max_inflight:
                break                       # pipeline full: backpressure
            t0 = self._st_coalesce.begin()
            batch = self.batcher.next_batch(self.queue, self.ticks)
            self._st_coalesce.end(t0)
            if batch is None:
                break                       # batcher holding a partial run
            if batch.op == "get":
                done.extend(self._dispatch_reads(batch))
            else:
                # write barrier: every in-flight read resolves under the
                # pre-write snapshot it was pinned to, then the write
                # applies, then the cache drops the superseded keys
                done.extend(self._drain())
                self._apply_writes(batch)
                done.extend(batch.requests)
                self.write_barriers += 1
                wrote = True
            admitted += 1
        # retire: keep up to ``carry`` batches in flight across the tick
        # boundary — a carried batch computes through the clients' next
        # submit phase and the following admission, so by the time it is
        # retired the resolve wait is ~zero (the whole device latency is
        # hidden).  When this tick neither admitted nor has queued work,
        # there is no overlap partner left — drain so results are not
        # held back from idle clients
        if admitted == 0 and len(self.queue) == 0:
            done.extend(self._drain())
        else:
            target = max(0, min(self.cfg.carry, self.cfg.max_inflight - 1))
            to_retire: list[_InflightRead] = []
            while len(self._inflight) > target:
                to_retire.append(self._inflight.popleft())
            done.extend(self._retire_many(to_retire))
        if (self._inflight
                and self.ticks - self._last_bubble
                >= self.cfg.force_drain_ticks):
            done.extend(self._drain())      # bounded maintenance staleness
            self.forced_drains += 1
        if not done and not self._inflight:
            # an idle tick is still the passage of (virtual) time
            for sh in self.store.shards:
                sh.clock.advance(self.cfg.idle_tick_us)
        self._maybe_bubble(idle=not done and len(self.queue) == 0)
        m = self.store.maintenance_us()
        self.max_maintenance_tick_us = max(self.max_maintenance_tick_us,
                                           m - self._maint_us_seen)
        self._maint_us_seen = m
        if wrote:
            # durability barrier before acknowledging: every write batch
            # this tick applied becomes durable under ONE coalesced
            # group-commit sync per shard (a no-op per-append writer makes
            # this free) — the WAL commit contract's sync point
            wsp = self._ct.begin_span("wal_sync", self._wal_parent)
            self.store.wal_sync()
            self._ct.end_span(wsp)
            self._wal_parent = None
        for r in done:
            r.completed_tick = self.ticks
            r.done = True
            self._ct.complete(r.trace, tick=self.ticks)
        self.completed += len(done)
        self._tr.end_tick(tick_no)
        self.ticks += 1
        return done

    def run_until_drained(self, max_ticks: int = 100000
                          ) -> list[ServerRequest]:
        out: list[ServerRequest] = []
        for _ in range(max_ticks):
            if not len(self.queue) and not self._inflight:
                break
            out.extend(self.tick())
        return out

    # ----------------------------------------------------------------- reads
    def _dispatch_reads(self, batch: Batch) -> list[ServerRequest]:
        """Probe the cache and launch the store lookup for the misses —
        non-blocking.  Returns completed requests only when the cache
        answered the whole batch (no store work to wait on)."""
        uniq = batch.keys
        bt = self._ct.join_batch(batch.requests)
        vals = np.zeros((uniq.shape[0], self._value_size), np.uint8)
        found = np.zeros(uniq.shape[0], bool)
        if self.cache is not None:
            t0 = self._st_cache.begin()
            hit = self.cache.lookup(uniq, self.store.shard_epochs(), vals)
            self._st_cache.end(t0)
            found |= hit
            self.served_from_cache += int(hit.sum())
        else:
            hit = np.zeros(uniq.shape[0], bool)
        miss = ~hit
        if not miss.any():
            self.cache_only_batches += 1
            self._ct.end_span(bt)
            return self._scatter(batch, found, vals, epochs=None)
        t0 = self._st_dispatch.begin()
        dsp = self._ct.begin_span("dispatch", bt)
        pb = self.store.dispatch_get(uniq[miss], with_values=True,
                                     trace=dsp)
        self._ct.end_span(dsp, stage="dispatch")
        self._st_dispatch.end(t0)
        completed: list[ServerRequest] = []
        if (self._inflight
                and pb.epochs != self._inflight[0].pending.epochs):
            # should be unreachable (writes barrier, maintenance runs in
            # bubbles): an epoch moved mid-pipeline.  Count it and repair
            # by retiring the old-epoch batches now — each batch still
            # resolves under the single state it was pinned to
            self.epoch_violations += 1
            completed = self._drain()
        self._inflight.append(_InflightRead(batch, found, vals, miss, pb,
                                            self.ticks,
                                            self._st_compute.begin(),
                                            tr_batch=bt,
                                            tr_compute=self._ct.begin_span(
                                                "device_compute", bt)))
        self.batches_dispatched += 1
        self.max_depth_seen = max(self.max_depth_seen, len(self._inflight))
        return completed

    def _begin_retire(self, fl: _InflightRead) -> _InflightRead:
        """Non-blocking first half of a retire: hand the batch's blocking
        half (device sync + merge + value fetch) to the I/O pool.  With a
        pool attached, beginning several retires before finishing any
        overlaps their resolves with each other and with the next batch's
        device dispatch; without one the work runs inside
        :meth:`_finish_retire`, the original serial order.  Idempotent —
        the tick-start prefetch may begin a batch that a drain later this
        tick begins again."""
        if fl.fetch is not None:
            return fl
        t0 = self._st_resolve.begin()
        fl.fetch = self.store.resolve_get_async(fl.pending)
        self._st_resolve.end(t0)
        # compute = dispatch->retire in-flight span: how long the device
        # had before the host blocked on this batch (crosses ticks; the
        # handle no-ops when the dispatch tick was unsampled)
        self._st_compute.end(fl.t_dispatch)
        self._ct.end_span(fl.tr_compute, stage="device_compute")
        return fl

    def _finish_retire(self, fl: _InflightRead) -> list[ServerRequest]:
        """Blocking second half: join the value fetch and fan the results
        back out."""
        # the exposed join: flow-linked from the io_task span that ran
        # the blocking half on the pool (fan-in back onto the tick loop)
        vsp = self._ct.begin_span("value_fetch", fl.tr_batch,
                                  link=fl.fetch.span)
        f, v = fl.fetch.wait()
        self._ct.end_span(vsp, stage="value_fetch")
        fl.found[fl.miss] = f
        fl.vals[fl.miss] = v
        self.store_probe_keys += int(fl.miss.sum())
        self._charge_read_clocks(fl.pending.owner)
        pos = np.nonzero(fl.miss)[0][f]
        # fill under the batch's pinned epoch vector — equal to the live
        # one (writes barrier; maintenance runs in bubbles)
        self._fill_cache(fl.batch.keys[pos], fl.vals[pos],
                         fl.pending.epochs)
        self.batches_retired += 1
        self._ct.end_span(fl.tr_batch)
        return self._scatter(fl.batch, fl.found, fl.vals,
                             epochs=fl.pending.epochs)

    def _retire(self, fl: _InflightRead) -> list[ServerRequest]:
        """Resolve one in-flight batch and fan the results back out."""
        return self._finish_retire(self._begin_retire(fl))

    def _retire_many(self, fls: list[_InflightRead]) -> list[ServerRequest]:
        """Retire a group: begin every batch's value fetch before joining
        any, so the fetches run side by side on the I/O pool.  Requests
        still complete in pipeline (dispatch) order — the joins are
        ordered, only the I/O underneath is concurrent."""
        out: list[ServerRequest] = []
        for fl in fls:
            self._begin_retire(fl)
        for fl in fls:
            out.extend(self._finish_retire(fl))
        return out

    def _scatter(self, batch: Batch, found, vals, epochs) -> list:
        for req, idx in zip(batch.requests, batch.scatter):
            req.found = found[idx]
            req.result = vals[idx]
            # the single epoch vector this request was answered under —
            # None when the cache answered everything (cache entries are
            # themselves epoch-stamped); tests assert on it
            req.epochs_served = epochs
        return batch.requests

    def _drain(self) -> list[ServerRequest]:
        """Retire every in-flight batch (pipeline barrier)."""
        fls = list(self._inflight)
        self._inflight.clear()
        return self._retire_many(fls)

    # ----------------------------------------------------------- maintenance
    def _maybe_bubble(self, idle: bool) -> None:
        """Run the bubble work — store learning ticks plus one
        coordinator round — only at a drain point, and (unless idle or
        just past a barrier) at most every ``bubble_every_ticks``."""
        if self._inflight:
            return                          # not a drain point
        due = (idle
               or self.ticks - self._last_bubble
               >= self.cfg.bubble_every_ticks)
        if not due:
            return
        msp = self._ct.begin_maintenance(self.ticks, kind="bubble")
        for sh in self.store.shards:
            sh._tick()
        if self.coordinator is not None:
            self.coordinator.tick()
        self._ct.end_maintenance(msp)
        self._last_bubble = self.ticks
        self.bubbles += 1

    # ----------------------------------------------------------------- stats
    def stats(self) -> dict:
        out = super().stats()
        out["pipeline"] = {
            "max_inflight": self.cfg.max_inflight,
            "inflight": len(self._inflight),
            "dispatched": self.batches_dispatched,
            "retired": self.batches_retired,
            "cache_only_batches": self.cache_only_batches,
            "write_barriers": self.write_barriers,
            "bubbles": self.bubbles,
            "forced_drains": self.forced_drains,
            "max_depth_seen": self.max_depth_seen,
            "epoch_violations": self.epoch_violations,
        }
        return out
