"""Batched request-serving front end over the sharded Bourbon store: a
bounded :class:`RequestQueue` + coalescing :class:`Batcher`, a
snapshot-consistent multi-get, the epoch-invalidated
:class:`HotKeyCache`, and the :class:`FleetMaintenanceCoordinator` that
staggers and budgets per-shard GC/checkpointing.  Two tick loops serve
requests: the synchronous :class:`BourbonServer` and the
:class:`PipelinedServer`, which keeps up to ``max_inflight`` read
batches in flight (dispatch/resolve split, writes as barriers,
maintenance in post-drain bubbles).  See README.md in this package for
the architecture."""

from .admission import Batch, Batcher, RequestQueue, ServerRequest
from .cache import HotKeyCache
from .coordinator import CoordinatorConfig, FleetMaintenanceCoordinator
from .frontend import BourbonServer, ServerConfig
from .pipeline import PipelineConfig, PipelinedServer

__all__ = ["Batch", "Batcher", "BourbonServer", "CoordinatorConfig",
           "FleetMaintenanceCoordinator", "HotKeyCache", "PipelineConfig",
           "PipelinedServer", "RequestQueue", "ServerConfig",
           "ServerRequest"]
