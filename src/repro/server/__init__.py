"""Batched request-serving front end over the sharded Bourbon store: a
bounded :class:`RequestQueue` + coalescing :class:`Batcher`, a
snapshot-consistent multi-get, the epoch-invalidated
:class:`HotKeyCache`, and the :class:`FleetMaintenanceCoordinator` that
staggers and budgets per-shard GC/checkpointing.  See README.md in this
package for the architecture."""

from .admission import Batch, Batcher, RequestQueue, ServerRequest
from .cache import HotKeyCache
from .coordinator import CoordinatorConfig, FleetMaintenanceCoordinator
from .frontend import BourbonServer, ServerConfig

__all__ = ["Batch", "Batcher", "BourbonServer", "CoordinatorConfig",
           "FleetMaintenanceCoordinator", "HotKeyCache", "RequestQueue",
           "ServerConfig", "ServerRequest"]
