"""Serving front-end benchmark (suite ``serve``).

Part A — request serving: C closed-loop clients each keep one small GET
outstanding against a sharded store.  The **batched** path runs them
through :class:`repro.server.BourbonServer` (queue -> coalesce/dedup ->
HotKeyCache -> one snapshot-consistent multi-get per batch); the
**naive** path answers each request with its own ``get_batch`` call, the
way a client of the bare ``ShardedStore`` drives it today.  Reported per
path: throughput (requests/s), p50/p99 request wall latency, and the
cache hit rate — the LearnedKV-style end-to-end argument that the
serving layer, not the microbenchmark, decides what the learned index
is worth.  (Since the host-fallback lookup was fused into one jitted
program, the naive loop is ~100x faster than it used to be and the
batched-vs-naive gap narrows sharply at small scale — the pipelined
comparison below is the headline now.)

Part A2 — pipelined vs synchronous tick loop: async closed-loop clients
(up to ``PIPE_DEPTH`` requests outstanding each — the regime where
batches keep arriving while earlier ones are in flight) at 16/64/256
drive the synchronous :class:`BourbonServer` (admission -> multi-get ->
host sync -> maintenance in sequence, one blocking host sync per batch)
against the :class:`~repro.server.PipelinedServer` (dispatch/resolve
split, up to ``max_inflight`` batches outstanding with ``carry`` crossing
tick boundaries, maintenance only in drain bubbles).  Both arms serve
the same 8-shard fleet with the same ``max_batch_keys``; timing starts
after a warm phase so neither arm pays XLA compiles.  Reported per arm:
throughput (requests/s) and p50/p99 request latency in *ticks*; the
``serve/pipeline.speedup`` lines carry the acceptance metric (pipelined
>= 1.5x sync at 64 clients).  The overlap headroom is host-core-bound —
on a 2-core container XLA steals the spare core whenever the sync arm
blocks, compressing the ratio; the emitted ``cores=`` field says what
the number was measured on.

The A2 fleet also runs a **threaded** arm: the same pipelined server
with a host I/O pool (``io_workers=IO_WORKERS``), which hands each
batch's blocking half — the device sync, overlay merge, and value-log
fetch — to a worker so it overlaps the next batch's admission and
dispatch.  ``serve/pipelined_io.c*`` reports its throughput plus the
measured overlap ratio (hidden / (hidden + exposed) resolve time); the
``serve/pipeline.io_speedup.c*`` lines carry the acceptance metric
(threaded >= the PR 5 pipelined baseline at 64/256 clients), with
``epoch_violations == 0`` still asserted on the threaded arm.

Part A4 — group-commit WAL: durable-write arms on ``fsync=True`` stores.
Async closed-loop clients drive a write-heavy (100% PUT) and a mixed
YCSB-A-shaped (~50/50 GET/PUT) stream through the pipelined server
twice: once with the per-append writer (every WAL append fsyncs) and
once with the group-commit queue (appends enqueue; the tick's single
``wal_sync`` barrier makes one committer flush+fsync cover every batch
applied that tick).  Reported per arm: throughput, p50/p99 request
latency in ticks, fsyncs per request, and the coalesce factor
(appends/commits); the ``serve/wal.fsync_reduction.*`` lines carry the
acceptance metric (>= 4x fewer fsyncs per op with group commit on the
write-heavy arm).  Durability is identical across arms — both fsync
everything acknowledged before the tick completes its requests.

Part B — fleet maintenance: an update-heavy stream (sustained
overwrites) drives value-log GC on every shard.  Uncoordinated, each
shard's MaintenanceScheduler fires from its own write ticks and the
fleet can stall together; with the :class:`FleetMaintenanceCoordinator`
the same work is staggered round-robin under a per-tick virtual-clock
budget.  Reported: the worst single-tick maintenance charge (the stall
metric) and the reclamation achieved — coordination must bound the
former without giving up the latter.  Reclamation is compared on the
**final value-log footprint** (space actually held at quiesce), not raw
bytes_reclaimed: eager uncoordinated GC relocates live entries that the
next overwrite round kills, so it re-reclaims the same logical space
through its own churn and inflates the raw counter (the `moved=`
numbers make the effect visible).

``REPRO_BENCH_SMOKE=1`` shrinks everything so CI can run the whole loop
in seconds.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.core import LSMConfig, StoreConfig
from repro.core.engine import EngineConfig
from repro.distributed import ShardedConfig, ShardedStore
from repro.obs import ObsConfig
from repro.server import (BourbonServer, CoordinatorConfig, PipelineConfig,
                          PipelinedServer, ServerConfig, ServerRequest)

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_KEYS = (1 << 13) if SMOKE else (1 << 15)
CLIENTS = 64
KEYS_PER_REQ = 8
ROUNDS = 6 if SMOKE else 48           # requests per client (part A)
W_ROUNDS = 8 if SMOKE else 12         # overwrite rounds (part B)
VALUE_SIZE = 16
BUDGET_US = 2048.0
# part A2 (pipelined vs sync tick loop)
PIPE_CLIENTS = (16, 64) if SMOKE else (16, 64, 256)
PIPE_SHARDS = 4 if SMOKE else 8
PIPE_KEYS_PER_REQ = 32                # multi-get reads (feature batches)
PIPE_DEPTH = 2                        # requests outstanding per client
PIPE_ROUNDS = 8 if SMOKE else 36
PIPE_WARM = 2 if SMOKE else 4         # untimed leading rounds per client
MAX_INFLIGHT = 8
PIPE_CARRY = 1
IO_WORKERS = 2                        # threaded arm: host I/O pool size
# part A4 (group-commit WAL): durable-write arms on fsync=True stores.
# keys_per_req == max_batch_keys so every PUT request is its own batch
# (its own WAL append per touched shard) — the per-append writer then
# fsyncs once per batch per shard while the group-commit queue covers
# every batch the tick applied with one committer fsync per shard.
GC_CLIENTS = 16
GC_ROUNDS = 6 if SMOKE else 16
GC_KEYS_PER_REQ = 128
GC_SHARDS = 4
GC_BATCHES_PER_TICK = 16
# part A3 (obs tracing overhead): interleaved obs-off / obs-on /
# obs-on+causal-tracing arms at the acceptance client count; best-of-N
# per arm absorbs scheduler noise
OBS_CLIENTS = 64
OBS_TRIALS = 4 if SMOKE else 3        # best-of per arm absorbs CPU noise
OBS_ROUNDS = 16 if SMOKE else 36      # longer than PIPE_ROUNDS in smoke:
OBS_SAMPLE_EVERY = 4                  # the 5% gate needs a stable ratio
TRACE_SAMPLE_EVERY = 64               # causal-tracing arm: the default


def _store_cfg(**kw) -> StoreConfig:
    """Shared store geometry; ``kw`` overrides (the A4 durability arms
    pass ``fsync=True`` and toggle ``wal_group_commit``)."""
    return StoreConfig(granularity="level", policy="always",
                       value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                       lsm=LSMConfig(memtable_cap=1 << 11, file_cap=1 << 12,
                                     l1_cap_records=1 << 14),
                       engine=EngineConfig(seg_cap=4096), **kw)


def _open_store(path: str, keys: np.ndarray, n_shards: int,
                **kw) -> ShardedStore:
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    st = ShardedStore.open(path, ShardedConfig(n_shards=n_shards,
                                               boundaries=bounds),
                           _store_cfg(**kw))
    return st


def _load(st: ShardedStore, keys: np.ndarray) -> None:
    for off in range(0, keys.shape[0], 1 << 12):
        st.put_batch(keys[off: off + (1 << 12)])
    st.flush_all()
    st.learn_all()


def _request_streams(keys: np.ndarray, seed: int, clients: int = CLIENTS,
                     rounds: int = ROUNDS,
                     keys_per_req: int = KEYS_PER_REQ
                     ) -> list[list[np.ndarray]]:
    """Per-client request key arrays: 80% of probes from a hot 10% of the
    keyspace (the HotKeyCache's reason to exist), 20% uniform."""
    rng = np.random.default_rng(seed)
    hot = keys[: max(keys.shape[0] // 10, keys_per_req)]
    streams = []
    for _ in range(clients):
        reqs = []
        for _ in range(rounds):
            n_hot = int((rng.random(keys_per_req) < 0.8).sum())
            ks = np.concatenate([rng.choice(hot, n_hot),
                                 rng.choice(keys, keys_per_req - n_hot)])
            reqs.append(ks.astype(np.int64))
        streams.append(reqs)
    return streams


def _percentiles(lat_us: list[float]) -> tuple[float, float]:
    a = np.asarray(lat_us)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _run_batched(st: ShardedStore, streams) -> float:
    srv = BourbonServer(st, ServerConfig(
        max_batch_keys=1024, max_wait_ticks=1,
        queue_capacity=2 * CLIENTS, coordinate_maintenance=True,
        coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US)))
    nxt = [0] * CLIENTS               # next request index per client
    pending: list[ServerRequest | None] = [None] * CLIENTS
    lat: list[float] = []
    total = CLIENTS * ROUNDS
    served = 0
    rid = 0
    t_start = time.perf_counter()
    while served < total:
        for c in range(CLIENTS):
            if pending[c] is not None or nxt[c] >= ROUNDS:
                continue
            r = ServerRequest(rid, "get", streams[c][nxt[c]])
            r._t0 = time.perf_counter()
            if srv.submit(r):         # full queue = backpressure: retry
                rid += 1
                pending[c] = r
                nxt[c] += 1
        srv.tick()
        now = time.perf_counter()
        for c in range(CLIENTS):
            r = pending[c]
            if r is not None and r.done:
                lat.append((now - r._t0) * 1e6)
                pending[c] = None
                served += 1
    dt = time.perf_counter() - t_start
    p50, p99 = _percentiles(lat)
    s = srv.stats()
    hit = s["cache"]["hit_rate"]
    emit(f"serve/batched.c{CLIENTS}", dt / total * 1e6,
         f"reqs_per_s={total / dt:.0f} p50_us={p50:.0f} p99_us={p99:.0f} "
         f"cache_hit={hit:.2f} batches={s['batches']} "
         f"dedup={1 - s['batch_keys'] / max(s['request_keys'], 1):.2f} "
         f"rejected={s['rejected']}")
    return total / dt


def _run_naive(st: ShardedStore, streams) -> float:
    """One store call per request, FIFO over clients — no queue, no
    coalescing, no cache: the pre-server client experience."""
    lat: list[float] = []
    total = CLIENTS * ROUNDS
    t_start = time.perf_counter()
    for i in range(ROUNDS):
        for c in range(CLIENTS):
            t0 = time.perf_counter()
            st.get_batch(streams[c][i], with_values=True)
            lat.append((time.perf_counter() - t0) * 1e6)
    dt = time.perf_counter() - t_start
    p50, p99 = _percentiles(lat)
    emit(f"serve/naive.c{CLIENTS}", dt / total * 1e6,
         f"reqs_per_s={total / dt:.0f} p50_us={p50:.0f} p99_us={p99:.0f}")
    return total / dt


def _closed_loop_async(srv, streams, clients: int, rounds: int,
                       depth: int = PIPE_DEPTH, warm: int = PIPE_WARM
                       ) -> tuple[float, float, float, dict]:
    """Drive ``srv`` with ``clients`` async closed-loop clients, each
    keeping up to ``depth`` requests outstanding; returns (reqs/s,
    p50_ticks, p99_ticks, stats).  The first ``warm`` rounds per client
    are untimed (XLA compiles, cache warm-up) so both arms are measured
    in steady state.  Latency is in server ticks (completed - submitted),
    the schedule-independent cost a request pays for batching and
    pipelining.  Stream items are GET key arrays, or ``(op, keys)``
    tuples for the mixed/write arms."""
    nxt = [0] * clients
    pending: list[list[ServerRequest]] = [[] for _ in range(clients)]
    lat_ticks: list[int] = []
    total = clients * rounds
    warm_total = clients * warm
    served = 0
    rid = 0
    t_start = None
    while served < total:
        if served >= warm_total and t_start is None:
            t_start = time.perf_counter()
        for c in range(clients):
            while len(pending[c]) < depth and nxt[c] < rounds:
                item = streams[c][nxt[c]]
                op, ks = item if isinstance(item, tuple) else ("get", item)
                r = ServerRequest(rid, op, ks)
                if not srv.submit(r):   # backpressure: retry next tick
                    break
                rid += 1
                pending[c].append(r)
                nxt[c] += 1
        srv.tick()
        for c in range(clients):
            done = [r for r in pending[c] if r.done]
            for r in done:
                pending[c].remove(r)
                if served >= warm_total:
                    lat_ticks.append(r.latency_ticks)
                served += 1
    dt = time.perf_counter() - t_start
    p50, p99 = _percentiles(lat_ticks)
    return (total - warm_total) / dt, p50, p99, srv.stats()


def _run_pipeline_arm(st: ShardedStore, keys: np.ndarray,
                      clients: int) -> tuple[float, float, float]:
    """Part A2: identical async clients and batch geometry against the
    synchronous tick loop, the pipelined server, and the pipelined
    server with the host I/O pool attached; returns
    (sync_rps, pipelined_rps, threaded_rps)."""
    streams = _request_streams(keys, seed=20 + clients, clients=clients,
                               rounds=PIPE_ROUNDS,
                               keys_per_req=PIPE_KEYS_PER_REQ)
    qcap = 2 * PIPE_DEPTH * clients
    srv = BourbonServer(st, ServerConfig(
        max_batch_keys=1024, max_wait_ticks=0, queue_capacity=qcap,
        max_batches_per_tick=8, coordinate_maintenance=True,
        coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US)))
    sync_rps, p50, p99, s = _closed_loop_async(srv, streams, clients,
                                               PIPE_ROUNDS)
    emit(f"serve/sync_tick.c{clients}", 1e6 / sync_rps,
         f"reqs_per_s={sync_rps:.0f} p50_ticks={p50:.0f} "
         f"p99_ticks={p99:.0f} cache_hit={s['cache']['hit_rate']:.2f} "
         f"batches={s['batches']}")
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=1024, max_wait_ticks=0, queue_capacity=qcap,
        max_batches_per_tick=8, max_inflight=MAX_INFLIGHT,
        carry=PIPE_CARRY, coordinate_maintenance=True,
        coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US)))
    pipe_rps, p50, p99, s = _closed_loop_async(srv, streams, clients,
                                               PIPE_ROUNDS)
    p = s["pipeline"]
    emit(f"serve/pipelined.c{clients}", 1e6 / pipe_rps,
         f"reqs_per_s={pipe_rps:.0f} p50_ticks={p50:.0f} "
         f"p99_ticks={p99:.0f} cache_hit={s['cache']['hit_rate']:.2f} "
         f"batches={s['batches']} max_depth={p['max_depth_seen']} "
         f"bubbles={p['bubbles']} "
         f"epoch_violations={p['epoch_violations']}")
    # threaded arm: same pipelined server, host I/O pool attached — each
    # in-flight batch's resolve runs on a worker while the tick loop
    # admits and dispatches the next one
    vf0 = st.stats()["value_fetch"]
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=1024, max_wait_ticks=0, queue_capacity=qcap,
        max_batches_per_tick=8, max_inflight=MAX_INFLIGHT,
        carry=PIPE_CARRY, coordinate_maintenance=True,
        io_workers=IO_WORKERS,
        coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US)))
    try:
        io_rps, p50, p99, s = _closed_loop_async(srv, streams, clients,
                                                 PIPE_ROUNDS)
    finally:
        srv.shutdown()
    p = s["pipeline"]
    vf1 = st.stats()["value_fetch"]
    hid = vf1["hidden_us"] - vf0["hidden_us"]
    exp = vf1["exposed_us"] - vf0["exposed_us"]
    overlap = hid / max(hid + exp, 1e-9)
    emit(f"serve/pipelined_io.c{clients}", 1e6 / io_rps,
         f"reqs_per_s={io_rps:.0f} p50_ticks={p50:.0f} "
         f"p99_ticks={p99:.0f} cache_hit={s['cache']['hit_rate']:.2f} "
         f"batches={s['batches']} io_workers={IO_WORKERS} "
         f"io_tasks={s['io']['submitted']} overlap={overlap:.2f} "
         f"epoch_violations={p['epoch_violations']}")
    assert p["epoch_violations"] == 0, "threaded arm broke epoch pinning"
    return sync_rps, pipe_rps, io_rps


def _run_obs_arm(st: ShardedStore, keys: np.ndarray, enabled: bool,
                 seed: int, trace_every: int = 0):
    """One pipelined serving run; returns (reqs/s, server) — the server
    is kept alive so an instrumented arm's snapshot/timeline/trace ring
    can be exported after the measurement.  Every arm runs the
    *threaded* server (``io_workers=IO_WORKERS``) so the 5% overhead
    gates cover tracing on the I/O-pool path too.  ``trace_every``
    feeds ``ObsConfig.trace_sample_every``: 0 disables causal tracing
    (stage tracer only), >0 samples one request in that many."""
    streams = _request_streams(keys, seed=seed, clients=OBS_CLIENTS,
                               rounds=OBS_ROUNDS,
                               keys_per_req=PIPE_KEYS_PER_REQ)
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=1024, max_wait_ticks=0,
        queue_capacity=2 * PIPE_DEPTH * OBS_CLIENTS,
        max_batches_per_tick=8, max_inflight=MAX_INFLIGHT,
        carry=PIPE_CARRY, coordinate_maintenance=True,
        io_workers=IO_WORKERS,
        coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US),
        obs=ObsConfig(enabled=enabled, sample_every=OBS_SAMPLE_EVERY,
                      trace_sample_every=trace_every)))
    try:
        rps, _, _, _ = _closed_loop_async(srv, streams, OBS_CLIENTS,
                                          OBS_ROUNDS)
    finally:
        srv.shutdown()      # closes the pool; snapshot/timeline survive
    return rps, srv


# arm → (ObsConfig.enabled, ObsConfig.trace_sample_every)
_OBS_ARMS = {"off": (False, 0),                      # uninstrumented
             "on": (True, 0),                        # stage tracer only
             "trace": (True, TRACE_SAMPLE_EVERY)}    # + causal tracing


def _obs_overhead(st: ShardedStore, keys: np.ndarray) -> None:
    """Part A3: the tracing-overhead acceptance arms.  Identical
    pipelined serving runs with obs off, obs on (stage tracer), and obs
    on + causal tracing at the default sample rate — interleaved (off
    first, so an instrumented arm never rides a warmer store), best-of
    -``OBS_TRIALS`` per arm.  The traced arm then reports the per-stage
    breakdown, and its snapshot + timeline + span-ring summary land in
    the suite's JSON artifact."""
    best = {arm: 0.0 for arm in _OBS_ARMS}
    srv_tr = None
    for t in range(OBS_TRIALS):
        for arm, (enabled, trace_every) in _OBS_ARMS.items():
            rps, srv = _run_obs_arm(st, keys, enabled, seed=40 + t,
                                    trace_every=trace_every)
            best[arm] = max(best[arm], rps)
            if arm == "trace":
                srv_tr = srv
    snap = srv_tr.obs.snapshot()
    for s in snap["server_stage_us"]["samples"]:
        stage = dict(s["labels"])["stage"]
        v = s["value"]
        emit(f"serve/obs_stage.{stage}", v["sum"] / max(v["count"], 1),
             f"count={v['count']} max_us={v['max']:.0f}")
    ratio = best["on"] / max(best["off"], 1e-9)
    emit(f"serve/obs_overhead.c{OBS_CLIENTS}", 0.0,
         f"obs_on_rps={best['on']:.0f} obs_off_rps={best['off']:.0f} "
         f"ratio={ratio:.3f} within_5pct={ratio >= 0.95} "
         f"sample_every={OBS_SAMPLE_EVERY} trials={OBS_TRIALS}")
    ct = srv_tr.obs.ctrace
    spans = ct.spans()
    tratio = best["trace"] / max(best["off"], 1e-9)
    pv = srv_tr.stats()["pipeline"]["epoch_violations"]
    emit(f"serve/obs_trace_overhead.c{OBS_CLIENTS}", 0.0,
         f"trace_rps={best['trace']:.0f} obs_off_rps={best['off']:.0f} "
         f"ratio={tratio:.3f} within_5pct={tratio >= 0.95} "
         f"trace_sample_every={TRACE_SAMPLE_EVERY} "
         f"traced={ct.traced_requests} completed={ct.completed_requests} "
         f"spans={len(spans)} epoch_violations={pv}")
    assert pv == 0, "traced threaded arm broke epoch pinning"
    common.set_artifact_extra("obs", {
        "snapshot": snap,
        "timeline": srv_tr.obs.timeline(),
        "trace": {"sample_every": TRACE_SAMPLE_EVERY,
                  "traced_requests": ct.traced_requests,
                  "completed_requests": ct.completed_requests,
                  "spans_in_ring": len(spans),
                  "span_names": sorted({s.name for s in spans})}})


def _obs_part() -> None:
    """Self-contained store setup + part A3 (shared by the full suite
    and the ``serve_obs`` CI gate)."""
    rng = np.random.default_rng(1)
    keys = rng.permutation(np.arange(1, N_KEYS + 1, dtype=np.int64) * 7)
    d = tempfile.mkdtemp(prefix="bourbon_serve_obs_")
    try:
        st = _open_store(os.path.join(d, "db"), keys, n_shards=PIPE_SHARDS)
        _load(st, keys)
        # pre-compile the pow2 probe-pad shapes so a mid-measurement XLA
        # compile can't skew either arm
        rng = np.random.default_rng(4)
        pad = 64
        while pad <= 4096:
            st.get_batch(rng.choice(keys, min(pad, keys.shape[0]),
                                    replace=False), with_values=True)
            pad *= 2
        _obs_overhead(st, keys)
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run_obs_only() -> None:
    """Entry point of the ``serve_obs`` suite (the CI overhead gate)."""
    _obs_part()


def _mixed_streams(keys: np.ndarray, seed: int, clients: int, rounds: int,
                   keys_per_req: int, put_frac: float) -> list[list]:
    """Per-client ``(op, keys)`` request streams: YCSB-A-shaped at
    ``put_frac=0.5``, pure write pressure at ``1.0``.  PUT keys are drawn
    from the loaded keyspace (overwrites — steady WAL pressure with no
    store growth)."""
    rng = np.random.default_rng(seed)
    streams = []
    for _ in range(clients):
        reqs = []
        for _ in range(rounds):
            op = "put" if rng.random() < put_frac else "get"
            reqs.append((op,
                         rng.choice(keys, keys_per_req).astype(np.int64)))
        streams.append(reqs)
    return streams


def _run_wal_arm(kind: str, keys: np.ndarray, group_commit: bool,
                 put_frac: float) -> dict:
    """One part-A4 durability arm: a fresh ``fsync=True`` store (the WAL
    writer is the variable under test), pipelined server, async
    closed-loop clients; WAL counters are measured as deltas so the load
    phase doesn't pollute them."""
    wal_kind = "group" if group_commit else "per_append"
    d = tempfile.mkdtemp(prefix=f"bourbon_serve_wal_{wal_kind}_")
    try:
        st = _open_store(os.path.join(d, "db"), keys, n_shards=GC_SHARDS,
                         fsync=True, wal_group_commit=group_commit)
        _load(st, keys)
        streams = _mixed_streams(keys, seed=60, clients=GC_CLIENTS,
                                 rounds=GC_ROUNDS,
                                 keys_per_req=GC_KEYS_PER_REQ,
                                 put_frac=put_frac)
        srv = PipelinedServer(st, PipelineConfig(
            max_batch_keys=GC_KEYS_PER_REQ, max_wait_ticks=0,
            queue_capacity=2 * PIPE_DEPTH * GC_CLIENTS,
            max_batches_per_tick=GC_BATCHES_PER_TICK,
            max_inflight=MAX_INFLIGHT, carry=PIPE_CARRY,
            coordinate_maintenance=True,
            coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US)))
        w0 = st.stats()["wal"]
        rps, p50, p99, s = _closed_loop_async(srv, streams, GC_CLIENTS,
                                              GC_ROUNDS)
        w1 = st.stats()["wal"]
        ops = GC_CLIENTS * GC_ROUNDS
        appends = w1["appends"] - w0["appends"]
        fsyncs = w1["fsyncs"] - w0["fsyncs"]
        commits = w1["commits"] - w0["commits"]
        fsyncs_per_op = fsyncs / ops
        coalesce = appends / max(commits, 1)
        p = s["pipeline"]
        emit(f"serve/wal_{kind}.{wal_kind}", 1e6 / rps,
             f"reqs_per_s={rps:.0f} p50_ticks={p50:.0f} "
             f"p99_ticks={p99:.0f} fsyncs_per_op={fsyncs_per_op:.2f} "
             f"appends={appends} fsyncs={fsyncs} commits={commits} "
             f"coalesce={coalesce:.1f} put_frac={put_frac} "
             f"epoch_violations={p['epoch_violations']}")
        st.close()
        return {"rps": rps, "p50_ticks": p50, "p99_ticks": p99,
                "appends": appends, "fsyncs": fsyncs, "commits": commits,
                "fsyncs_per_op": fsyncs_per_op, "coalesce": coalesce}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _overwrite_stream(keys: np.ndarray, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.permutation(keys) for _ in range(4)]


def _run_fleet(name: str, coordinate: bool, keys, order) -> int:
    d = tempfile.mkdtemp(prefix=f"bourbon_serve_{name}_")
    try:
        st = _open_store(os.path.join(d, "db"), keys, n_shards=4)
        srv = BourbonServer(st, ServerConfig(
            max_batch_keys=1024, max_wait_ticks=0, queue_capacity=64,
            coordinate_maintenance=coordinate,
            coordinator=CoordinatorConfig(budget_us_per_tick=BUDGET_US,
                                          max_shards_per_tick=1)))
        rid = 0
        t0 = time.perf_counter()
        for rnd in range(W_ROUNDS):
            hot = order[rnd % len(order)]
            for off in range(0, hot.shape[0], 1 << 10):
                srv.submit(ServerRequest(rid, "put",
                                         hot[off: off + (1 << 10)]))
                rid += 1
                srv.run_until_drained()
        # drain deferred maintenance: idle ticks advance the virtual
        # clocks (T_waits expire), so keep ticking until reclamation
        # stops moving for a while
        quiet = 0
        seen = -1
        for _ in range(8000):
            srv.tick()
            got = sum(sh.auto_gc_stats["segments_removed"]
                      for sh in st.shards)
            quiet = quiet + 1 if got == seen else 0
            seen = got
            if quiet >= 256:
                break
        wall = time.perf_counter() - t0
        s = srv.stats()
        agg = s["store"]
        extra = ""
        if coordinate:
            co = s["coordinator"]
            extra = (f" budget_us={BUDGET_US:.0f} "
                     f"within_budget={s['max_maintenance_tick_us'] <= BUDGET_US} "
                     f"gc_deferred={co['gc_deferred']}")
        emit(f"serve/fleet.{name}", s["max_maintenance_tick_us"],
             f"final_vlog_bytes={agg['vlog_disk_bytes']} "
             f"reclaimed_bytes={agg['auto_gc']['bytes_reclaimed']} "
             f"segments={agg['vlog_segments_removed']} "
             f"moved={agg['auto_gc']['entries_moved']} "
             f"checkpoints={agg['manifest_checkpoints']} "
             f"wall_s={wall:.1f}{extra}")
        st.close()
        return agg["vlog_disk_bytes"]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run() -> None:
    rng = np.random.default_rng(1)
    keys = rng.permutation(np.arange(1, N_KEYS + 1, dtype=np.int64) * 7)

    # part A: batched front end vs naive per-request loop (read-heavy)
    d = tempfile.mkdtemp(prefix="bourbon_serve_ab_")
    try:
        st = _open_store(os.path.join(d, "db"), keys, n_shards=2)
        _load(st, keys)
        streams = _request_streams(keys, seed=2)
        naive = _run_naive(st, streams)
        batched = _run_batched(st, streams)
        emit("serve/speedup", 0.0,
             f"batched_over_naive={batched / naive:.2f}x "
             f"clients={CLIENTS} keys_per_req={KEYS_PER_REQ}")
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # part A2: pipelined vs synchronous tick loop on a wider fleet
    d = tempfile.mkdtemp(prefix="bourbon_serve_pipe_")
    try:
        st = _open_store(os.path.join(d, "db"), keys, n_shards=PIPE_SHARDS)
        _load(st, keys)
        # pre-compile every pow2 probe-pad shape the batcher can produce,
        # so a mid-measurement XLA compile can't skew either arm
        rng = np.random.default_rng(4)
        pad = 64
        while pad <= 4096:
            st.get_batch(rng.choice(keys, min(pad, keys.shape[0]),
                                    replace=False), with_values=True)
            pad *= 2
        for clients in PIPE_CLIENTS:
            sync_rps, pipe_rps, io_rps = _run_pipeline_arm(st, keys,
                                                           clients)
            emit(f"serve/pipeline.speedup.c{clients}", 0.0,
                 f"pipelined_over_sync={pipe_rps / sync_rps:.2f}x "
                 f"max_inflight={MAX_INFLIGHT} carry={PIPE_CARRY} "
                 f"depth={PIPE_DEPTH} cores={os.cpu_count()} "
                 f"meets_1_5x={pipe_rps / sync_rps >= 1.5}")
            emit(f"serve/pipeline.io_speedup.c{clients}", 0.0,
                 f"threaded_over_pipelined={io_rps / pipe_rps:.2f}x "
                 f"io_workers={IO_WORKERS} cores={os.cpu_count()} "
                 f"beats_baseline={io_rps >= pipe_rps}")
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # part A3: obs tracing overhead (per-stage breakdown + 5% gate)
    _obs_part()

    # part A4: group-commit WAL durable-write arms (fsync=True stores)
    wal_extra = {}
    for kind, put_frac in (("write", 1.0), ("mixed", 0.5)):
        res = {arm: _run_wal_arm(kind, keys, gc_on, put_frac)
               for arm, gc_on in (("per_append", False), ("group", True))}
        red = (res["per_append"]["fsyncs_per_op"]
               / max(res["group"]["fsyncs_per_op"], 1e-9))
        emit(f"serve/wal.fsync_reduction.{kind}", 0.0,
             f"per_append_fsyncs_per_op="
             f"{res['per_append']['fsyncs_per_op']:.2f} "
             f"group_fsyncs_per_op={res['group']['fsyncs_per_op']:.2f} "
             f"reduction={red:.1f}x "
             f"coalesce={res['group']['coalesce']:.1f} "
             f"meets_4x={red >= 4.0}")
        wal_extra[kind] = {"reduction": red, **{
            arm: res[arm] for arm in res}}
    common.set_artifact_extra("wal_group_commit", wal_extra)

    # part B: fleet-stall time with vs without the coordinator
    wkeys = keys[: N_KEYS // 2]
    order = _overwrite_stream(wkeys, seed=3)
    base = _run_fleet("uncoordinated", False, wkeys, order)
    coord = _run_fleet("coordinated", True, wkeys, order)
    # space still held at quiesce: coordinated must match (within 10%)
    # what the uncoordinated fleet achieved
    ratio = coord / max(base, 1)
    emit("serve/fleet.space_ratio", 0.0,
         f"coordinated_over_uncoordinated={ratio:.3f} "
         f"within_10pct={abs(ratio - 1.0) <= 0.10}")
