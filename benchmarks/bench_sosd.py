"""Fig. 15: SOSD-style learned-index benchmark (amzn/face/logn/norm/uden/
uspr key distributions).  Paper: Bourbon 1.48x-1.74x over baseline."""

from __future__ import annotations

import numpy as np

from .common import N_OPS, emit, prepared_store, time_lookups

DATASETS = ["amzn", "face", "logn", "norm", "uden", "uspr"]


def run() -> dict:
    out = {}
    rng = np.random.default_rng(29)
    for ds in DATASETS:
        st_b, keys = prepared_store(dataset=ds, mode="bourbon")
        st_w, _ = prepared_store(dataset=ds, mode="wisckey", policy="never")
        probes = rng.choice(keys, N_OPS // 8)
        us_w = time_lookups(st_w, probes)
        us_b = time_lookups(st_b, probes)
        emit(f"fig15.{ds}.wisckey", us_w)
        emit(f"fig15.{ds}.bourbon", us_b, f"speedup={us_w / us_b:.2f}x")
        out[ds] = us_w / us_b
    return out


if __name__ == "__main__":
    run()
