"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (common.emit).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig9 fig13  # subset
"""

from __future__ import annotations

import sys
import time

SUITES = {
    "fig8": ("bench_paths", "latency breakdown by lookup step"),
    "fig9": ("bench_datasets", "datasets: wisckey vs bourbon vs level"),
    "fig10": ("bench_load_orders", "sequential vs random load"),
    "fig11": ("bench_distributions", "request distributions"),
    "fig12": ("bench_range", "range queries"),
    "fig13": ("bench_mixed", "mixed writes: cba vs always vs offline + table1"),
    "fig14": ("bench_ycsb", "YCSB A-F"),
    "ycsb": ("bench_ycsb",
             "filter plane: zipf lookups at 0/25/50/75% miss ratios, "
             "filters on vs off (probe counts + FPR in the artifact)",
             "run_miss"),
    "fig15": ("bench_sosd", "SOSD datasets"),
    "fig17": ("bench_error_bound", "delta sweep + space overheads"),
    "table2": ("bench_storage", "fast-storage + limited-memory tier model"),
    "recovery": ("bench_recovery",
                 "durable engine: reopen w/ persisted models vs relearn; "
                 "value-log GC"),
    "gc": ("bench_gc_policy",
           "manual vs CBA-scheduled value-log GC under sustained "
           "overwrites"),
    "dist_recovery": ("bench_dist_recovery",
                      "sharded store killed mid-write: reopen from shard "
                      "dirs vs rebuild from scratch"),
    "serve": ("bench_serve",
              "batched request-serving front end vs naive per-request "
              "loop; pipelined (multi-batch in-flight) vs synchronous "
              "tick loop at 16/64/256 clients; fleet-stall time with vs "
              "without the maintenance coordinator; obs-on vs obs-off "
              "tracing overhead"),
    # obs-only subset of serve: the CI overhead gate reruns just this
    "serve_obs": ("bench_serve",
                  "per-stage latency breakdown + obs-on within 5% of "
                  "obs-off throughput at 64 clients", "run_obs_only"),
}


def main() -> None:
    from benchmarks import common

    want = sys.argv[1:] or [k for k in SUITES if k != "serve_obs"]
    print("name,us_per_call,derived")
    for key in want:
        entry = SUITES[key]
        mod_name, desc = entry[0], entry[1]
        fn_name = entry[2] if len(entry) > 2 else "run"
        mod = __import__(f"benchmarks.{mod_name}", fromlist=[fn_name])
        t0 = time.time()
        print(f"# {key}: {desc}")
        getattr(mod, fn_name)()
        art = common.write_artifact(key)
        if art:
            print(f"# {key} artifact: {art}")
        print(f"# {key} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
