"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (common.emit).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig9 fig13  # subset
"""

from __future__ import annotations

import sys
import time

SUITES = {
    "fig8": ("bench_paths", "latency breakdown by lookup step"),
    "fig9": ("bench_datasets", "datasets: wisckey vs bourbon vs level"),
    "fig10": ("bench_load_orders", "sequential vs random load"),
    "fig11": ("bench_distributions", "request distributions"),
    "fig12": ("bench_range", "range queries"),
    "fig13": ("bench_mixed", "mixed writes: cba vs always vs offline + table1"),
    "fig14": ("bench_ycsb", "YCSB A-F"),
    "fig15": ("bench_sosd", "SOSD datasets"),
    "fig17": ("bench_error_bound", "delta sweep + space overheads"),
    "table2": ("bench_storage", "fast-storage + limited-memory tier model"),
    "recovery": ("bench_recovery",
                 "durable engine: reopen w/ persisted models vs relearn; "
                 "value-log GC"),
    "gc": ("bench_gc_policy",
           "manual vs CBA-scheduled value-log GC under sustained "
           "overwrites"),
    "dist_recovery": ("bench_dist_recovery",
                      "sharded store killed mid-write: reopen from shard "
                      "dirs vs rebuild from scratch"),
    "serve": ("bench_serve",
              "batched request-serving front end vs naive per-request "
              "loop; pipelined (multi-batch in-flight) vs synchronous "
              "tick loop at 16/64/256 clients; fleet-stall time with vs "
              "without the maintenance coordinator"),
}


def main() -> None:
    want = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for key in want:
        mod_name, desc = SUITES[key]
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.time()
        print(f"# {key}: {desc}")
        mod.run()
        print(f"# {key} done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
