"""Fig. 10: sequential vs random load order (AR/OSM).  Paper: random load
creates cross-level overlap -> many negative internal lookups -> higher
latency and smaller (but still large) speedup."""

from __future__ import annotations

import numpy as np

from .common import N_OPS, emit, prepared_store, time_lookups


def run() -> dict:
    out = {}
    rng = np.random.default_rng(11)
    for ds in ["ar", "osm"]:
        for order in ["sequential", "random"]:
            st_b, keys = prepared_store(dataset=ds, order=order,
                                        mode="bourbon")
            st_w, _ = prepared_store(dataset=ds, order=order, mode="wisckey",
                                     policy="never")
            probes = rng.choice(keys, N_OPS // 8)
            us_w = time_lookups(st_w, probes)
            us_b = time_lookups(st_b, probes)
            # negative internal lookups served (10b)
            neg = sum(t.stats.n_neg for t in st_b.tree.all_files())
            pos = sum(t.stats.n_pos for t in st_b.tree.all_files())
            emit(f"fig10.{ds}.{order}.wisckey", us_w)
            emit(f"fig10.{ds}.{order}.bourbon", us_b,
                 f"speedup={us_w / us_b:.2f}x neg={neg} pos={pos}")
            out[(ds, order)] = dict(w=us_w, b=us_b, neg=neg, pos=pos)
    return out


if __name__ == "__main__":
    run()
