"""Sharded durable store: kill mid-write, reopen from the per-shard
directories vs rebuild the distributed plane from scratch.

The old plane rebuilt its range-partitioned state from a transient
in-memory snapshot on every process start — re-ingesting the data and
refitting every model.  With the shard lifecycle on the storage engine,
reopen is MANIFEST replay + mmap'd sstables (persisted file/level models
included) + WAL replay for the unflushed tail, then one device-state
stack over the recovered snapshots.  Reported:

* ``reopen_from_disk``       — ShardedStore.open on the killed directory
                               tree + first distributed GET.
* ``rebuild_from_scratch``   — fresh directory, re-put the full stream,
                               learn_all, first distributed GET (what a
                               snapshotless plane pays after any crash).
* ``snapshot_load``          — load_shard_snapshot per shard directory
                               (the raw sstable_io path, no store).

``REPRO_BENCH_SMOKE=1`` shrinks the load so CI exercises the kill/reopen
path in seconds.
"""

from __future__ import annotations

import gc
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core import LSMConfig, MaintenanceConfig, StoreConfig, make_dataset
from repro.core.engine import EngineConfig
from repro.distributed import ShardedConfig, ShardedStore, load_shard_snapshot

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_KEYS = (1 << 13) if SMOKE else (1 << 17)
N_SHARDS = 2 if SMOKE else 4
BATCH = 1 << 12


def _store_cfg() -> StoreConfig:
    # smoke shrinks the LSM geometry too, so the load still reaches the
    # deeper levels and exercises level-model persistence
    lsm = (LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                     l1_cap_records=1 << 13) if SMOKE else
           LSMConfig(memtable_cap=1 << 12, file_cap=1 << 13,
                     l1_cap_records=1 << 15))
    return StoreConfig(mode="bourbon", granularity="level", policy="always",
                       value_size=16, lsm=lsm,
                       engine=EngineConfig(seg_cap=4096),
                       maintenance=MaintenanceConfig(auto_gc=False,
                                                     auto_checkpoint=False,
                                                     track_dead=False))


def _scfg(keys: np.ndarray) -> ShardedConfig:
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, N_SHARDS) / N_SHARDS))
    return ShardedConfig(n_shards=N_SHARDS, boundaries=bounds)


def _load(st: ShardedStore, keys: np.ndarray) -> None:
    for off in range(0, keys.shape[0], BATCH):
        st.put_batch(keys[off: off + BATCH])


def run() -> None:
    keys = make_dataset("ar", N_KEYS, seed=1)
    perm = np.random.default_rng(0).permutation(keys)
    # the kill-time tail stays below the per-shard memtable capacity: it
    # lives only in the WALs, so the persisted file/level models are still
    # current when the store dies (reopen serves them, relearning nothing)
    n_tail = min(BATCH, N_KEYS // 8)
    flushed, tail = perm[: -n_tail], perm[-n_tail:]
    probes = np.concatenate([perm[: 1 << 12], perm[: 1 << 10] + 1])
    d = tempfile.mkdtemp(prefix="bourbon_dist_recovery_")
    d2 = tempfile.mkdtemp(prefix="bourbon_dist_rebuild_")
    try:
        st = ShardedStore.open(d, _scfg(keys), _store_cfg())
        _load(st, flushed)
        st.flush_all()
        st.learn_all()
        _load(st, tail)       # WAL-only at kill time
        st.get_batch(probes)  # warm process-wide jax init out of the timings
        del st                # KILL: no close
        gc.collect()

        t0 = time.perf_counter()
        st = ShardedStore.open(d)          # per-shard directories alone
        found, _ = st.get_batch(probes)    # includes the state stack
        reopen_us = (time.perf_counter() - t0) * 1e6
        s = st.stats()
        assert found[: 1 << 12].all()
        emit("dist_recovery/reopen_from_disk", reopen_us,
             f"shards={s['n_shards']} models_recovered="
             f"{s['models_recovered']} level_models="
             f"{s['level_models_recovered']} relearned={s['files_learned']}")
        st.close()

        t0 = time.perf_counter()
        snaps = [load_shard_snapshot(os.path.join(d, f"shard-{i}"))
                 for i in range(N_SHARDS)]
        snap_us = (time.perf_counter() - t0) * 1e6
        emit("dist_recovery/snapshot_load", snap_us,
             f"records={sum(k.shape[0] for k, _ in snaps)}")

        t0 = time.perf_counter()
        st = ShardedStore.open(d2, _scfg(keys), _store_cfg())
        _load(st, flushed)
        st.flush_all()
        st.learn_all()
        _load(st, tail)
        found, _ = st.get_batch(probes)
        rebuild_us = (time.perf_counter() - t0) * 1e6
        assert found[: 1 << 12].all()
        emit("dist_recovery/rebuild_from_scratch", rebuild_us,
             f"speedup={rebuild_us / max(reopen_us, 1.0):.1f}x")
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)
