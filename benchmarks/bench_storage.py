"""Tables 2-3: fast storage + limited memory, via the two-tier byte-cost
model (DESIGN.md §8.5 — no SSDs in this container).

Tier model: a lookup pays data-access = bytes_moved / tier_bandwidth +
tier_latency on a miss of the resident set; Bourbon reduces *indexing* and
bytes moved (19-record window vs 256-record block).

Table 2 (Optane-class, everything on device): expect ~1.25-1.28x.
Table 3 (SATA-class + 25%-resident cache): uniform ~1.04x (access-bound),
zipfian ~1.25x (cache-friendly -> index-bound)."""

from __future__ import annotations

import numpy as np

from repro.core import request_indices
from .common import N_OPS, emit, prepared_store, time_lookups

# tier model: (latency_us, GB/s)
OPTANE = (10.0, 2.5)
SATA = (80.0, 0.5)
RECORD = 24            # key+ptr bytes
BLOCK = 256 * RECORD   # baseline data-access unit
WINDOW = 19 * RECORD   # bourbon window
VALUE = 64


def tiered_latency(us_index: float, hit_rate: float, tier, unit_bytes):
    lat, bw = tier
    miss = 1.0 - hit_rate
    access = miss * (lat + (unit_bytes + VALUE) / bw / 1e3)
    return us_index + access


def run() -> dict:
    out = {}
    st_b, keys = prepared_store(dataset="ar", mode="bourbon")
    st_w, _ = prepared_store(dataset="ar", mode="wisckey", policy="never")
    rng = np.random.default_rng(37)
    probes = keys[request_indices("uniform", rng, keys.shape[0], N_OPS // 8)]
    us_b = time_lookups(st_b, probes)
    us_w = time_lookups(st_w, probes)

    # Table 2: Optane, fully resident index, every value read hits storage
    t2_w = tiered_latency(us_w, 0.0, OPTANE, BLOCK)
    t2_b = tiered_latency(us_b, 0.0, OPTANE, WINDOW)
    emit("table2.ar.optane.speedup", t2_w / t2_b,
         f"wisckey={t2_w:.2f}us bourbon={t2_b:.2f}us")
    out["optane"] = t2_w / t2_b

    # Table 3: SATA + 25% resident. uniform hit ~25%; zipfian(80/20) ~80%.
    for dist, hit in [("uniform", 0.25), ("zipfian", 0.80)]:
        pr = keys[request_indices(dist, rng, keys.shape[0], N_OPS // 8)]
        ub = time_lookups(st_b, pr)
        uw = time_lookups(st_w, pr)
        t3_w = tiered_latency(uw, hit, SATA, BLOCK)
        t3_b = tiered_latency(ub, hit, SATA, WINDOW)
        emit(f"table3.{dist}.speedup", t3_w / t3_b,
             f"wisckey={t3_w:.1f}us bourbon={t3_b:.1f}us hit={hit}")
        out[dist] = t3_w / t3_b
    return out


if __name__ == "__main__":
    run()
