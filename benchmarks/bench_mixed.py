"""Fig. 13 + Table 1: mixed read/write workloads.

Fig 13: WiscKey vs Bourbon-offline vs Bourbon-always vs Bourbon-cba across
write fractions — foreground time (a), learning time (b), total work (c),
baseline-path fraction (d).  Foreground/learning/compaction totals run on the
virtual clock calibrated by bench_paths; the baseline-path fraction and CBA
decisions are real store behaviour.

Table 1: file vs level learning under the same mixes.
Paper claims reproduced: cba learning cost ~10x below always at 50% writes
with matching foreground time; level learning fails under writes (all level
learnings invalidated); offline degrades as data churns."""

from __future__ import annotations

import numpy as np

from repro.core import make_dataset
from .common import N_KEYS, N_OPS, emit, load_store, make_store

WRITE_FRACS = [0.01, 0.05, 0.5]


def run_workload(store, keys, write_frac, n_ops, seed=23):
    rng = np.random.default_rng(seed)
    batch = 4096
    next_new = int(keys[-1]) + 1
    for off in range(0, n_ops, batch):
        if rng.random() < write_frac:
            store.put_batch(rng.choice(keys, batch))
        else:
            store.get_batch(rng.choice(keys, batch))
    store.drain_learning()


def run() -> dict:
    out = {}
    keys = make_dataset("ar", N_KEYS // 2, seed=1)
    n_ops = N_OPS
    for wf in WRITE_FRACS:
        rows = {}
        for name, kw in [
            ("wisckey", dict(mode="wisckey", policy="never")),
            ("offline", dict(mode="bourbon", policy="offline")),
            ("always", dict(mode="bourbon", policy="always")),
            ("cba", dict(mode="bourbon", policy="cba")),
        ]:
            st = make_store(**kw)
            load_store(st, keys)
            if kw["policy"] in ("offline", "always", "cba") and \
                    kw["policy"] != "never":
                st.learn_all()   # models for the initially loaded data
            st.foreground_us = 0.0
            st.lookups_model_path = st.lookups_baseline_path = 0
            st.executor.learn_time_us = 0.0
            run_workload(st, keys, wf, n_ops)
            s = st.stats()
            fg = s["foreground_us"] / 1e6
            lt = s["learn_us"] / 1e6
            total = fg + lt + s["compact_us"] / 1e6
            base_frac = 1.0 - s["model_path_frac"]
            emit(f"fig13.w{int(wf*100)}.{name}.foreground_s", fg)
            emit(f"fig13.w{int(wf*100)}.{name}.learn_s", lt)
            emit(f"fig13.w{int(wf*100)}.{name}.total_s", total,
                 f"baseline_path_frac={base_frac:.3f} "
                 f"files_learned={s['files_learned']}")
            rows[name] = dict(fg=fg, learn=lt, total=total,
                              base_frac=base_frac)
        out[wf] = rows

    # Table 1: file vs level under writes
    for wf, label in [(0.5, "write-heavy"), (0.05, "read-heavy")]:
        for gran in ["file", "level"]:
            st = make_store(mode="bourbon", policy="always",
                            granularity=gran)
            load_store(st, keys)
            st.learn_all()
            st.foreground_us = 0.0
            st.lookups_model_path = st.lookups_baseline_path = 0
            run_workload(st, keys, wf, n_ops)
            s = st.stats()
            emit(f"table1.{label}.{gran}.model_path_pct",
                 100 * s["model_path_frac"],
                 f"level_attempts={s['level_attempts']} "
                 f"level_failures={s['level_failures']}")
    return out


if __name__ == "__main__":
    run()
