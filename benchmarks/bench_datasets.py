"""Fig. 9: lookup latency by dataset, WiscKey vs Bourbon vs Bourbon-level,
plus segment counts (9b).  Paper claim: 1.23x-1.78x file-model speedup,
1.33x-1.92x level-model; linear dataset fastest (1 segment/model)."""

from __future__ import annotations

import numpy as np

from .common import N_OPS, emit, prepared_store, time_lookups

DATASETS = ["linear", "seg1%", "seg10%", "normal", "ar", "osm"]


def run() -> dict:
    out = {}
    rng = np.random.default_rng(7)
    for ds in DATASETS:
        st_b, keys = prepared_store(dataset=ds, mode="bourbon")
        st_w, _ = prepared_store(dataset=ds, mode="wisckey", policy="never")
        st_l, _ = prepared_store(dataset=ds, mode="bourbon",
                                 granularity="level")
        probes = rng.choice(keys, N_OPS // 4)
        us_w = time_lookups(st_w, probes)
        us_b = time_lookups(st_b, probes)
        us_l = time_lookups(st_l, probes)
        segs = st_b.stats()["avg_segments"]
        emit(f"fig9.{ds}.wisckey", us_w)
        emit(f"fig9.{ds}.bourbon", us_b,
             f"speedup={us_w / us_b:.2f}x segs/file={segs:.1f}")
        emit(f"fig9.{ds}.bourbon-level", us_l,
             f"speedup={us_w / us_l:.2f}x")
        out[ds] = dict(wisckey=us_w, bourbon=us_b, level=us_l, segs=segs)
    return out


if __name__ == "__main__":
    run()
