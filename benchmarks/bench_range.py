"""Fig. 12: range queries.  Paper: biggest gain at range length 1 (~1.9x,
pure indexing), decaying toward ~1.15x at length 100 (scan-dominated).

The indexed part (locate the first key) is measured on the real engine;
the scan part is a host merge identical for both systems."""

from __future__ import annotations

import time

import numpy as np

from .common import emit, prepared_store

LENGTHS = [1, 10, 50, 100]
N_QUERIES = 2048


def run() -> dict:
    out = {}
    st_b, keys = prepared_store(dataset="ar", mode="bourbon")
    st_w, _ = prepared_store(dataset="ar", mode="wisckey", policy="never")
    rng = np.random.default_rng(17)
    starts = np.sort(rng.choice(keys, N_QUERIES, replace=False))

    def throughput(st, length):
        t0 = time.perf_counter()
        # locate via the engine (indexed path)
        st.get_batch(starts)
        # scan via host merge (same path both systems)
        st.range_query(starts[:64], length)
        dt = time.perf_counter() - t0
        return (N_QUERIES) / dt

    for L in LENGTHS:
        thr_w = throughput(st_w, L)
        thr_b = throughput(st_b, L)
        emit(f"fig12.len{L}.normalized_throughput", thr_b / thr_w,
             f"bourbon={thr_b:.0f}q/s wisckey={thr_w:.0f}q/s")
        out[L] = thr_b / thr_w
    return out


if __name__ == "__main__":
    run()
