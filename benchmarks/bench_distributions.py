"""Fig. 11: request distributions (sequential, zipfian, hotspot, exponential,
uniform, latest) on randomly-loaded AR/OSM.  Paper: 1.54x-1.76x across all."""

from __future__ import annotations

import numpy as np

from repro.core import request_indices
from .common import N_OPS, emit, prepared_store, time_lookups

DISTS = ["sequential", "zipfian", "hotspot", "exponential", "uniform",
         "latest"]


def run() -> dict:
    out = {}
    for ds in ["ar", "osm"]:
        st_b, keys = prepared_store(dataset=ds, mode="bourbon")
        st_w, _ = prepared_store(dataset=ds, mode="wisckey", policy="never")
        rng = np.random.default_rng(13)
        for dist in DISTS:
            idx = request_indices(dist, rng, keys.shape[0], N_OPS // 8)
            probes = keys[idx]
            us_w = time_lookups(st_w, probes)
            us_b = time_lookups(st_b, probes)
            emit(f"fig11.{ds}.{dist}.wisckey", us_w)
            emit(f"fig11.{ds}.{dist}.bourbon", us_b,
                 f"speedup={us_w / us_b:.2f}x")
            out[(ds, dist)] = us_w / us_b
    return out


if __name__ == "__main__":
    run()
