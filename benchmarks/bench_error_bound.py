"""Fig. 17: error bound (delta) vs latency and space overhead; per-dataset
space overheads.  Paper: delta=8 optimal; space overhead 0-2%."""

from __future__ import annotations

import numpy as np

from .common import N_OPS, emit, prepared_store, time_lookups

DELTAS = [2, 4, 8, 16, 32, 64]


def run() -> dict:
    out = {}
    rng = np.random.default_rng(31)
    for d in DELTAS:
        st, keys = prepared_store(dataset="ar", mode="bourbon", delta=d)
        probes = rng.choice(keys, N_OPS // 8)
        us = time_lookups(st, probes)
        s = st.stats()
        emit(f"fig17a.delta{d}.latency", us,
             f"segments={s['total_segments']} "
             f"space_overhead={100*s['space_overhead']:.3f}%")
        out[d] = dict(us=us, overhead=s["space_overhead"])
    for ds in ["linear", "seg10%", "normal", "ar", "osm"]:
        st, _ = prepared_store(dataset=ds, mode="bourbon", delta=8)
        s = st.stats()
        emit(f"fig17b.{ds}.space_overhead_pct", 100 * s["space_overhead"],
             f"model_bytes={s['model_bytes']}")
        assert s["space_overhead"] < 0.02 + 0.01, ds  # paper: 0-2%
    return out


if __name__ == "__main__":
    run()
