"""Shared benchmark utilities.

Scale note: the paper loads 64M keys and runs 10M ops on a 20-core Xeon.
This container is a single CPU core, so the default scale is 256K keys /
128K ops (set REPRO_BENCH_FULL=1 for 4M/1M).  What is *measured* is the real
tensor-path latency per lookup of each engine path; what is *derived*
(learning/compaction totals, Fig 13) runs on the virtual-clock cost model
calibrated from those measurements (DESIGN.md §8.4).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (BourbonStore, LSMConfig, StoreConfig, make_dataset)
from repro.core.engine import EngineConfig

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_KEYS = (1 << 22) if FULL else (1 << 18)
N_OPS = (1 << 20) if FULL else (1 << 17)
BATCH = 4096

# machine-readable artifact accumulator: every emit() line is also
# recorded here (with its k=v fields parsed) and write_artifact() dumps
# the suite's run as BENCH_<suite>.json — the CSV stays the human view,
# the JSON is what CI and the obs-overhead gate consume
_RESULTS: list[dict] = []
_EXTRA: dict = {}


def make_store(mode="bourbon", policy="always", granularity="file",
               delta=8, **kw) -> BourbonStore:
    lsm = LSMConfig(memtable_cap=1 << 13, file_cap=1 << 14,
                    l1_cap_records=1 << 16, plr_delta=delta)
    return BourbonStore(StoreConfig(mode=mode, policy=policy,
                                    granularity=granularity, lsm=lsm,
                                    engine=EngineConfig(seg_cap=4096), **kw))


def load_store(store: BourbonStore, keys: np.ndarray, order="random",
               seed=0) -> None:
    if order == "random":
        keys = np.random.default_rng(seed).permutation(keys)
    for off in range(0, keys.shape[0], 1 << 14):
        store.put_batch(keys[off: off + (1 << 14)])
    store.flush_all()


def prepared_store(dataset="ar", n=N_KEYS, order="random", **kw):
    keys = make_dataset(dataset, n, seed=1)
    st = make_store(**kw)
    load_store(st, keys, order)
    if st.cfg.mode == "bourbon":
        st.learn_all()
    return st, keys


def time_lookups(store: BourbonStore, probes: np.ndarray,
                 warmup: int = 1) -> float:
    """Returns measured microseconds per lookup (batched engine path)."""
    for _ in range(warmup):
        store.get_batch(probes[:BATCH])
    t0 = time.perf_counter()
    n = 0
    for off in range(0, probes.shape[0], BATCH):
        store.get_batch(probes[off: off + BATCH])
        n += min(BATCH, probes.shape[0] - off)
    dt = time.perf_counter() - t0
    return dt / n * 1e6


def _parse_fields(derived: str) -> dict:
    """Parse the free-form ``k=v`` tokens of a derived string into typed
    fields (floats where they parse, strings otherwise)."""
    out: dict = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.4f},{derived}")
    _RESULTS.append({"name": name, "us_per_call": float(us_per_call),
                     "derived": derived, "fields": _parse_fields(derived)})


def set_artifact_extra(key: str, value) -> None:
    """Attach an extra JSON-serializable payload (e.g. an obs snapshot or
    stage timeline) to the suite's artifact."""
    _EXTRA[key] = value


def write_artifact(suite: str) -> str | None:
    """Dump everything emitted since the last artifact as
    ``BENCH_<suite>.json`` under ``$REPRO_BENCH_ARTIFACTS`` (default
    ``bench_artifacts/``; set empty to disable).  Returns the path."""
    outdir = os.environ.get("REPRO_BENCH_ARTIFACTS", "bench_artifacts")
    if not outdir:
        _RESULTS.clear()
        _EXTRA.clear()
        return None
    os.makedirs(outdir, exist_ok=True)
    payload = {
        "suite": suite,
        "created_unix": time.time(),
        "config": {"full": FULL, "smoke": SMOKE, "n_keys": N_KEYS,
                   "n_ops": N_OPS, "batch": BATCH,
                   "cpu_count": os.cpu_count()},
        "results": list(_RESULTS),
        **_EXTRA,
    }
    path = os.path.join(outdir, f"BENCH_{suite}.json")
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        # a crashed/killed bench run must not leave a torn tmp behind
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _RESULTS.clear()
    _EXTRA.clear()
    return path
