"""Shared benchmark utilities.

Scale note: the paper loads 64M keys and runs 10M ops on a 20-core Xeon.
This container is a single CPU core, so the default scale is 256K keys /
128K ops (set REPRO_BENCH_FULL=1 for 4M/1M).  What is *measured* is the real
tensor-path latency per lookup of each engine path; what is *derived*
(learning/compaction totals, Fig 13) runs on the virtual-clock cost model
calibrated from those measurements (DESIGN.md §8.4).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (BourbonStore, LSMConfig, StoreConfig, make_dataset)
from repro.core.engine import EngineConfig

FULL = os.environ.get("REPRO_BENCH_FULL") == "1"
N_KEYS = (1 << 22) if FULL else (1 << 18)
N_OPS = (1 << 20) if FULL else (1 << 17)
BATCH = 4096


def make_store(mode="bourbon", policy="always", granularity="file",
               delta=8, **kw) -> BourbonStore:
    lsm = LSMConfig(memtable_cap=1 << 13, file_cap=1 << 14,
                    l1_cap_records=1 << 16, plr_delta=delta)
    return BourbonStore(StoreConfig(mode=mode, policy=policy,
                                    granularity=granularity, lsm=lsm,
                                    engine=EngineConfig(seg_cap=4096), **kw))


def load_store(store: BourbonStore, keys: np.ndarray, order="random",
               seed=0) -> None:
    if order == "random":
        keys = np.random.default_rng(seed).permutation(keys)
    for off in range(0, keys.shape[0], 1 << 14):
        store.put_batch(keys[off: off + (1 << 14)])
    store.flush_all()


def prepared_store(dataset="ar", n=N_KEYS, order="random", **kw):
    keys = make_dataset(dataset, n, seed=1)
    st = make_store(**kw)
    load_store(st, keys, order)
    if st.cfg.mode == "bourbon":
        st.learn_all()
    return st, keys


def time_lookups(store: BourbonStore, probes: np.ndarray,
                 warmup: int = 1) -> float:
    """Returns measured microseconds per lookup (batched engine path)."""
    for _ in range(warmup):
        store.get_batch(probes[:BATCH])
    t0 = time.perf_counter()
    n = 0
    for off in range(0, probes.shape[0], BATCH):
        store.get_batch(probes[off: off + BATCH])
        n += min(BATCH, probes.shape[0] - off)
    dt = time.perf_counter() - t0
    return dt / n * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.4f},{derived}")
