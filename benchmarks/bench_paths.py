"""Fig. 2 / Fig. 8: lookup latency breakdown by step, baseline vs model path.

Times the engine's actual per-stage implementations on a built level:
baseline = SearchIB (fence compare-count) + SearchFB (bloom) + SearchDB
(block gather + locate); model = ModelLookup (PLR segment + FMA) + SearchFB
+ LocateKey (delta-window probe).  Also reports the bytes asymmetry that is
the paper's LoadData win (256-record block vs 19-record window)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import BATCH, emit, prepared_store


def _timeit(fn, *args, iters=100):
    r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters / BATCH * 1e6


def run() -> dict:
    st, keys = prepared_store(dataset="ar", n=1 << 18, mode="bourbon")
    state = st.engine.build_state(st.tree, st.level_models)
    eng = st.engine
    # pick the most populated level
    li = max(range(7), key=lambda i: len(st.tree.levels[i]))
    lv = state.levels[li]
    rng = np.random.default_rng(3)
    lo, hi = int(np.asarray(lv.min_key)[0]), int(np.asarray(lv.max_key)[0])
    in_range = keys[(keys >= lo) & (keys <= hi)]
    probes = jnp.asarray(rng.choice(in_range, BATCH))
    f, _ = jax.jit(eng._find_file)(lv, probes)

    t_find = _timeit(jax.jit(eng._find_file), lv, probes)
    t_base = _timeit(jax.jit(eng._probe_file_baseline), lv, f, probes)
    t_model = _timeit(jax.jit(eng._probe_file_model), lv, f, probes)
    from repro.core.engine import bloom_probe_rows
    t_bloom = _timeit(jax.jit(lambda lv, f, p: bloom_probe_rows(
        lv.bloom, lv.bloom_nw, f, p, eng.cfg.bloom_k)), lv, f, probes)

    emit("fig8.FindFiles", t_find)
    emit("fig8.SearchFB(bloom)", t_bloom)
    emit("fig8.baseline.SearchIB+FB+DB", t_base)
    emit("fig8.bourbon.Model+FB+Locate", t_model)
    emit("fig8.search_speedup", t_base / t_model,
         f"baseline={t_base:.3f}us model={t_model:.3f}us")
    emit("fig8.loaddata_bytes_ratio", 256 / 19.0,
         "block=256rec window=19rec")
    return {"t_base": t_base, "t_model": t_model}


if __name__ == "__main__":
    run()
