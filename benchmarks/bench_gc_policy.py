"""GC policy benchmark: manual vs CBA-scheduled value-log GC under a
sustained-overwrite YCSB-style load (update-heavy, zipfian-ish key reuse).

Three stores see the identical write stream:

* ``none``   — GC disabled (growth baseline),
* ``manual`` — operator-driven: one big gc_value_log() at the end,
* ``auto``   — the MaintenanceScheduler collects segments whenever their
               estimated reclaim benefit beats relocation cost.

Reported per policy: peak and final vlog disk bytes, entries relocated,
real GC wall time, and post-load lookup latency — the LearnedKV-style
argument that *scheduled* maintenance keeps space bounded without a
stop-the-world pass.  ``REPRO_BENCH_SMOKE=1`` shrinks the load so CI can
execute the scheduler path in seconds.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit, time_lookups
from repro.core import LSMConfig, MaintenanceConfig, StoreConfig, BourbonStore
from repro.core.engine import EngineConfig

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_KEYS = (1 << 12) if SMOKE else (1 << 15)
ROUNDS = 4 if SMOKE else 8
BATCH = 1 << 10


def _cfg(maint: MaintenanceConfig) -> StoreConfig:
    return StoreConfig(mode="wisckey", policy="never", value_size=16,
                       vlog_seg_slots=1 << 10, maintenance=maint,
                       lsm=LSMConfig(memtable_cap=1 << 12, file_cap=1 << 13,
                                     l1_cap_records=1 << 15),
                       engine=EngineConfig(seg_cap=4096))


def _run_policy(name: str, maint: MaintenanceConfig, manual_gc: bool,
                keys: np.ndarray, order: np.ndarray) -> None:
    d = tempfile.mkdtemp(prefix=f"bourbon_gc_{name}_")
    try:
        st = BourbonStore.open(d, _cfg(maint))
        peak = 0
        t0 = time.perf_counter()
        for r in range(ROUNDS):
            hot = keys[order[r % order.shape[0]]]
            for off in range(0, hot.shape[0], BATCH):
                st.put_batch(hot[off: off + BATCH])
            peak = max(peak, st.vlog.disk_bytes())
        st.flush_all()
        load_us = (time.perf_counter() - t0) * 1e6
        gc_us = 0.0
        moved = 0
        if manual_gc:
            t0 = time.perf_counter()
            res = st.gc_value_log(min_dead_ratio=0.3)
            gc_us = (time.perf_counter() - t0) * 1e6
            moved = res["entries_moved"]
        s = st.stats()
        if not manual_gc:
            moved = s["auto_gc"]["entries_moved"]
        peak = max(peak, s["vlog_disk_bytes"])
        probes = np.random.default_rng(2).choice(keys, 1 << 13)
        emit(f"gc/{name}.load", load_us / (ROUNDS * keys.shape[0]),
             f"final_bytes={s['vlog_disk_bytes']} peak_bytes={peak} "
             f"moved={moved} auto_runs={s['auto_gc']['runs']} "
             f"checkpoints={s['manifest_checkpoints']}")
        emit(f"gc/{name}.gc_pass", gc_us,
             f"segments_removed={s['vlog_segments_removed']}")
        emit(f"gc/{name}.lookup_after", time_lookups(st, probes))
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run() -> None:
    rng = np.random.default_rng(1)
    keys = rng.permutation(np.arange(1, N_KEYS + 1, dtype=np.int64) * 7)
    # update-heavy reuse: each round rewrites a (biased) permutation of
    # the working set, so old versions pile up in sealed segments
    order = np.stack([rng.permutation(N_KEYS) for _ in range(4)])
    _run_policy("none", MaintenanceConfig(auto_gc=False,
                                          auto_checkpoint=False),
                manual_gc=False, keys=keys, order=order)
    _run_policy("manual", MaintenanceConfig(auto_gc=False,
                                            auto_checkpoint=False),
                manual_gc=True, keys=keys, order=order)
    _run_policy("auto", MaintenanceConfig(), manual_gc=False,
                keys=keys, order=order)
