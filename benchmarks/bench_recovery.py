"""Durable-storage benchmark: reopen time with persisted PLR models vs
relearn-from-scratch, and lookup latency before/after value-log GC.

The first comparison is the storage-format argument (LearnedKV / Bourbon
§4.2): serializing the learned segments inside the sstables makes a
reopened store model-path-ready immediately, while a metadata-only format
pays a full relearn.  The GC rows quantify WiscKey-style space
reclamation and confirm the read path is unharmed by relocation.
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import N_KEYS, emit, time_lookups
from repro.core import (BourbonStore, LSMConfig, MaintenanceConfig,
                        StoreConfig, make_dataset)
from repro.core.engine import EngineConfig


def _durable_cfg() -> StoreConfig:
    # auto maintenance off: this suite measures the *manual* GC pass
    # (bench_gc_policy covers the CBA-scheduled path)
    return StoreConfig(mode="bourbon", policy="always",
                       lsm=LSMConfig(memtable_cap=1 << 13, file_cap=1 << 14,
                                     l1_cap_records=1 << 16),
                       engine=EngineConfig(seg_cap=4096), value_size=16,
                       maintenance=MaintenanceConfig(auto_gc=False,
                                                     auto_checkpoint=False))


def run() -> None:
    n = max(N_KEYS >> 1, 1 << 16)
    keys = make_dataset("ar", n, seed=1)
    rng = np.random.default_rng(0)
    d = tempfile.mkdtemp(prefix="bourbon_recovery_")
    try:
        st = BourbonStore.open(d, _durable_cfg())
        perm = rng.permutation(keys)
        for off in range(0, n, 1 << 14):
            st.put_batch(perm[off: off + (1 << 14)])
        st.flush_all()
        st.learn_all()
        st.close()

        # reopen with persisted models: no retraining
        t0 = time.perf_counter()
        st = BourbonStore.open(d, _durable_cfg())
        reopen_us = (time.perf_counter() - t0) * 1e6
        s = st.stats()
        emit("recovery/reopen_persisted_models", reopen_us,
             f"files={s['n_files']} models_recovered={s['models_recovered']}")
        probes = rng.choice(keys, 1 << 15)
        emit("recovery/lookup_after_reopen", time_lookups(st, probes))

        # relearn-from-scratch: same store with its models stripped
        for t in st.tree.all_files():
            t.model = None
        t0 = time.perf_counter()
        st.learn_all()
        relearn_us = (time.perf_counter() - t0) * 1e6
        emit("recovery/reopen_relearn_scratch", reopen_us + relearn_us,
             f"relearn_only_us={relearn_us:.0f}")

        # overwrite-heavy phase, then GC
        half = perm[: n // 2]
        for _ in range(3):
            for off in range(0, half.shape[0], 1 << 14):
                st.put_batch(half[off: off + (1 << 14)])
        st.flush_all()
        before = st.vlog.disk_bytes()
        emit("recovery/lookup_pre_gc", time_lookups(st, probes))
        t0 = time.perf_counter()
        res = st.gc_value_log(min_dead_ratio=0.3)
        gc_us = (time.perf_counter() - t0) * 1e6
        after = st.vlog.disk_bytes()
        emit("recovery/gc_pass", gc_us,
             f"reclaimed={before - after}B segs={res['segments_removed']} "
             f"moved={res['entries_moved']}")
        emit("recovery/lookup_post_gc", time_lookups(st, probes))
        st.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
