"""Fig. 14: YCSB A-F on the default/AR/OSM datasets (randomly loaded).
Paper: C ~1.6x, B/D 1.24-1.44x, A/F 1.06-1.18x, E 1.16-1.19x."""

from __future__ import annotations

import time

import numpy as np

from repro.core import WorkloadSpec, iter_workload, make_dataset
from .common import N_KEYS, N_OPS, emit, load_store, make_store

WORKLOADS = ["A", "B", "C", "D", "E", "F"]
DATASETS = ["uden", "ar", "osm"]   # uden ~ ycsb default (dense int keys)


def run_spec(store, keys, spec) -> float:
    t0 = time.perf_counter()
    n = 0
    for op, batch_keys in iter_workload(spec, keys):
        if op == "get":
            store.get_batch(batch_keys)
        elif op == "put":
            store.put_batch(batch_keys)
        else:  # scan
            store.get_batch(batch_keys)          # locate (indexed)
            store.range_query(batch_keys[:16], spec.scan_len)
        n += batch_keys.shape[0]
    return n / (time.perf_counter() - t0)


def run() -> dict:
    out = {}
    n_ops = N_OPS // 8
    for ds in DATASETS:
        keys = make_dataset(ds, N_KEYS // 2, seed=1)
        for wl in WORKLOADS:
            thr = {}
            for name, kw in [("wisckey", dict(mode="wisckey", policy="never")),
                             ("bourbon", dict(mode="bourbon", policy="cba"))]:
                st = make_store(**kw)
                load_store(st, keys)
                if name == "bourbon":
                    st.learn_all()
                spec = WorkloadSpec.ycsb(wl, n_ops)
                thr[name] = run_spec(st, keys, spec)
            emit(f"fig14.{ds}.ycsb-{wl}.throughput_ratio",
                 thr["bourbon"] / thr["wisckey"],
                 f"bourbon={thr['bourbon']:.0f}ops/s "
                 f"wisckey={thr['wisckey']:.0f}ops/s")
            out[(ds, wl)] = thr["bourbon"] / thr["wisckey"]
    return out


if __name__ == "__main__":
    run()
