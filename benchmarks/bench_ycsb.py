"""Fig. 14: YCSB A-F on the default/AR/OSM datasets (randomly loaded).
Paper: C ~1.6x, B/D 1.24-1.44x, A/F 1.06-1.18x, E 1.16-1.19x.

``run_miss`` is the filter-plane arm (``ycsb`` suite): read-only zipf
lookups with a controlled miss ratio (0/25/50/75% of probes guaranteed
absent), filters on vs off, reporting per-level probe counts, screened
fraction, and the observed filter FPR in the artifact."""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import WorkloadSpec, iter_workload, make_dataset
from repro.core.filters import FilterConfig
from .common import (BATCH, N_KEYS, N_OPS, emit, load_store, make_store,
                     set_artifact_extra)

WORKLOADS = ["A", "B", "C", "D", "E", "F"]
DATASETS = ["uden", "ar", "osm"]   # uden ~ ycsb default (dense int keys)
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
MISS_RATIOS = (0, 25, 50, 75)


def run_spec(store, keys, spec) -> float:
    t0 = time.perf_counter()
    n = 0
    for op, batch_keys in iter_workload(spec, keys):
        if op == "get":
            store.get_batch(batch_keys)
        elif op == "put":
            store.put_batch(batch_keys)
        else:  # scan
            store.get_batch(batch_keys)          # locate (indexed)
            store.range_query(batch_keys[:16], spec.scan_len)
        n += batch_keys.shape[0]
    return n / (time.perf_counter() - t0)


def run() -> dict:
    out = {}
    n_ops = N_OPS // 8
    for ds in DATASETS:
        keys = make_dataset(ds, N_KEYS // 2, seed=1)
        for wl in WORKLOADS:
            thr = {}
            for name, kw in [("wisckey", dict(mode="wisckey", policy="never")),
                             ("bourbon", dict(mode="bourbon", policy="cba"))]:
                st = make_store(**kw)
                load_store(st, keys)
                if name == "bourbon":
                    st.learn_all()
                spec = WorkloadSpec.ycsb(wl, n_ops)
                thr[name] = run_spec(st, keys, spec)
            emit(f"fig14.{ds}.ycsb-{wl}.throughput_ratio",
                 thr["bourbon"] / thr["wisckey"],
                 f"bourbon={thr['bourbon']:.0f}ops/s "
                 f"wisckey={thr['wisckey']:.0f}ops/s")
            out[(ds, wl)] = thr["bourbon"] / thr["wisckey"]
    return out


def _zipf_present(rng, keys: np.ndarray, n: int) -> np.ndarray:
    """Zipf-skewed draws over the loaded key population (the YCSB B/C
    request shape the filter plane has to not hurt)."""
    idx = np.minimum(rng.zipf(1.3, size=n) - 1, keys.shape[0] - 1)
    return keys[idx]


def _one_pass(store, probes: np.ndarray, reps: int = 3) -> float:
    # best-of-N: a shared-CPU container jitters single passes hard enough
    # to invert arms that differ by 15%
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for off in range(0, probes.shape[0], BATCH):
            store.get_batch(probes[off: off + BATCH])
        best = min(best, time.perf_counter() - t0)
    return probes.shape[0] / best


def run_miss() -> dict:
    """Filter-plane headline: zipf GETs at 0/25/50/75% guaranteed-miss
    ratios, filters on vs off.  Absent keys arrive clustered in their own
    batches (the existence-check-sweep shape, where a screened batch can
    collapse to a near-empty dispatch) spread evenly through the stream.
    Emits throughput + us/op per arm plus probe-count and FPR extras; the
    ≥1.15x speedup target lives on the 50% arm."""
    rng = np.random.default_rng(7)
    n = min(N_KEYS // 4, 1 << 14 if SMOKE else 1 << 16)
    n_batches = 4 if SMOKE else 8
    n_ops = n_batches * BATCH
    keys = np.arange(1, n + 1, dtype=np.int64) * 4   # loaded population
    stores = {}
    for arm, enabled in (("on", True), ("off", False)):
        st = make_store(mode="bourbon", policy="cba",
                        filters=FilterConfig(enabled=enabled))
        load_store(st, keys)
        st.learn_all()
        st.engine.record_probe_split = True          # per-level probe counts
        stores[arm] = st
    out, detail = {}, {}
    for ratio in MISS_RATIOS:
        miss_batches = n_batches * ratio // 100
        n_miss = miss_batches * BATCH
        blocks, acc = [], 0
        for _ in range(n_batches):
            acc += miss_batches
            if acc >= n_batches:     # evenly interleaved absent sweeps
                acc -= n_batches
                blocks.append(keys[rng.integers(0, n, size=BATCH)] + 1)
            else:
                blocks.append(_zipf_present(rng, keys, BATCH))
        probes = np.concatenate(blocks)
        thr, probe_tot = {}, {}
        for arm, st in stores.items():
            # untimed pass compiles every pad size the screen will produce;
            # the timed passes over the same probes see only warm programs
            _one_pass(st, probes, reps=1)
            pre = st.engine.probe_split_np().sum()
            pre_scr = st.stats().get("filter_screened", 0)
            thr[arm] = _one_pass(st, probes)
            probe_tot[arm] = int(st.engine.probe_split_np().sum() - pre) // 3
            if arm == "on":
                s = st.stats()
                scr = (s["filter_screened"] - pre_scr) // 3
                fstats = st.engine.filter_stats_np()
                # absent probes that still dispatched = host-screen FPs
                fpr = (1.0 - scr / n_miss) if n_miss else 0.0
                detail[str(ratio)] = {
                    "n_ops": int(n_ops), "n_miss": int(n_miss),
                    "screened": int(scr), "observed_screen_fpr": fpr,
                    "level_pruned": fstats[:, 0].tolist(),
                    "level_false_positives": fstats[:, 1].tolist(),
                    "probes_on": probe_tot["on"],
                }
            emit(f"ycsb.miss{ratio:02d}.filters_{arm}.lookup",
                 1e6 / thr[arm],
                 f"ops_per_s={thr[arm]:.0f} device_probes={probe_tot[arm]}")
        detail[str(ratio)]["probes_off"] = probe_tot["off"]
        speedup = thr["on"] / thr["off"]
        probe_cut = (1.0 - probe_tot["on"] / probe_tot["off"]
                     if probe_tot["off"] else 0.0)
        emit(f"ycsb.miss{ratio:02d}.filters_speedup", speedup,
             f"probe_reduction={probe_cut:.3f}")
        out[ratio] = speedup
    set_artifact_extra("filter_plane", detail)
    return out


if __name__ == "__main__":
    run()
