"""Host I/O plane: request-order fetch results under out-of-order pool
completion, group-commit WAL ordering/coalescing, WAL replay after a
crash mid-coalesce, pool-size determinism through the pipelined server,
and the durability contract (`put` acknowledged at enqueue, durable at
``wal_sync``)."""

import gc
import threading
import time

import numpy as np
import pytest

from repro.core import BourbonStore, LSMConfig, StoreConfig
from repro.core.engine import EngineConfig
from repro.distributed import ShardedConfig, ShardedStore
from repro.io import IOFuture, IOPool, ValueFetch, wait_all
from repro.server import PipelineConfig, PipelinedServer, ServerRequest
from repro.storage.wal import GroupCommitWAL, WALWriter, replay_wal

VALUE_SIZE = 16


def _store_cfg(**kw):
    defaults = dict(granularity="level", policy="always",
                    value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _keys(n, seed=0, stride=7):
    return np.random.default_rng(seed).permutation(
        np.arange(1, n + 1, dtype=np.int64) * stride)


def _values(keys, version):
    v = np.zeros((keys.shape[0], VALUE_SIZE), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _sharded(tmp_path, keys, n_shards=2, **kw):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    return ShardedStore.open(str(tmp_path / "db"),
                             ShardedConfig(n_shards=n_shards,
                                           boundaries=bounds),
                             _store_cfg(**kw))


def _hold_committer(wal: GroupCommitWAL, hold: bool) -> None:
    with wal._cv:
        wal._hold = hold
        wal._cv.notify_all()


# ------------------------------------------------------------------ the pool

def test_pool_results_land_in_request_order_under_out_of_order_completion():
    """Tasks finish in adversarial (reverse) order; fixed-index scatter
    still produces the request-ordered result, bit-identical to inline."""
    n_tasks, rows = 8, 4
    out = np.zeros((n_tasks * rows, 8), np.int64)
    gate = threading.Event()

    def task(i):
        # later-submitted tasks complete first: earlier ones wait on the
        # last one, which flips the gate
        if i == n_tasks - 1:
            gate.set()
        else:
            assert gate.wait(5.0)
            time.sleep(0.001 * (n_tasks - i))
        lo = i * rows
        out[lo: lo + rows] = i + 1

    pool = IOPool(workers=n_tasks)
    vf = ValueFetch(out, [lambda i=i: task(i) for i in range(n_tasks)],
                    pool=pool)
    got = vf.wait()
    assert got is out
    expect = np.repeat(np.arange(1, n_tasks + 1), rows)[:, None] * \
        np.ones(8, np.int64)
    np.testing.assert_array_equal(out, expect)
    # wait() is idempotent, the pool accounted every task
    assert vf.wait() is out
    assert pool.stats()["completed"] == n_tasks
    pool.close()


def test_pool_exception_parked_until_join():
    pool = IOPool(workers=2)

    def boom():
        raise ValueError("task failed")

    fut = pool.submit(boom)
    ok = pool.submit(lambda: 41)
    assert ok.result() == 41        # other tasks unaffected
    with pytest.raises(ValueError, match="task failed"):
        fut.result()
    with pytest.raises(ValueError):
        wait_all([pool.submit(lambda: 1), pool.submit(boom)])
    pool.close()


def test_closed_pool_runs_submits_inline():
    pool = IOPool(workers=1)
    pool.close()
    pool.close()                    # idempotent
    fut = pool.submit(lambda a, b: a + b, 2, 3)
    assert isinstance(fut, IOFuture) and fut.done() and fut.result() == 5


def test_valuefetch_without_pool_runs_tasks_at_wait():
    ran = []
    vf = ValueFetch("res", [lambda: ran.append(1)])
    assert ran == []                # nothing runs before the join
    assert vf.wait() == "res" and ran == [1]
    assert vf.wait() == "res" and ran == [1]   # idempotent


# ------------------------------------------------------------- group commit

def test_group_commit_preserves_append_order_and_coalesces(tmp_path):
    path = str(tmp_path / "wal-0.log")
    wal = GroupCommitWAL(path)
    _hold_committer(wal, True)      # freeze: everything lands in ONE group
    n_batches = 12
    for i in range(n_batches):
        ks = np.arange(i * 10, i * 10 + 10, dtype=np.int64)
        wal.append(ks, ks + 1, ks + 2)
    assert wal.commits == 0         # acknowledged, nothing durable yet
    _hold_committer(wal, False)
    wal.sync()
    assert wal.appends == n_batches
    assert wal.commits == 1         # the whole backlog in one commit group
    assert wal.drain_batch_sizes() == [n_batches]
    wal.close()
    batches = replay_wal(path)
    assert len(batches) == n_batches
    for i, (ks, seqs, vptrs) in enumerate(batches):   # strict append order
        np.testing.assert_array_equal(
            ks, np.arange(i * 10, i * 10 + 10, dtype=np.int64))
        np.testing.assert_array_equal(seqs, ks + 1)
        np.testing.assert_array_equal(vptrs, ks + 2)


def test_group_commit_and_per_append_writers_produce_identical_logs(tmp_path):
    batches = [(np.arange(i * 7, i * 7 + 7, dtype=np.int64),) * 3
               for i in range(5)]
    p1, p2 = str(tmp_path / "a.log"), str(tmp_path / "b.log")
    w1 = WALWriter(p1)
    w2 = GroupCommitWAL(p2)
    for ks, seqs, vptrs in batches:
        w1.append(ks, seqs, vptrs)
        w2.append(ks, seqs, vptrs)
    w1.close()
    w2.close()                      # quiesce: drains every queued frame
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()


def test_group_commit_close_is_a_durability_point(tmp_path):
    path = str(tmp_path / "wal-0.log")
    wal = GroupCommitWAL(path)
    _hold_committer(wal, True)
    ks = np.arange(20, dtype=np.int64)
    wal.append(ks, ks, ks)
    wal.close()                     # must flush the held frame, not drop it
    assert len(replay_wal(path)) == 1


def test_crash_mid_coalesce_keeps_only_committed_prefix(tmp_path):
    path = str(tmp_path / "wal-0.log")
    wal = GroupCommitWAL(path)
    a = np.arange(10, dtype=np.int64)
    wal.append(a, a, a)
    wal.sync()                      # batch A durable
    _hold_committer(wal, True)
    b = np.arange(100, 110, dtype=np.int64)
    wal.append(b, b, b)             # acknowledged, never synced
    wal.crash()
    batches = replay_wal(path)
    assert len(batches) == 1        # clean prefix: A survived, B gone
    np.testing.assert_array_equal(batches[0][0], a)


def test_group_commit_sync_surfaces_committer_errors(tmp_path):
    path = str(tmp_path / "wal-0.log")
    wal = GroupCommitWAL(path)
    _hold_committer(wal, True)
    ks = np.arange(4, dtype=np.int64)
    wal.append(ks, ks, ks)
    wal._f.close()                  # inject: the commit write will fail
    _hold_committer(wal, False)
    with pytest.raises(ValueError):
        wal.sync()
    with pytest.raises(ValueError):   # appends refuse too, not silently lost
        wal.append(ks, ks, ks)


# --------------------------------------------- store-level crash + recovery

def test_store_recovers_after_crash_mid_group_commit(tmp_path):
    """Kill the store while a later write batch sits un-synced in the
    commit queue: reopen must replay every batch covered by the last
    ``wal_sync`` and silently drop the un-acknowledged suffix."""
    d = str(tmp_path / "db")
    cfg = _store_cfg(storage_dir=d, wal_group_commit=True,
                     fetch_values=True)
    st = BourbonStore.open(d, cfg)
    synced = _keys(120, seed=5)     # stays below memtable_cap: no flush
    st.put_batch(synced, _values(synced, 1))
    st.wal_sync()                   # durability point for `synced`
    _hold_committer(st._storage.wal, True)
    lost = synced[:40] + 1          # distinct keys, acknowledged only
    st.put_batch(lost, _values(lost, 2))
    st._storage.wal.crash()
    del st
    gc.collect()                    # engine finalizer releases the LOCK

    st2 = BourbonStore.open(d, cfg)
    f, v = st2.get_batch(synced)
    assert f.all()
    np.testing.assert_array_equal(v, _values(synced, 1))
    f_lost, _ = st2.get_batch(lost)
    assert not f_lost.any()         # un-synced suffix is gone, no error
    st2.close()


def test_wal_sync_durability_survives_reopen_cycles(tmp_path):
    d = str(tmp_path / "db")
    cfg = _store_cfg(storage_dir=d, wal_group_commit=True,
                     fetch_values=True)
    shadow = {}
    for cycle in range(3):
        st = BourbonStore.open(d, cfg)
        ks = _keys(100, seed=cycle, stride=11 + cycle)
        st.put_batch(ks, _values(ks, cycle))
        shadow.update((int(k), cycle) for k in ks)
        st.wal_sync()
        st._storage.wal.crash()     # crash AFTER the sync: nothing lost
        del st
        gc.collect()
    st = BourbonStore.open(d, cfg)
    probes = np.array(sorted(shadow), np.int64)
    f, v = st.get_batch(probes)
    assert f.all()
    for i, k in enumerate(probes):
        assert v[i, 1] == shadow[int(k)] % 251
    ws = st._storage.wal_stats()
    assert ws["group_commit"] and ws["appends"] >= ws["commits"]
    st.close()


# --------------------------------------------------- server-level semantics

def _serve_workload(tmp_path, io_workers, group_commit=False, tag=""):
    keys = _keys(3000, seed=9)
    st = _sharded(tmp_path / f"io{io_workers}{tag}", keys,
                  wal_group_commit=group_commit)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=256,
                                             max_wait_ticks=0,
                                             max_inflight=4,
                                             io_workers=io_workers))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    reqs = []
    for c in range(10):
        ks = np.concatenate([keys[c * 80: c * 80 + 70],
                             keys[c * 80: c * 80 + 10] + 1])  # misses
        r = ServerRequest(rid, "get", ks)
        rid += 1
        assert srv.submit(r)
        reqs.append(r)
    srv.run_until_drained()
    out = [(r.found.copy(), r.result.copy()) for r in reqs]
    stats = srv.stats()
    srv.shutdown()
    st.close()
    return out, stats


def test_pool_sizes_and_inline_are_bit_identical(tmp_path):
    """The CI determinism gate as a test: pool off / 1 worker / 4 workers
    answer every request identically, with zero epoch violations."""
    baseline, s0 = _serve_workload(tmp_path, io_workers=0)
    for w in (1, 4):
        got, s = _serve_workload(tmp_path, io_workers=w)
        for (f0, v0), (f1, v1) in zip(baseline, got):
            np.testing.assert_array_equal(f0, f1)
            np.testing.assert_array_equal(v0, v1)
        assert s["pipeline"]["epoch_violations"] == 0
        assert s["io"]["workers"] == w and s["io"]["depth"] == 0
    assert s0["pipeline"]["epoch_violations"] == 0
    assert s0["io"] is None


def test_threaded_group_commit_server_matches_oracle(tmp_path):
    """Interleaved put/get/delete through the threaded pipeline with the
    group-commit WAL: every GET observes exactly the writes submitted
    before it, and write acks coalesce (commits < appends)."""
    keys = _keys(2000, seed=12)
    st = _sharded(tmp_path, keys, wal_group_commit=True)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=128,
                                             max_wait_ticks=0,
                                             max_inflight=4,
                                             io_workers=3))
    rng = np.random.default_rng(13)
    oracle: dict[int, int | None] = {}
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    oracle.update((int(k), 0) for k in keys)
    pending = []
    for step in range(24):
        op = rng.choice(["put", "get", "get", "delete"])
        ks = rng.choice(keys, 40, replace=False)
        if op == "put":
            ver = step % 251
            assert srv.submit(ServerRequest(rid, "put", ks,
                                            _values(ks, ver)))
            oracle.update((int(k), ver) for k in ks)
        elif op == "delete":
            assert srv.submit(ServerRequest(rid, "delete", ks))
            for k in ks:
                oracle[int(k)] = None
        else:
            r = ServerRequest(rid, "get", ks)
            assert srv.submit(r)
            pending.append((r, {int(k): oracle.get(int(k)) for k in ks}))
        rid += 1
        if step % 5 == 0:
            srv.tick()
    srv.run_until_drained()
    assert pending
    for r, expect in pending:
        assert r.done
        for i, k in enumerate(r.keys):
            want = expect[int(k)]
            if want is None:
                assert not r.found[i]
            else:
                assert r.found[i] and r.result[i, 1] == want
    stats = srv.stats()
    assert stats["pipeline"]["epoch_violations"] == 0
    wal = stats["store"]["wal"]
    assert wal["appends"] > 0
    # the committer is eager, so with instant (fsync-off) commits every
    # group may hold a single frame — coalescing is opportunistic; the
    # deterministic multi-frame-group claim is the held-committer WAL
    # tests' job.  Here: never MORE commits than acknowledged appends
    assert 0 < wal["commits"] <= wal["appends"]
    srv.shutdown()
    st.close()


def test_io_pool_metrics_reach_the_obs_snapshot(tmp_path):
    from repro.obs import ObsConfig
    keys = _keys(800, seed=3)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=128,
                                             max_wait_ticks=0,
                                             io_workers=2,
                                             obs=ObsConfig(enabled=True,
                                                           sample_every=1)))
    rid = 0
    for off in range(0, keys.shape[0], 400):
        ks = keys[off: off + 400]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    r = ServerRequest(rid, "get", keys[:300])
    assert srv.submit(r)
    srv.run_until_drained()
    snap = srv.obs.registry.snapshot()
    assert {"io_pool_workers", "io_pool_queue_depth", "io_pool_max_depth",
            "io_pool_tasks_total",
            "fleet_value_fetch_overlap_ratio"} <= set(snap)
    srv.shutdown()
    st.close()
