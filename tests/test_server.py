"""repro.server: admission/batching, the epoch-invalidated HotKeyCache,
fleet maintenance coordination, and the ShardedStore serving hooks
(range_query across shard boundaries, aggregated maintenance stats)."""

import gc

import numpy as np
import pytest

from repro.core import LSMConfig, StoreConfig
from repro.core.cba import MaintenanceConfig
from repro.core.engine import EngineConfig
from repro.distributed import ShardedConfig, ShardedStore
from repro.server import (Batcher, BourbonServer, CoordinatorConfig,
                          RequestQueue, ServerConfig, ServerRequest)

VALUE_SIZE = 16


def _store_cfg(**kw):
    defaults = dict(granularity="level", policy="always",
                    value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _keys(n, seed=0, stride=7):
    return np.random.default_rng(seed).permutation(
        np.arange(1, n + 1, dtype=np.int64) * stride)


def _sharded(tmp_path, keys, n_shards=2, **kw):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    return ShardedStore.open(str(tmp_path / "db"),
                             ShardedConfig(n_shards=n_shards,
                                           boundaries=bounds),
                             _store_cfg(**kw))


def _values(keys, version):
    v = np.zeros((keys.shape[0], VALUE_SIZE), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _drain(srv, reqs=None):
    srv.run_until_drained()
    if reqs is not None:
        for r in reqs:
            assert r.done


# ---------------------------------------------------------------- admission

def test_queue_backpressure_rejects_when_full():
    q = RequestQueue(capacity=2)
    a = ServerRequest(0, "get", np.array([1]))
    b = ServerRequest(1, "get", np.array([2]))
    c = ServerRequest(2, "get", np.array([3]))
    assert q.submit(a, 0) and q.submit(b, 0)
    assert not q.submit(c, 0)
    assert q.rejected == 1 and q.submitted == 2 and len(q) == 2


def test_batcher_coalesces_dedups_and_scatters():
    q = RequestQueue(capacity=8)
    r1 = ServerRequest(0, "get", np.array([10, 20, 30]))
    r2 = ServerRequest(1, "get", np.array([20, 40]))     # 20 shared
    q.submit(r1, 0)
    q.submit(r2, 0)
    b = Batcher(max_batch_keys=16, max_wait_ticks=0)
    batch = b.next_batch(q, 0)
    assert batch is not None and batch.op == "get"
    np.testing.assert_array_equal(batch.keys, [10, 20, 30, 40])  # deduped
    # fan-in maps recover each request's own key order
    np.testing.assert_array_equal(batch.keys[batch.scatter[0]], r1.keys)
    np.testing.assert_array_equal(batch.keys[batch.scatter[1]], r2.keys)
    assert b.request_keys == 5 and b.batch_keys == 4
    assert len(q) == 0


def test_batcher_holds_partial_batch_then_dispatches():
    q = RequestQueue(capacity=8)
    q.submit(ServerRequest(0, "get", np.array([1, 2])), 0)
    b = Batcher(max_batch_keys=64, max_wait_ticks=2)
    assert b.next_batch(q, 0) is None          # partial: wait for more
    assert b.next_batch(q, 1) is None
    assert b.next_batch(q, 2) is not None      # max_wait_ticks reached
    assert b.held == 2 and b.batches == 1


def test_batcher_never_reorders_ops():
    """A PUT ahead of a GET in the queue always dispatches first — the
    write run is cut at the op change and dispatches immediately (no
    hold), so the GET can only ever run after it."""
    q = RequestQueue(capacity=8)
    q.submit(ServerRequest(0, "put", np.array([5]),
                           _values(np.array([5]), 1)), 0)
    q.submit(ServerRequest(1, "get", np.array([5])), 0)
    b = Batcher(max_batch_keys=64, max_wait_ticks=2)
    first = b.next_batch(q, 0)
    assert first is not None and first.op == "put"
    assert b.next_batch(q, 0) is None       # lone partial GET may wait...
    second = b.next_batch(q, 2)             # ...but only max_wait_ticks
    assert second is not None and second.op == "get"


# ------------------------------------------------------------------- server

def test_server_serves_reads_writes_and_misses(tmp_path):
    keys = _keys(4000, seed=1)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=512,
                                         max_wait_ticks=1,
                                         queue_capacity=64))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    reqs = []
    for c in range(16):
        ks = np.concatenate([keys[c * 50: c * 50 + 40],
                             keys[c * 50: c * 50 + 10] + 1])  # 10 misses
        r = ServerRequest(rid, "get", ks)
        rid += 1
        assert srv.submit(r)
        reqs.append(r)
    _drain(srv, reqs)
    for c, r in enumerate(reqs):
        assert r.found[:40].all()
        assert (r.result[:40, 0] == (r.keys[:40] % 251)).all()
        miss = ~np.isin(r.keys[40:], keys)
        assert not r.found[40:][miss].any()
    s = srv.stats()
    assert s["completed"] == s["submitted"] == rid
    assert s["batches"] < rid          # coalescing actually happened
    st.close()


def test_cache_hot_keys_then_put_delete_supersede(tmp_path):
    """The satellite correctness matrix: a cached key must not serve
    stale data after a PUT or DELETE that supersedes it."""
    keys = _keys(3000, seed=2)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=512,
                                         max_wait_ticks=0))
    rid = [0]

    def do(op, ks, values=None):
        r = ServerRequest(rid[0], op, ks, values)
        rid[0] += 1
        assert srv.submit(r)
        srv.run_until_drained()
        return r

    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        do("put", ks, _values(ks, 0))
    hot = keys[:64]
    do("get", hot)
    h0 = srv.cache.hits
    r = do("get", hot)                       # second read: cache hits
    assert srv.cache.hits > h0
    assert r.found.all() and (r.result[:, 1] == 0).all()
    # PUT supersedes: the very next read must see version 1
    do("put", hot, _values(hot, 1))
    r = do("get", hot)
    assert r.found.all() and (r.result[:, 1] == 1).all()
    # DELETE supersedes: the very next read must miss
    do("delete", hot[:8])
    r = do("get", hot[:8])
    assert not r.found.any()
    assert srv.cache.inval_write > 0
    st.close()


def test_cache_epoch_invalidation_on_roll_and_compaction(tmp_path):
    """A cached key is dropped when its shard's structural epoch moves —
    exercised by a memtable roll and then by enough load to compact —
    without the key itself ever being rewritten."""
    keys = _keys(12000, seed=3)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=1024,
                                         max_wait_ticks=0))
    rid = [0]

    def do(op, ks, values=None):
        r = ServerRequest(rid[0], op, ks, values)
        rid[0] += 1
        assert srv.submit(r)
        srv.run_until_drained()
        return r

    seed_ks = keys[:512]
    do("put", seed_ks, _values(seed_ks, 0))
    probe = seed_ks[:16]
    do("get", probe)                          # fills the cache (memtable)
    # roll shard memtables by writing OTHER keys only: no explicit
    # invalidation of `probe` ever happens, the epoch must do it
    filler = keys[512:2600]
    e0 = st.shard_epochs()
    for off in range(0, filler.shape[0], 500):
        ks = filler[off: off + 500]
        do("put", ks, _values(ks, 0))
    assert st.shard_epochs() != e0            # memtable(s) rolled
    inv0 = srv.cache.inval_epoch
    r = do("get", probe)
    assert srv.cache.inval_epoch > inv0       # dropped by the epoch rule
    assert r.found.all() and (r.result[:, 1] == 0).all()  # still correct
    # now push enough data to trigger compaction events too
    rest = keys[2600:]
    for off in range(0, rest.shape[0], 500):
        ks = rest[off: off + 500]
        do("put", ks, _values(ks, 0))
    assert any(len(sh.tree.levels[1]) > 0 for sh in st.shards)
    inv1 = srv.cache.inval_epoch
    r = do("get", probe)
    assert srv.cache.inval_epoch > inv1       # compaction epoch bump
    assert r.found.all() and (r.result[:, 1] == 0).all()
    st.close()


def test_server_kill_reopen_comes_back_cold_but_correct(tmp_path):
    keys = _keys(5000, seed=4)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=1024,
                                         max_wait_ticks=0))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    r = ServerRequest(rid, "get", keys[:64])
    rid += 1
    srv.submit(r)
    srv.run_until_drained()
    assert r.found.all()
    del srv, st                               # CRASH: no close
    gc.collect()

    st2 = ShardedStore.open(str(tmp_path / "db"))
    srv2 = BourbonServer(st2, ServerConfig(max_batch_keys=1024,
                                           max_wait_ticks=0))
    assert srv2.cache.hits == 0 and len(srv2.cache) == 0   # cold cache
    probes = np.concatenate([keys[:2000], keys[:200] + 1])
    r = ServerRequest(0, "get", probes)
    srv2.submit(r)
    srv2.run_until_drained()
    assert r.found[:2000].all()
    assert (r.result[:2000, 0] == (probes[:2000] % 251)).all()
    miss = ~np.isin(keys[:200] + 1, keys)
    assert not r.found[2000:][miss].any()
    assert srv2.cache.hits == 0               # first pass was all misses
    st2.close()


# -------------------------------------------------------------- maintenance

def _overwrite_rounds(srv, keys, rounds, rid0=0):
    rid = rid0
    for rnd in range(rounds):
        for off in range(0, keys.shape[0], 500):
            ks = keys[off: off + 500]
            srv.submit(ServerRequest(rid, "put", ks, _values(ks, rnd)))
            rid += 1
            srv.run_until_drained()
    return rid


def test_coordinator_budget_is_a_hard_per_tick_ceiling(tmp_path):
    budget = 1500.0
    keys = _keys(4096, seed=5)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(
        max_batch_keys=512, max_wait_ticks=0,
        coordinator=CoordinatorConfig(budget_us_per_tick=budget,
                                      max_shards_per_tick=1)))
    assert all(sh.maintenance_deferred for sh in st.shards)
    _overwrite_rounds(srv, keys, rounds=5)
    for _ in range(200):                      # drain deferred maintenance
        srv.tick()
    s = srv.stats()
    assert s["store"]["auto_gc"]["segments_removed"] > 0
    assert s["max_maintenance_tick_us"] <= budget + 1e-9
    co = s["coordinator"]
    assert co["max_tick_us"] <= budget + 1e-9
    assert co["runs"] > 0
    # round-robin staggering: both shards got their own maintenance turns
    assert all(n > 0 for n in co["per_shard_runs"])
    st.close()


def test_coordinator_rejects_starving_budget_and_autosizes(tmp_path):
    """GC is atomic per segment: a budget below one segment's worst-case
    collect cost would defer every candidate forever, so it is refused;
    an unset budget auto-sizes to exactly that atomic unit."""
    keys = _keys(500, seed=10)
    st = _sharded(tmp_path, keys)
    atomic = st.shards[0].cfg.costs.t_gc(st.shards[0].cfg.vlog_seg_slots,
                                         st.shards[0].cfg.vlog_seg_slots)
    with pytest.raises(ValueError, match="atomic"):
        BourbonServer(st, ServerConfig(
            coordinator=CoordinatorConfig(budget_us_per_tick=atomic / 2)))
    srv = BourbonServer(st, ServerConfig())          # auto budget
    assert srv.coordinator.budget_us == pytest.approx(atomic)
    st.close()


def test_batcher_splits_puts_with_and_without_values(tmp_path):
    """Puts with explicit values and default-valued puts cannot share one
    store call: the run is cut at the boundary, both still complete in
    submission order (the crash path would have lost both)."""
    keys = _keys(100, seed=11)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=512,
                                         max_wait_ticks=0))
    a = ServerRequest(0, "put", keys[:10], _values(keys[:10], 3))
    b = ServerRequest(1, "put", keys[10:20])         # store-default values
    assert srv.submit(a) and srv.submit(b)
    srv.run_until_drained()
    assert a.done and b.done
    r = ServerRequest(2, "get", keys[:20])
    srv.submit(r)
    srv.run_until_drained()
    assert r.found.all()
    assert (r.result[:10, 1] == 3).all()             # explicit values
    assert (r.result[10:20, 0]
            == (keys[10:20] & 0xFF).astype(np.uint8)).all()  # defaults
    st.close()


def test_run_maintenance_budget_defers_not_drops(tmp_path):
    """A zero budget does no work but remembers it; an uncapped call
    later collects what was deferred."""
    keys = _keys(3000, seed=6)
    st = _sharded(tmp_path, keys,
                  maintenance=MaintenanceConfig(gc_t_wait_us=0.0,
                                                gc_scan_interval_us=0.0))
    st.set_maintenance_deferred(True)
    for rnd in range(4):                      # pile up dead entries
        for off in range(0, keys.shape[0], 500):
            ks = keys[off: off + 500]
            st.put_batch(ks, _values(ks, rnd))
    spent = sum(st.run_shard_maintenance(i, budget_us=0.0)
                for i in range(st.n_shards))
    assert spent == 0.0
    assert st.stats()["auto_gc"]["segments_removed"] == 0
    assert sum(sh.cba.gc_deferred for sh in st.shards) > 0
    for i in range(st.n_shards):
        assert st.run_shard_maintenance(i) > 0.0  # no budget: collect now
        assert st.shards[i].last_maintenance_us > 0.0
    assert st.stats()["auto_gc"]["segments_removed"] > 0
    st.close()


def test_learning_and_virtual_time_progress_under_coordinator(tmp_path):
    """With a coordinator owning maintenance, the shards' own learning
    pipeline must still progress: read batches charge the virtual clocks
    (ShardedStore.get_batch alone charges nothing) and every server tick
    ticks the stores, so queued learning jobs complete during idle —
    they must not freeze the moment write traffic stops."""
    keys = _keys(8000, seed=12)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=1024,
                                         max_wait_ticks=0))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        srv.submit(ServerRequest(rid, "put", ks, _values(ks, 0)))
        rid += 1
        srv.run_until_drained()
    # read-only traffic advances virtual time on the probed shards
    t0 = [sh.clock.now for sh in st.shards]
    r = ServerRequest(rid, "get", keys[:800])
    rid += 1
    srv.submit(r)
    srv.run_until_drained()
    assert r.found.all()
    assert all(sh.clock.now > t for sh, t in zip(st.shards, t0))
    # idle ticks drain any queued/running learning jobs to completion
    for _ in range(2000):
        if all(not sh.executor.queue and not sh.executor.running
               for sh in st.shards):
            break
        srv.tick()
    assert all(not sh.executor.queue and not sh.executor.running
               for sh in st.shards)
    assert all(sh.level_models[1] is not None or not sh.tree.levels[1]
               for sh in st.shards)
    st.close()


def test_uncoordinated_server_still_tracks_stall_metric(tmp_path):
    keys = _keys(3000, seed=7)
    st = _sharded(tmp_path, keys)
    srv = BourbonServer(st, ServerConfig(max_batch_keys=512,
                                         max_wait_ticks=0,
                                         coordinate_maintenance=False))
    assert srv.coordinator is None
    assert not any(sh.maintenance_deferred for sh in st.shards)
    _overwrite_rounds(srv, keys, rounds=4)
    s = srv.stats()
    assert s["store"]["auto_gc"]["segments_removed"] > 0
    assert s["max_maintenance_tick_us"] > 0.0   # self-driven GC observed
    st.close()


# ------------------------------------------------------------ cache (unit)

def test_cache_fill_never_evicts_a_row_it_is_updating():
    """Regression: a full cache filled with a batch mixing new keys and
    an already-cached (oldest-stamped) key must not evict that key's row
    for one of the new keys — the later duplicate-row write would serve
    the old key's value under the new key."""
    from repro.server import HotKeyCache
    c = HotKeyCache(slots=4)
    def v(key):
        row = np.zeros((1, 8), np.uint8)
        row[0, 0] = key % 251
        return row
    ep = (0,)
    for k in (1, 2, 3, 4):
        c.fill(np.array([k], np.int64), v(k), np.zeros(1, np.int64), ep)
    # key 1 is oldest-stamped; refill it together with three new keys
    batch = np.array([5, 6, 7, 1], np.int64)
    vals = np.concatenate([v(5), v(6), v(7), v(1)])
    c.fill(batch, vals, np.zeros(4, np.int64), ep)
    out = np.zeros((4, 8), np.uint8)
    hit = c.lookup(batch, ep, out)
    assert hit.all()
    assert (out[:, 0] == batch % 251).all()     # every key its own value


def test_cache_fill_larger_than_slots_keeps_tail_and_counts_evictions():
    """Regression: one fill with more new keys than the cache has slots
    must not crash — the last ``slots`` pairs are admitted (what
    sequential insertion would have kept) and the drop is counted."""
    from repro.server import HotKeyCache
    c = HotKeyCache(slots=8)
    keys = np.arange(1, 13, dtype=np.int64)
    vals = np.zeros((12, 8), np.uint8)
    vals[:, 0] = keys
    c.fill(keys, vals, np.zeros(12, np.int64), (0,))
    assert len(c) == 8
    assert c.evictions == 4
    out = np.zeros((8, 8), np.uint8)
    hit = c.lookup(keys[-8:], (0,), out)
    assert hit.all() and (out[:, 0] == keys[-8:]).all()


# ------------------------------------------------- ShardedStore satellites

def test_sharded_range_query_merges_across_shard_boundaries(tmp_path):
    keys = np.arange(1, 4001, dtype=np.int64) * 5
    st = _sharded(tmp_path, np.random.default_rng(8).permutation(keys),
                  n_shards=4)
    st.put_batch(keys, _values(keys, 0))
    # deleted keys must not appear in scans (newest version is a
    # tombstone), even though older versions remain in the tree
    deleted = keys[100:140]
    st.delete_batch(deleted)
    st.flush_all()
    flat = np.sort(np.setdiff1d(keys, deleted))
    got = st.range_query(np.array([int(deleted[0]) - 5], np.int64), 30)[0]
    i0 = np.searchsorted(flat, int(deleted[0]) - 5)
    np.testing.assert_array_equal(got, flat[i0: i0 + 30])
    assert not np.isin(deleted, got).any()
    bounds = np.asarray(st._splits)
    # start just below each boundary with a length that crosses it, plus
    # one scan long enough to span two boundaries
    starts = [int(b) - 60 for b in bounds] + [int(bounds[0]) - 60]
    lengths = [40, 40, 40, int(np.searchsorted(flat, bounds[1]))]
    for sk, ln in zip(starts, lengths):
        got = st.range_query(np.array([sk], np.int64), ln)[0]
        i0 = np.searchsorted(flat, sk)
        np.testing.assert_array_equal(got, flat[i0: i0 + ln])
    # running off the end of the keyspace pads with -1
    got = st.range_query(np.array([flat[-3]], np.int64), 10)[0]
    np.testing.assert_array_equal(got[:3], flat[-3:])
    assert (got[3:] == -1).all()
    # batched form matches per-key form
    batch = st.range_query(np.asarray(starts, np.int64), 40)
    for bi, sk in enumerate(starts):
        i0 = np.searchsorted(flat, sk)
        np.testing.assert_array_equal(batch[bi], flat[i0: i0 + 40])
    st.close()


def test_sharded_stats_aggregate_maintenance_counters(tmp_path):
    keys = _keys(3000, seed=9)
    st = _sharded(tmp_path, keys)
    for rnd in range(4):
        for off in range(0, keys.shape[0], 500):
            ks = keys[off: off + 500]
            st.put_batch(ks, _values(ks, rnd))
    s = st.stats()
    per = s["shards"]
    assert s["vlog_segments_removed"] == sum(
        p["vlog_segments_removed"] for p in per) > 0
    assert s["auto_gc"]["segments_removed"] == sum(
        p["auto_gc"]["segments_removed"] for p in per)
    assert s["auto_gc"]["bytes_reclaimed"] > 0
    assert s["gc_us"] == pytest.approx(sum(p["gc_us"] for p in per))
    assert s["gc_us"] > 0
    assert s["manifest_checkpoints"] == sum(
        p["manifest_checkpoints"] for p in per)
    assert s["maintenance_us"] >= s["gc_us"]
    assert s["n_gets"] == 0
    st.close()
