"""Pipelined serving: dispatch/resolve split correctness, in-flight
epoch consistency (every batch answered under exactly one epoch vector),
write-barrier ordering, backpressure at ``max_inflight``, bubble-only
maintenance, and the engine-side satellites (jit-trace stability across
epochs, lazy CBA counter materialization)."""

import numpy as np
import pytest

from repro.core import LSMConfig, StoreConfig
from repro.core.filters import FilterConfig
from repro.core.lsm import N_LEVELS
from repro.core.store import BourbonStore
from repro.distributed import ShardedConfig, ShardedStore
from repro.server import (BourbonServer, PipelineConfig, PipelinedServer,
                          ServerConfig, ServerRequest)
from repro.core.engine import EngineConfig

VALUE_SIZE = 16


def _store_cfg(**kw):
    defaults = dict(granularity="level", policy="always",
                    value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _keys(n, seed=0, stride=7):
    return np.random.default_rng(seed).permutation(
        np.arange(1, n + 1, dtype=np.int64) * stride)


def _sharded(tmp_path, keys, n_shards=2, **kw):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    return ShardedStore.open(str(tmp_path / "db"),
                             ShardedConfig(n_shards=n_shards,
                                           boundaries=bounds),
                             _store_cfg(**kw))


def _values(keys, version):
    v = np.zeros((keys.shape[0], VALUE_SIZE), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _load(srv, keys, version=0, rid0=0, chunk=500):
    rid = rid0
    for off in range(0, keys.shape[0], chunk):
        ks = keys[off: off + chunk]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, version)))
        rid += 1
        srv.run_until_drained()
    return rid


# --------------------------------------------------------------- correctness

def test_pipelined_matches_synchronous_server(tmp_path):
    """Same mixed workload through the synchronous tick loop and the
    pipelined one: identical answers, request by request."""
    keys = _keys(4000, seed=1)
    results = []
    for cls, cfg in ((BourbonServer, ServerConfig(max_batch_keys=256,
                                                  max_wait_ticks=0)),
                     (PipelinedServer, PipelineConfig(max_batch_keys=256,
                                                      max_wait_ticks=0,
                                                      max_inflight=4))):
        st = _sharded(tmp_path / cls.__name__, keys)
        srv = cls(st, cfg)
        rid = _load(srv, keys)
        got = []
        reqs = []
        for c in range(12):
            ks = np.concatenate([keys[c * 60: c * 60 + 50],
                                 keys[c * 60: c * 60 + 10] + 1])  # misses
            r = ServerRequest(rid, "get", ks)
            rid += 1
            assert srv.submit(r)
            reqs.append(r)
        srv.run_until_drained()
        for r in reqs:
            assert r.done
            got.append((r.found.copy(), r.result.copy()))
        results.append(got)
        st.close()
    for (f_sync, v_sync), (f_pipe, v_pipe) in zip(*results):
        np.testing.assert_array_equal(f_sync, f_pipe)
        np.testing.assert_array_equal(v_sync, v_pipe)


def test_pipelined_mixed_stream_matches_oracle(tmp_path):
    """Interleaved put/get/delete stream against a python-dict oracle:
    with writes acting as pipeline barriers, every GET must observe
    exactly the prefix of writes submitted before it."""
    keys = _keys(3000, seed=2)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=128,
                                             max_wait_ticks=0,
                                             max_inflight=4))
    rng = np.random.default_rng(3)
    oracle: dict[int, int] = {}
    rid = _load(srv, keys, version=0)
    oracle.update((int(k), 0) for k in keys)
    pending = []   # (request, expected {key: version|None})
    for step in range(30):
        op = rng.choice(["put", "get", "get", "delete"])
        ks = rng.choice(keys, 40, replace=False)
        if op == "put":
            ver = step % 251
            assert srv.submit(ServerRequest(rid, "put", ks,
                                            _values(ks, ver)))
            oracle.update((int(k), ver) for k in ks)
        elif op == "delete":
            assert srv.submit(ServerRequest(rid, "delete", ks))
            for k in ks:
                oracle[int(k)] = None
        else:
            r = ServerRequest(rid, "get", ks)
            assert srv.submit(r)
            pending.append((r, {int(k): oracle.get(int(k)) for k in ks}))
        rid += 1
        if step % 7 == 0:
            srv.tick()
    srv.run_until_drained()
    assert pending
    for r, expect in pending:
        assert r.done
        for i, k in enumerate(r.keys):
            want = expect[int(k)]
            if want is None:
                assert not r.found[i]
            else:
                assert r.found[i] and r.result[i, 1] == want
    assert srv.stats()["pipeline"]["epoch_violations"] == 0
    st.close()


# ------------------------------------------------------------ epoch pinning

def test_inflight_epoch_consistency_when_memtable_rolls(tmp_path):
    """Read batches in flight when a memtable-rolling write arrives must
    all have been answered under the single pre-roll epoch vector."""
    keys = _keys(6000, seed=4)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=128,
                                             max_wait_ticks=0,
                                             max_inflight=4,
                                             max_batches_per_tick=8))
    rid = _load(srv, keys)
    e_pre = st.shard_epochs()
    reads = []
    for c in range(3):                    # three separate 100-key batches
        r = ServerRequest(rid, "get", keys[c * 100: c * 100 + 100])
        rid += 1
        assert srv.submit(r)
        reads.append(r)
    # a write big enough to roll at least one shard's memtable, queued
    # BEHIND the reads in the same tick
    roll = keys[1000: 1000 + 2048]
    assert srv.submit(ServerRequest(rid, "put", roll, _values(roll, 5)))
    rid += 1
    srv.run_until_drained()
    e_post = st.shard_epochs()
    assert e_post != e_pre                # the write really rolled
    for r in reads:
        assert r.done and r.found.all()
        assert (r.result[:, 1] == 0).all()          # pre-put snapshot
        assert r.epochs_served == e_pre             # pinned, one vector
    s = srv.stats()["pipeline"]
    assert s["epoch_violations"] == 0
    assert s["write_barriers"] >= 1
    assert s["max_depth_seen"] >= 2       # batches really were in flight
    # a read AFTER the roll serves under the new epoch vector
    r = ServerRequest(rid, "get", roll[:64])
    assert srv.submit(r)
    srv.run_until_drained()
    assert r.found.all() and (r.result[:, 1] == 5).all()
    assert r.epochs_served == e_post
    st.close()


def test_write_barrier_get_after_put_never_sees_old_value(tmp_path):
    """Strict ordering through the pipeline: GET submitted after a PUT
    (same tick, pipeline already holding older reads) must see the new
    value; reads submitted before the PUT see the old snapshot."""
    keys = _keys(3000, seed=5)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=128,
                                             max_wait_ticks=0,
                                             max_inflight=4,
                                             max_batches_per_tick=8))
    rid = _load(srv, keys)
    hot = keys[:64]
    pre = ServerRequest(rid, "get", hot)
    rid += 1
    assert srv.submit(pre)
    assert srv.submit(ServerRequest(rid, "put", hot, _values(hot, 7)))
    rid += 1
    post = ServerRequest(rid, "get", hot)
    rid += 1
    assert srv.submit(post)
    srv.run_until_drained()
    assert pre.done and pre.found.all() and (pre.result[:, 1] == 0).all()
    assert post.done and post.found.all() and (post.result[:, 1] == 7).all()
    # delete ordering too: GET after DELETE must miss
    assert srv.submit(ServerRequest(rid, "delete", hot[:8]))
    rid += 1
    post_del = ServerRequest(rid, "get", hot[:8])
    rid += 1
    assert srv.submit(post_del)
    srv.run_until_drained()
    assert post_del.done and not post_del.found.any()
    assert srv.stats()["pipeline"]["epoch_violations"] == 0
    st.close()


# ------------------------------------------------------------- backpressure

def test_backpressure_with_max_inflight_outstanding(tmp_path):
    keys = _keys(3000, seed=6)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=64, max_wait_ticks=0, max_inflight=2,
        max_batches_per_tick=8, queue_capacity=4, cache_slots=0))
    rid = _load(srv, keys)
    # 4 fill the queue, the rest bounce
    reqs, rejected = [], 0
    for c in range(8):
        r = ServerRequest(rid, "get", keys[c * 64: c * 64 + 64])
        rid += 1
        if srv.submit(r):
            reqs.append(r)
        else:
            rejected += 1
    assert len(reqs) == 4 and rejected == 4
    srv.tick()
    s = srv.stats()["pipeline"]
    # the pipeline admitted only up to its depth limit even though the
    # queue held more and max_batches_per_tick allowed more
    assert s["max_depth_seen"] == 2
    assert s["dispatched"] >= 2
    assert len(srv.queue) > 0             # backpressure held work back
    srv.run_until_drained()
    for r in reqs:
        assert r.done and r.found.all()
    assert srv.stats()["pipeline"]["max_depth_seen"] <= 2
    assert srv.queue.rejected == 4
    st.close()


# -------------------------------------------------------------- maintenance

def test_maintenance_runs_only_in_bubbles(tmp_path):
    """Coordinator rounds happen at drain points (bubbles), not on every
    tick — and deferred GC still converges during idle draining."""
    keys = _keys(3000, seed=7)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(max_batch_keys=512,
                                             max_wait_ticks=0,
                                             bubble_every_ticks=8))
    assert all(sh.maintenance_deferred for sh in st.shards)
    rid = 0
    for rnd in range(4):
        rid = _load(srv, keys, version=rnd, rid0=rid)
    for _ in range(400):                  # idle ticks: drain deferred GC
        srv.tick()
    s = srv.stats()
    assert s["store"]["auto_gc"]["segments_removed"] > 0
    p = s["pipeline"]
    assert p["bubbles"] == s["coordinator"]["ticks"]
    assert p["bubbles"] < s["ticks"]      # strictly fewer rounds than ticks
    assert s["max_maintenance_tick_us"] <= srv.coordinator.budget_us + 1e-9
    st.close()


def test_sustained_reads_force_drain_keeps_maintenance_alive(tmp_path):
    """Under a read stream that never drains naturally, the forced-drain
    guard still creates bubbles so maintenance cannot starve forever."""
    keys = _keys(2000, seed=8)
    st = _sharded(tmp_path, keys)
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=64, max_wait_ticks=0, max_inflight=4,
        max_batches_per_tick=1, queue_capacity=256, cache_slots=0,
        force_drain_ticks=16, bubble_every_ticks=4))
    rid = _load(srv, keys)
    rng = np.random.default_rng(9)
    b0 = srv.stats()["pipeline"]["bubbles"]
    for i in range(120):                  # open-loop: queue never empties
        for _ in range(3):
            srv.submit(ServerRequest(rid, "get",
                                     rng.choice(keys, 64, replace=False)))
            rid += 1
        srv.tick()
    p = srv.stats()["pipeline"]
    assert p["forced_drains"] > 0
    assert p["bubbles"] > b0
    srv.run_until_drained()
    st.close()


# ------------------------------------------------------- engine satellites

def test_lookup_trace_count_stable_across_epochs(tmp_path):
    """Regression (retrace audit): a fresh DeviceState whose padded
    geometry is unchanged must reuse the cached traced program — the jit
    cache is keyed on the state's full shape signature.  Filters are off:
    the plane's host-answer path would resolve these small batches without
    ever dispatching a device program."""
    cfg = StoreConfig(mode="wisckey",
                      lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                    l1_cap_records=1 << 13),
                      filters=FilterConfig(enabled=False))
    st = BourbonStore(cfg)
    keys = _keys(3000, seed=10)
    st.put_batch(keys)
    st.flush_all()
    probes = keys[:64]
    st.get_batch(probes)
    tc = st.engine.trace_count
    assert tc >= 1
    # epoch change with stable geometry: force every DeviceLevel to be
    # rebuilt (fresh device arrays, same shapes) as a state refresh would
    st.engine._state_versions = [-1] * N_LEVELS
    st.engine._lm_versions = [-1] * N_LEVELS
    f, _ = st.get_batch(probes)
    assert f.all()
    assert st.engine.trace_count == tc    # no retrace
    # sanity: a genuinely different batch shape does trace again
    st.get_batch(keys[:300])
    assert st.engine.trace_count > tc


def test_counter_materialization_is_lazy():
    """The CBA counter vectors stay device-side until first touched."""
    cfg = StoreConfig(mode="wisckey",
                      lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                    l1_cap_records=1 << 13))
    st = BourbonStore(cfg)
    keys = _keys(2000, seed=11)
    st.put_batch(keys)
    st.flush_all()
    state = st.engine.build_state(st.tree, st.level_models)
    res = st.engine.lookup(state, keys[:64], "baseline",
                           l0_live=len(st.tree.levels[0]))
    assert res._pos_np is None and res._neg_np is None   # not yet pulled
    pos = res.pos_counts                  # first touch materializes
    assert res._pos_np is not None
    assert len(pos) == N_LEVELS
    assert all(isinstance(p, np.ndarray) for p in pos)
    assert sum(int(p.sum()) for p in pos) == 64          # all hits counted
    assert res.found.all()


def test_store_dispatch_resolve_roundtrip_and_double_resolve(tmp_path):
    """BourbonStore's split halves compose to exactly get_batch, pending
    handles are single-shot, and two dispatches may be in flight."""
    cfg = _store_cfg()
    st = BourbonStore.open(str(tmp_path / "db"), cfg)
    keys = _keys(3000, seed=12)
    st.put_batch(keys, _values(keys, 0))
    st.flush_all()
    pb1 = st.dispatch_get(keys[:100])
    pb2 = st.dispatch_get(keys[100:200])       # two in flight at once
    f1, v1 = st.resolve_get(pb1)
    f2, v2 = st.resolve_get(pb2)
    assert f1.all() and f2.all()
    fs, vs = st.get_batch(keys[:100])
    np.testing.assert_array_equal(f1, fs)
    np.testing.assert_array_equal(v1, vs)
    with pytest.raises(RuntimeError, match="resolved"):
        st.resolve_get(pb1)
    st.close()


def test_sharded_dispatch_pins_epoch_vector(tmp_path):
    keys = _keys(3000, seed=13)
    st = _sharded(tmp_path, keys)
    st.put_batch(keys, _values(keys, 0))
    st.flush_all()
    e0 = st.shard_epochs()
    pb = st.dispatch_get(keys[:128], with_values=True)
    assert pb.epochs == e0
    # a write that rolls the memtable moves the live epochs, but the
    # dispatched batch still resolves under its pinned snapshot
    roll = keys[200: 200 + 2048]
    st.put_batch(roll, _values(roll, 1))
    assert st.shard_epochs() != e0
    f, v = st.resolve_get(pb)
    assert f.all() and (v[:, 1] == 0).all()
    assert pb.epochs == e0
    with pytest.raises(RuntimeError, match="resolved"):
        st.resolve_get(pb)
    st.close()
