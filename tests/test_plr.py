"""Greedy-PLR: error-bound guarantee, numpy/jax agreement, edge cases."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: seeded-random fallback (see _hypothesis_shim)
    from _hypothesis_shim import given, settings, st

from repro.core import greedy_plr_np, greedy_plr_jax, plr_predict_np
from repro.core.datasets import make_dataset


@pytest.mark.parametrize("name", ["linear", "seg10%", "normal", "osm", "uspr"])
@pytest.mark.parametrize("delta", [2, 8, 32])
def test_error_bound_guarantee(name, delta):
    keys = make_dataset(name, 4096, seed=3)
    m = greedy_plr_np(keys, delta=delta)
    pred = plr_predict_np(m, keys)
    err = np.abs(pred - np.arange(keys.shape[0]))
    assert err.max() <= delta + 1e-6, f"max err {err.max()} > delta {delta}"


def test_linear_dataset_single_segment():
    keys = np.arange(1000, dtype=np.int64)
    m = greedy_plr_np(keys, delta=8)
    assert int(m.n_segments) == 1


def test_more_segments_for_rougher_data():
    lin = greedy_plr_np(make_dataset("linear", 8192), delta=8)
    seg = greedy_plr_np(make_dataset("seg10%", 8192), delta=8)
    nrm = greedy_plr_np(make_dataset("normal", 8192), delta=8)
    assert int(lin.n_segments) <= int(seg.n_segments)
    assert int(lin.n_segments) <= int(nrm.n_segments)


def test_larger_delta_fewer_segments():
    keys = make_dataset("normal", 8192, seed=7)
    counts = [int(greedy_plr_np(keys, delta=d).n_segments) for d in (2, 8, 32, 128)]
    assert counts == sorted(counts, reverse=True)


def test_jax_matches_numpy():
    keys = make_dataset("normal", 2048, seed=5)
    m_np = greedy_plr_np(keys, delta=8, pad_to=1024)
    m_jx = greedy_plr_jax(np.asarray(keys), delta=8, cap=1024)
    assert int(m_np.n_segments) == int(m_jx.n_segments)
    n = int(m_np.n_segments)
    np.testing.assert_allclose(np.asarray(m_jx.starts)[:n],
                               np.asarray(m_np.starts)[:n])
    np.testing.assert_allclose(np.asarray(m_jx.slopes)[:n],
                               np.asarray(m_np.slopes)[:n], rtol=1e-12)
    # jax version satisfies the bound too
    pred = plr_predict_np(m_jx, keys)
    assert np.abs(pred - np.arange(keys.shape[0])).max() <= 8 + 1e-6


def test_tiny_inputs():
    for n in (1, 2, 3):
        keys = np.arange(n, dtype=np.int64) * 7
        m = greedy_plr_np(keys, delta=8)
        pred = plr_predict_np(m, keys)
        assert np.abs(pred - np.arange(n)).max() <= 8


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**50), min_size=2, max_size=300, unique=True),
       st.sampled_from([1, 4, 8, 16]))
def test_property_error_bound(raw, delta):
    keys = np.sort(np.asarray(raw, np.int64))
    m = greedy_plr_np(keys, delta=delta)
    pred = plr_predict_np(m, keys)
    assert np.abs(pred - np.arange(keys.shape[0])).max() <= delta + 1e-6
