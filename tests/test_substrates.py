"""Substrate tests: data determinism, checkpoint roundtrip + resume,
fault-tolerant restart (real process kill), gradient compression, elastic
planning, serving engine end-to-end."""

import json
import os
import pathlib
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import AsyncSaver, latest_step, restore, save
from repro.core.jaxcompat import make_mesh, shard_map
from repro.data.pipeline import DataConfig, TokenDataset, synthetic_tokens
from repro.launch.elastic import ElasticController, shrink_plan
from repro.optim import compressed_psum, dequantize_int8, quantize_int8


# ------------------------------------------------------------------- data

def test_data_deterministic_and_host_sharded():
    ds = TokenDataset(synthetic_tokens(100_000, 1000),
                      DataConfig(seq_len=64, global_batch=8))
    a1, l1 = ds.batch_for_step(7, host=0, n_hosts=4)
    a2, _ = ds.batch_for_step(7, host=0, n_hosts=4)
    np.testing.assert_array_equal(a1, a2)            # pure function of step
    assert a1.shape == (2, 64)
    np.testing.assert_array_equal(a1[:, 1:], l1[:, :-1])  # labels shifted
    # all hosts' shards together form the global batch, disjoint
    rows = [ds.batch_for_step(7, h, 4)[0] for h in range(4)]
    allrows = np.concatenate(rows)
    assert allrows.shape == (8, 64)


def test_any_host_can_recompute_any_shard():
    """The straggler/elastic invariant: shard content depends only on
    (step, shard index), not on which host computes it."""
    ds = TokenDataset(synthetic_tokens(50_000, 500),
                      DataConfig(seq_len=32, global_batch=8))
    t_h1, _ = ds.batch_for_step(3, host=1, n_hosts=4)
    # host 1's shard = samples [step*gb + 1*per .. +2*per)
    t_all = np.concatenate([ds.batch_for_step(3, h, 4)[0] for h in range(4)])
    t_again = np.concatenate([ds.batch_for_step(3, h, 8)[0] for h in range(8)])
    np.testing.assert_array_equal(t_all, t_again)    # mesh-width independent


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    save(tree, tmp_path, 3)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, step = restore(like, tmp_path, None)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_async_checkpoint_and_commit_protocol(tmp_path):
    saver = AsyncSaver()
    tree = {"w": jnp.ones((100, 100))}
    saver.save_async(tree, tmp_path, 1)
    saver.wait()
    assert latest_step(tmp_path) == 1
    # partial (uncommitted) checkpoints are invisible
    d = tmp_path / "step_00000005"
    d.mkdir()
    (d / "w__full.npy").write_bytes(b"junk")
    assert latest_step(tmp_path) == 1   # no manifest -> not committed


def test_fault_tolerant_restart(tmp_path):
    """Kill a real training process mid-run; restart must resume from the
    last committed checkpoint and finish."""
    ckpt = str(tmp_path / "ck")
    code = f"""
import sys
sys.path.insert(0, "src")
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, TokenDataset, synthetic_tokens
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.steps import TrainConfig
cfg = get_smoke_config("qwen2-0.5b")
ds = TokenDataset(synthetic_tokens(200_000, cfg.vocab),
                  DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab))
tc = TrainerConfig(steps=16, ckpt_every=4, ckpt_dir={ckpt!r},
                   fail_at_step={{fail}}, log_every=4,
                   train=TrainConfig(remat="none"))
tr = Trainer(cfg, tc, ds)
out = tr.run()
print("FINAL", out["losses"][-1][0])
"""
    env = dict(os.environ, PYTHONPATH="src")
    # first run crashes at step 10 (after the step-8 checkpoint committed)
    r1 = subprocess.run([sys.executable, "-c", code.replace("{fail}", "10")],
                        capture_output=True, text=True, cwd="/root/repo",
                        env=env, timeout=600)
    assert r1.returncode != 0 and "injected failure" in r1.stderr
    assert latest_step(ckpt) is not None
    resumed_from = latest_step(ckpt)
    assert resumed_from >= 4
    # second run resumes and completes
    r2 = subprocess.run([sys.executable, "-c", code.replace("{fail}", "None")],
                        capture_output=True, text=True, cwd="/root/repo",
                        env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "FINAL 15" in r2.stdout


# -------------------------------------------------------------- compression

def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(10_240) * 3.0, jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape, x.dtype)
    blockmax = np.abs(np.asarray(x)).reshape(-1, 256).max(axis=1)
    tol = (blockmax / 127.0 * 0.51 + 1e-6).repeat(256)
    assert (np.abs(np.asarray(y) - np.asarray(x)) <= tol).all()


def test_stochastic_rounding_unbiased():
    x = jnp.full((256,), 0.3, jnp.float32)
    outs = []
    for i in range(200):
        q, s = quantize_int8(x, rng=jax.random.key(i))
        outs.append(np.asarray(dequantize_int8(q, s, x.shape, x.dtype)))
    est = np.mean(outs)
    assert abs(est - 0.3) < 0.005, est


def test_compressed_psum_matches_fp32():
    """shard_map over a fake 4-way axis: compressed allreduce approximates
    the exact sum."""
    from jax.sharding import PartitionSpec as P
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = make_mesh((1,), ("pod",), axis_type="Explicit")
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 256)),
                    jnp.float32)

    def f(xs):
        return compressed_psum(xs, "pod")

    out = shard_map(f, mesh=mesh, in_specs=P("pod"),
                    out_specs=P("pod"))(x)
    # single shard: psum over 1 device = identity (quantize/dequant error only)
    err = np.abs(np.asarray(out) - np.asarray(x)).max()
    assert err < np.abs(np.asarray(x)).max() / 127 + 1e-5


# ------------------------------------------------------------------ elastic

def test_shrink_plan():
    assert shrink_plan(16, 0) == 16
    assert shrink_plan(16, 1) == 8
    assert shrink_plan(16, 8) == 8
    assert shrink_plan(16, 9) == 4


def test_elastic_reassignment_covers_all_shards():
    ec = ElasticController(8)
    ec.fail(3, step=10)
    ec.mark_slow(5, step=10)
    asg = ec.assignment(step=11)
    shards = sorted(s for lst in asg.values() for s in lst)
    assert shards == list(range(shrink_plan(8, 1)))
    assert 3 not in asg and 5 not in asg      # dead + slow excluded


# ------------------------------------------------------------------ serving

def test_serving_engine_end_to_end():
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_smoke_config("qwen2-0.5b")
    params = init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_seq=64))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=100 + i,
                    prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        assert r.done and len(r.generated) == 4
    # all pages returned to the pool
    assert len(eng.pool.free) == eng.ecfg.n_pages
    # the session store actually served lookups
    st = eng.sessions.stats()
    assert eng.steps >= 10
