"""bourbonlint fixture suites: every rule fires on its positive snippet,
stays quiet on its negative twin, suppressions work only with a
justification, and the baseline round-trips add/expire."""

import json
import os
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import (SUPPRESS, apply_baseline, dead_module_report,
                            default_rules, load_baseline, make_baseline,
                            run_lint, save_baseline)
from repro.analysis.core import SourceFile
from repro.analysis.durorder import DurabilityOrderRule
from repro.analysis.hotsync import HotSyncRule
from repro.analysis.jitdisc import JitDisciplineRule
from repro.analysis.obsdrift import ObsDriftRule
from repro.analysis.pairing import PairingRule

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def lint_snippet(tmp_path, code, rules, name="snip.py", subdir=""):
    d = tmp_path / subdir if subdir else tmp_path
    d.mkdir(parents=True, exist_ok=True)
    p = d / name
    p.write_text(textwrap.dedent(code))
    return run_lint([str(p)], rules, root=str(tmp_path))


# ------------------------------------------------------------------ HOTSYNC

HOTSYNC_POS = """
    import numpy as np, jax, jax.numpy as jnp

    class PipeServer:
        def tick(self):
            dev = jnp.zeros((8,))
            host = np.asarray(dev)            # blocking transfer
            n = int(dev.sum())                # device coercion
            jax.device_get(dev)
            dev.block_until_ready()
            return host, n
"""

HOTSYNC_NEG = """
    import numpy as np, jax.numpy as jnp

    class PipeServer:
        def tick(self, batch):
            keys = np.asarray(batch.keys)     # host numpy: fine
            n = int(keys.sum())               # host coercion: fine
            dev = jnp.asarray(keys)           # host->device: fine
            return self.store.resolve_get(self.store.dispatch_get(dev))

    class Fleet:
        def resolve_get(self, pb):
            # the designated sync point may transfer its pending arg
            found = np.asarray(pb.f_dev)[: pb.n]
            return found

        def snapshot(self):
            dev = jnp.zeros((4,))
            return np.asarray(dev)            # not a registered hot path
"""


def test_hotsync_fires(tmp_path):
    fs = lint_snippet(tmp_path, HOTSYNC_POS, [HotSyncRule()])
    msgs = [f.message for f in fs if f.rule == "HOTSYNC"]
    assert len(msgs) == 4
    assert any("np.asarray" in m for m in msgs)
    assert any("int()" in m for m in msgs)
    assert any("device_get" in m for m in msgs)
    assert any("block_until_ready" in m for m in msgs)


def test_hotsync_quiet(tmp_path):
    fs = lint_snippet(tmp_path, HOTSYNC_NEG, [HotSyncRule()])
    assert [f for f in fs if f.rule == "HOTSYNC"] == []


# ----------------------------------------------------------------- DURORDER

DURORDER_POS = """
    import os

    def publish(path, data, fsync=True):
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)                    # no flush, no fsync
        os.replace(tmp, path)                # ... and no fsync_dir
"""

DURORDER_NEG = """
    import os
    from .format import fsync_dir

    def publish(path, data, fsync=True):
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
        if fsync:
            fsync_dir(os.path.dirname(path))
"""


def durorder_rule():
    return DurabilityOrderRule(scopes=("storage",))


def test_durorder_fires(tmp_path):
    fs = lint_snippet(tmp_path, DURORDER_POS, [durorder_rule()],
                      subdir="storage")
    msgs = [f.message for f in fs if f.rule == "DURORDER"]
    assert any("flush+os.fsync" in m for m in msgs)          # TMPRENAME
    assert any("rename itself" in m for m in msgs)           # REPLACENODIR


def test_durorder_quiet(tmp_path):
    fs = lint_snippet(tmp_path, DURORDER_NEG, [durorder_rule()],
                      subdir="storage")
    assert [f for f in fs if f.rule == "DURORDER"] == []


def test_durorder_create_nosync(tmp_path):
    code = """
    import os

    def recover(path, fsync=True):
        with open(path, "ab") as f:          # new dir entry, never synced
            f.write(b"x")
    """
    fs = lint_snippet(tmp_path, code, [durorder_rule()], subdir="storage")
    assert any("fsync_dir" in f.message for f in fs)


def test_durorder_out_of_scope_quiet(tmp_path):
    # same code outside the storage scope is not durability-relevant
    fs = lint_snippet(tmp_path, DURORDER_POS, [durorder_rule()],
                      subdir="server")
    assert [f for f in fs if f.rule == "DURORDER"] == []


# ------------------------------------------------------------------ JITDISC

JITDISC_POS = """
    import jax

    class Engine:
        def build(self):
            for mode in self.modes:
                fn = jax.jit(lambda s, p: s + p)   # jit inside loop
            g = jax.jit(lambda x: x * self.scale)  # captures self.scale
            return g

    @jax.jit
    def probe(x):
        if x > 0:                                  # tracer truthiness
            return x
        return -x
"""

JITDISC_NEG = """
    import jax
    from functools import partial

    class Engine:
        def build(self, mode: str, slots: tuple):
            fn = partial(self._impl, mode=mode, slots=slots)
            return jax.jit(lambda s, p: fn(s, p))  # closes over locals only

    @partial(jax.jit, static_argnames=("mode",))
    def probe(x, mode):
        S = x.shape[-1]
        if mode == "model":                        # static: annotated arg
            return x
        if S <= 1024:                              # static: shape-derived
            return x * 2
        for i in range(3):                         # static unrolled loop
            x = x + i
        return -x
"""


def test_jitdisc_fires(tmp_path):
    fs = lint_snippet(tmp_path, JITDISC_POS, [JitDisciplineRule()])
    msgs = [f.message for f in fs if f.rule == "JITDISC"]
    assert any("inside a loop" in m for m in msgs)
    assert any("self state" in m and "self.scale" in m for m in msgs)
    assert any("truthiness" in m for m in msgs)


def test_jitdisc_quiet(tmp_path):
    fs = lint_snippet(tmp_path, JITDISC_NEG, [JitDisciplineRule()])
    assert [f for f in fs if f.rule == "JITDISC"] == []


def test_jitdisc_extra_traced(tmp_path):
    code = """
    class LookupEngine:
        def _lookup_impl(self, state, probes, mode: str):
            if probes:                     # tracer truthiness, no decorator
                return state
            return probes
    """
    fs = lint_snippet(tmp_path, code, [JitDisciplineRule()])
    assert any("truthiness" in f.message for f in fs)


# ------------------------------------------------------------------ PAIRING

PAIRING_POS = """
    class Server:
        def serve_discard(self, keys):
            self.store.dispatch_get(keys)          # dropped handle

        def serve_one_path(self, keys):
            pb = self.store.dispatch_get(keys)
            if pb.fast:
                return self.store.resolve_get(pb)
            return None                            # pb leaks on this path

        def fill_unstamped(self, keys, vals):
            self.cache.fill(keys, vals)            # no epoch stamp
"""

PAIRING_NEG = """
    class Server:
        def serve(self, keys):
            pb = self.store.dispatch_get(keys)
            if self._inflight and pb.epochs != self._epoch:   # test only
                self._flush()
            self._inflight.append(pb)              # escapes: consumed

        def serve_inline(self, keys):
            return self.store.resolve_get(self.store.dispatch_get(keys))

        def serve_branches(self, keys):
            pb = self.store.dispatch_get(keys)
            if self.eager:
                f, v = self.store.resolve_get(pb)
                return f, v
            return self._defer(pb)

        def fill_stamped(self, keys, vals, owners, epochs):
            self.cache.fill(keys, vals, owners, epochs)
"""


def test_pairing_fires(tmp_path):
    fs = lint_snippet(tmp_path, PAIRING_POS, [PairingRule()])
    msgs = [f.message for f in fs if f.rule == "PAIRING"]
    assert any("discarded" in m for m in msgs)
    assert any("every following path" in m for m in msgs)
    assert any("epoch stamp" in m for m in msgs)


def test_pairing_quiet(tmp_path):
    fs = lint_snippet(tmp_path, PAIRING_NEG, [PairingRule()])
    assert [f for f in fs if f.rule == "PAIRING"] == []


# ----------------------------------------------------------------- OBSDRIFT

OBSDRIFT_POS = """
    def attach(reg, tr):
        reg.counter("lookup_count")          # bad prefix, not *_total
        reg.gauge("store_files_total")       # gauge may not end _total
        c = reg.counter
        c("server_hits")                     # alias: counter not *_total
        reg.gauge("store_depth", region="eu")   # unknown label
        tr.stage("admissionz")               # not a READ_STAGE
        publish_stats(reg, "svr", {})        # undeclared prefix
        sp = tr.begin_span("walsync", bt)    # not a SPAN_NAME
        tr.end_span(sp, stage="fsync")       # not a CRITICAL_STAGE
"""

OBSDRIFT_NEG = """
    def attach(reg, tr, lb):
        reg.counter("server_gets_total", shard="0")
        reg.gauge("store_level_files", level="3", **lb)
        c = reg.counter
        c("cache_hits_total")
        reg.histogram("server_stage_us", stage="resolve")
        tr.stage("cache_probe")
        publish_stats(reg, "fleet", {})
        name = compute_name()
        reg.gauge(name)                      # dynamic: skipped
        sp = tr.begin_span("wal_sync", bt, link=bt, shard=0)
        tr.end_span(sp, stage="wal_fsync", retrack=True)
        tr.end_span(tr.begin_span(name, bt))    # dynamic name: skipped
"""


def obsdrift_rule():
    # fixture rule uses the built-in fallback declarations
    return ObsDriftRule()


def test_obsdrift_fires(tmp_path):
    fs = lint_snippet(tmp_path, OBSDRIFT_POS, [obsdrift_rule()])
    msgs = [f.message for f in fs if f.rule == "OBSDRIFT"]
    assert any("layer prefix" in m for m in msgs)
    assert any("'_total'" in m and "gauge" in m for m in msgs)
    assert any("server_hits" in m for m in msgs)      # alias tracked
    assert any("label 'region'" in m for m in msgs)
    assert any("READ_STAGES" in m for m in msgs)
    assert any("publish_stats prefix" in m for m in msgs)
    assert any("SPAN_NAMES" in m for m in msgs)
    assert any("CRITICAL_STAGES" in m for m in msgs)


def test_obsdrift_quiet(tmp_path):
    fs = lint_snippet(tmp_path, OBSDRIFT_NEG, [obsdrift_rule()])
    assert [f for f in fs if f.rule == "OBSDRIFT"] == []


def test_obsdrift_reads_live_declarations():
    rule = ObsDriftRule.from_root(REPO)
    assert "value_fetch" in rule.stages       # parsed from obs/__init__.py
    assert "fleet" in rule.prefixes           # parsed from obs/README.md
    assert "index" in rule.labels
    assert "shard_probe" in rule.spans        # parsed from obs/trace.py
    assert "wal_fsync" in rule.critical
    # code and README causal-tracing tables agree (drift would be
    # reported as findings against trace.py)
    assert rule._trace_drift == []


# ------------------------------------------------------------- suppressions

def test_suppression_honored(tmp_path):
    code = """
    import numpy as np, jax.numpy as jnp

    class PipeServer:
        def tick(self):
            dev = jnp.zeros((4,))
            # bourbonlint: allow[HOTSYNC] -- stats snapshot, off hot path
            return np.asarray(dev)
    """
    fs = lint_snippet(tmp_path, code, [HotSyncRule()])
    hot = [f for f in fs if f.rule == "HOTSYNC"]
    assert len(hot) == 1 and hot[0].suppressed
    assert not [f for f in fs if f.rule == SUPPRESS]


def test_suppression_without_justification_rejected(tmp_path):
    code = """
    import numpy as np, jax.numpy as jnp

    class PipeServer:
        def tick(self):
            dev = jnp.zeros((4,))
            return np.asarray(dev)  # bourbonlint: allow[HOTSYNC]
    """
    fs = lint_snippet(tmp_path, code, [HotSyncRule()])
    hot = [f for f in fs if f.rule == "HOTSYNC"]
    assert len(hot) == 1 and not hot[0].suppressed    # NOT suppressed
    supp = [f for f in fs if f.rule == SUPPRESS]
    assert len(supp) == 1 and "justification" in supp[0].message


def test_suppress_finding_not_suppressible(tmp_path):
    code = """
    # bourbonlint: allow[SUPPRESS] -- should not work
    # bourbonlint: allow[HOTSYNC]
    x = 1
    """
    fs = lint_snippet(tmp_path, code, [HotSyncRule()])
    supp = [f for f in fs if f.rule == SUPPRESS]
    assert len(supp) == 1 and not supp[0].suppressed


# ----------------------------------------------------------------- baseline

def test_baseline_add_expire_roundtrip(tmp_path):
    bl_path = str(tmp_path / "bl.json")
    rules = [HotSyncRule()]

    fs = lint_snippet(tmp_path, HOTSYNC_POS, rules)
    assert len(fs) == 4 and not any(f.baselined for f in fs)

    # add: baseline covers today's findings; rerun is green
    save_baseline(bl_path, make_baseline(fs))
    fs2 = lint_snippet(tmp_path, HOTSYNC_POS, rules)
    expired = apply_baseline(fs2, load_baseline(bl_path))
    assert all(f.baselined for f in fs2) and expired == []

    # a *new* violation of the same rule is not covered
    extra = HOTSYNC_POS + """
        def dispatch_more(self):
            return np.asarray(jnp.ones(2))
    """
    fs3 = lint_snippet(tmp_path, extra, rules)
    apply_baseline(fs3, load_baseline(bl_path))
    new = [f for f in fs3 if not f.baselined]
    assert len(new) == 1 and "dispatch_more" in new[0].symbol

    # expire: fixing the code leaves dangling baseline entries to prune
    fs4 = lint_snippet(tmp_path, HOTSYNC_NEG, rules)
    expired = apply_baseline(fs4, load_baseline(bl_path))
    assert len(expired) == 4
    save_baseline(bl_path, make_baseline(fs4))
    assert load_baseline(bl_path)["findings"] == []


def test_repo_baseline_is_empty():
    with open(os.path.join(REPO, ".bourbonlint-baseline.json")) as f:
        assert json.load(f)["findings"] == []


# ------------------------------------------------------------- repo-level

def test_repo_lints_clean():
    """The production gate: zero unbaselined findings on src/repro."""
    rules = default_rules(REPO)
    fs = run_lint([os.path.join(REPO, "src", "repro")], rules, root=REPO)
    new = [f for f in fs if not f.suppressed and not f.baselined]
    assert new == [], "\n" + "\n".join(f.render() for f in new)


def test_dead_module_report():
    rep = dead_module_report(REPO)
    assert rep["dead"] == [], rep["dead"]      # allowlist covers the rest
    assert rep["reachable"] > 50
    # the quarantined seed leftovers really are flagged, not forgotten
    assert any(m.startswith("repro.configs.") for m in rep["quarantined"])


def test_parse_error_reported(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def broken(:\n")
    fs = run_lint([str(p)], [HotSyncRule()], root=str(tmp_path))
    assert len(fs) == 1 and fs[0].rule == "PARSE"
