"""Durable storage engine: WAL replay, MANIFEST recovery, persisted PLR
models, crash injection at randomized points, and value-log GC."""

import os

import numpy as np
import pytest

from repro.core import BourbonStore, LSMConfig, StoreConfig
from repro.core.engine import EngineConfig


def small_cfg(**kw):
    defaults = dict(policy="always", value_size=16,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _values_for(keys: np.ndarray, version: int, value_size: int = 16):
    v = np.zeros((keys.shape[0], value_size), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _check_reads(store, shadow: dict, probes: np.ndarray,
                 batch: int = 4096) -> None:
    """Every get_batch result must match the shadow dict (presence and,
    via fetch_values, the exact payload version)."""
    store.cfg.fetch_values = True
    store.cfg.engine.fetch_values = True
    try:
        for off in range(0, probes.shape[0], batch):
            p = probes[off: off + batch]
            found, vals = store.get_batch(p)
            for i, k in enumerate(p):
                ver = shadow.get(int(k))
                if ver is None:
                    assert not found[i], f"key {k} found but never live"
                else:
                    assert found[i], f"key {k} lost"
                    assert vals[i, 0] == k % 251
                    assert vals[i, 1] == ver % 251, \
                        f"key {k}: stale value version"
    finally:
        store.cfg.fetch_values = False
        store.cfg.engine.fetch_values = False


# --------------------------------------------------------------- unit pieces

def test_sstable_file_roundtrip(tmp_path):
    from repro.core.sstable import build_sstable
    from repro.storage import append_model, load_sstable, write_sstable

    keys = np.arange(0, 5000, 2, dtype=np.int64)
    seqs = np.arange(keys.shape[0], dtype=np.int64)
    vptrs = seqs * 3
    t = build_sstable(keys, seqs, vptrs, level=2, now=42.0)
    write_sstable(str(tmp_path), t)
    r = load_sstable(str(tmp_path / f"{t.file_id:06d}.sst"))
    np.testing.assert_array_equal(r.keys, t.keys)
    np.testing.assert_array_equal(r.seqs, t.seqs)
    np.testing.assert_array_equal(r.vptrs, t.vptrs)
    np.testing.assert_array_equal(r.fences, t.fences)
    np.testing.assert_array_equal(r.bloom, t.bloom)
    assert (r.level, r.file_id, r.created_at) == (2, t.file_id, 42.0)
    assert r.model is None

    # model appended post hoc (the async-learning path)
    t.learn(delta=8)
    append_model(str(tmp_path / f"{t.file_id:06d}.sst"), t.model)
    r2 = load_sstable(str(tmp_path / f"{t.file_id:06d}.sst"))
    assert r2.model is not None
    assert int(r2.model.n_segments) == int(t.model.n_segments)
    np.testing.assert_allclose(np.asarray(r2.model.slopes),
                               np.asarray(t.model.slopes)[:int(t.model.n_segments)])


def test_wal_roundtrip_and_torn_tail(tmp_path):
    from repro.storage import WALWriter, replay_wal

    path = str(tmp_path / "wal-000001.log")
    w = WALWriter(path)
    rng = np.random.default_rng(0)
    batches = []
    for _ in range(5):
        k = rng.integers(0, 1 << 40, 200).astype(np.int64)
        s = rng.integers(0, 1 << 30, 200).astype(np.int64)
        v = rng.integers(-1, 1 << 30, 200).astype(np.int64)
        w.append(k, s, v)
        batches.append((k, s, v))
    w.close()
    got = replay_wal(path)
    assert len(got) == 5
    for (k, s, v), (gk, gs, gv) in zip(batches, got):
        np.testing.assert_array_equal(k, gk)
        np.testing.assert_array_equal(s, gs)
        np.testing.assert_array_equal(v, gv)
    # torn tail: drop 3 bytes -> the last frame must vanish, rest intact
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 3)
    got = replay_wal(path)
    assert len(got) == 4


def test_manifest_replay(tmp_path):
    from repro.storage import ManifestWriter, read_manifest

    w = ManifestWriter(str(tmp_path))
    w.append({"wal": 1})
    w.append({"add": [[0, 0], [1, 0]], "seq": 100, "clock": 5.0})
    w.append({"add": [[2, 1]], "del": [0, 1], "wal": 2, "seq": 200})
    w.append({"vlog_rm": [0, 3], "vhead": 4096})
    w.close()
    state, no = read_manifest(str(tmp_path))
    assert no == 1
    assert state.live == {2: 1}
    assert state.wal_no == 2
    assert state.seq == 200
    assert state.clock == 5.0
    assert state.vlog_removed == {0, 3}
    assert state.vhead == 4096


# ------------------------------------------------------------ lifecycle

def test_reopen_roundtrip_with_persisted_models(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    rng = np.random.default_rng(3)
    keys = rng.permutation(np.arange(1, 20001, dtype=np.int64) * 5)
    shadow = {}
    for off in range(0, keys.shape[0], 4096):
        ks = keys[off: off + 4096]
        st.put_batch(ks, _values_for(ks, 0))
        for k in ks:
            shadow[int(k)] = 0
    st.flush_all()
    st.learn_all()
    n_learned = st.stats()["n_learned"]
    assert n_learned == st.stats()["n_files"]
    st.close()

    st2 = BourbonStore.open(d, small_cfg())
    s = st2.stats()
    # persisted PLR models reload without retraining
    assert s["n_learned"] == s["n_files"] == n_learned
    assert s["models_recovered"] == n_learned
    assert s["files_learned"] == 0
    assert all(t.model is not None for t in st2.tree.all_files())
    _check_reads(st2, shadow, keys[:8192])
    miss, _ = st2.get_batch(keys[:4096] + 1)
    assert not miss.any()
    st2.close()


def test_crash_recovery_randomized_100k(tmp_path):
    """The acceptance scenario: >=100k keys with overwrites and deletes,
    crash (no close) at a randomized point, recover, compare against a
    shadow dict; persisted models reload with files_learned untouched."""
    d = str(tmp_path / "db")
    cfg = small_cfg(lsm=LSMConfig(memtable_cap=1 << 12, file_cap=1 << 13,
                                  l1_cap_records=1 << 15))
    st = BourbonStore.open(d, cfg)
    rng = np.random.default_rng(11)
    keys = rng.permutation(np.arange(1, 100_001, dtype=np.int64) * 7)
    shadow = {}
    for off in range(0, keys.shape[0], 8192):     # load phase (>=100k keys)
        ks = keys[off: off + 8192]
        st.put_batch(ks, _values_for(ks, 0))
        for k in ks:
            shadow[int(k)] = 0
    st.flush_all()
    st.learn_all()

    # mutation phase: overwrite + delete batches, crash at a random point
    ops = []
    for ver in (1, 2):
        for off in range(0, 40_000, 8192):
            ops.append(("put", keys[off: off + 8192], ver))
    ops.append(("del", keys[:10_000], None))
    for off in range(0, 20_000, 8192):
        ops.append(("put", keys[off: off + 8192], 3))
    crash_at = int(rng.integers(1, len(ops)))
    for op, ks, ver in ops[:crash_at]:
        if op == "put":
            st.put_batch(ks, _values_for(ks, ver))
            for k in ks:
                shadow[int(k)] = ver
        else:
            st.delete_batch(ks)
            for k in ks:
                shadow.pop(int(k), None)
    st.learn_all()   # models persisted into the live sstables at crash time
    del st  # CRASH: no close, memtable contents only in the WAL

    st2 = BourbonStore.open(d, cfg)
    s = st2.stats()
    assert s["n_records"] + len(st2.memtable) >= len(shadow)
    assert s["files_learned"] == 0               # nothing relearned
    assert s["models_recovered"] == s["n_learned"] == s["n_files"] > 0
    assert all(t.model is not None for t in st2.tree.all_files())
    probes = np.concatenate([keys, keys[:4096] + 1])  # all keys + misses
    _check_reads(st2, shadow, probes)
    st2.close()


def test_torn_wal_tail_drops_only_last_batch(tmp_path):
    d = str(tmp_path / "db")
    cfg = small_cfg(policy="never", mode="wisckey")
    st = BourbonStore.open(d, cfg)
    a = np.arange(1, 201, dtype=np.int64)
    b = np.arange(1001, 1101, dtype=np.int64)
    st.put_batch(a, _values_for(a, 0))
    st.put_batch(b, _values_for(b, 0))
    del st  # crash
    wals = [n for n in os.listdir(d) if n.startswith("wal-")]
    assert len(wals) == 1
    path = os.path.join(d, wals[0])
    with open(path, "r+b") as f:   # tear mid-frame: the b-batch is lost
        f.truncate(os.path.getsize(path) - 7)
    st2 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    fa, _ = st2.get_batch(np.concatenate([a, np.zeros(56, np.int64) + 5000]))
    assert fa[:200].all()
    fb, _ = st2.get_batch(np.concatenate([b, np.zeros(156, np.int64) + 5000]))
    assert not fb.any()            # unacknowledged tail dropped, no error
    st2.close()


def test_repeated_crash_cycles(tmp_path):
    """Kill the store at randomized points across several sessions; the
    shadow dict must survive every reopen."""
    d = str(tmp_path / "db")
    rng = np.random.default_rng(7)
    space = np.arange(1, 4001, dtype=np.int64) * 11
    shadow = {}
    ver = 0
    for session in range(4):
        st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
        n_batches = int(rng.integers(1, 6))
        for _ in range(n_batches):
            ver += 1
            ks = rng.choice(space, int(rng.integers(100, 1500)), replace=False)
            if rng.random() < 0.25:
                st.delete_batch(ks)
                for k in ks:
                    shadow.pop(int(k), None)
            else:
                st.put_batch(ks, _values_for(ks, ver))
                for k in ks:
                    shadow[int(k)] = ver
        if session % 2 == 0:
            del st                 # hard crash
        else:
            st.close()             # clean shutdown (WAL still replays)
        st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
        _check_reads(st, shadow, space)
        st.close()


# ------------------------------------------------------------------ vlog GC

def test_gc_reclaims_dead_bytes_and_keeps_reads_correct(tmp_path):
    d = str(tmp_path / "db")
    cfg = small_cfg(policy="never", mode="wisckey", vlog_seg_slots=1 << 10)
    st = BourbonStore.open(d, cfg)
    rng = np.random.default_rng(5)
    keys = rng.permutation(np.arange(1, 8001, dtype=np.int64) * 13)
    shadow = {}
    for ver in range(4):           # overwrite-heavy: 4 versions of each key
        for off in range(0, keys.shape[0], 2048):
            ks = keys[off: off + 2048]
            st.put_batch(ks, _values_for(ks, ver))
            for k in ks:
                shadow[int(k)] = ver
    st.delete_batch(keys[:1000])
    for k in keys[:1000]:
        shadow.pop(int(k), None)
    st.flush_all()

    entry = st.vlog.entry_size
    before = st.vlog.disk_bytes()
    live_ptrs = st._host_get_vptrs(keys)
    n_live = int((live_ptrs >= 0).sum())
    dead_bytes = before - n_live * entry
    assert dead_bytes > 0

    res = st.gc_value_log(min_dead_ratio=0.3)
    after = st.vlog.disk_bytes()
    assert res["segments_removed"] > 0
    assert before - after >= 0.5 * dead_bytes, \
        f"reclaimed {before - after} of {dead_bytes} dead bytes"
    # relocated pointers were routed through the LSM: reads stay exact
    _check_reads(st, shadow, keys)
    st.close()

    # ... and survive a reopen (GC edits are in the MANIFEST)
    st2 = BourbonStore.open(d, cfg)
    _check_reads(st2, shadow, keys)
    assert st2.vlog.removed == st.vlog.removed
    st2.close()


def test_gc_requires_durable_store():
    st = BourbonStore(small_cfg())
    with pytest.raises(RuntimeError):
        st.gc_value_log()


def test_manifest_torn_tail_then_new_session_survives(tmp_path):
    """Edits appended after a crash-torn manifest frame must stay visible:
    the writer truncates the torn tail before appending."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    a = np.arange(1, 3001, dtype=np.int64)
    st.put_batch(a, _values_for(a, 0))
    st.flush_all()
    st.close()
    mpath = [os.path.join(d, n) for n in os.listdir(d)
             if n.startswith("MANIFEST")][0]
    with open(mpath, "ab") as f:        # crash-torn partial frame
        f.write(b"\x13\x37torn-frame-garbage")
    # second session writes + flushes through the damaged manifest
    st2 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    b = np.arange(10_001, 13_001, dtype=np.int64)
    st2.put_batch(b, _values_for(b, 1))
    st2.flush_all()
    st2.close()
    # third session must see BOTH sessions' data
    st3 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    fa, _ = st3.get_batch(a)
    fb, _ = st3.get_batch(b)
    assert fa.all() and fb.all()
    st3.close()


def test_gc_at_exact_segment_boundary(tmp_path):
    """Head exactly on a segment boundary: the last-written segment is
    sealed and must be collectable without error."""
    d = str(tmp_path / "db")
    cfg = small_cfg(policy="never", mode="wisckey", vlog_seg_slots=1 << 10)
    st = BourbonStore.open(d, cfg)
    ks = np.arange(1, 2049, dtype=np.int64)     # exactly 2 segments of values
    st.put_batch(ks, _values_for(ks, 0))
    assert len(st.vlog) % (1 << 10) == 0
    st.delete_batch(ks)                          # everything dead
    st.flush_all()
    res = st.gc_value_log(min_dead_ratio=0.3)
    assert res["segments_removed"] == 2
    found, _ = st.get_batch(ks)
    assert not found.any()
    # the log keeps working after the boundary drop
    st.put_batch(ks[:100], _values_for(ks[:100], 1))
    found, _ = st.get_batch(ks[:100])
    assert found.all()
    st.close()


def test_reopen_with_wrong_vlog_geometry_refused(tmp_path):
    """Parsing segment files with a different entry size would destroy
    them; the manifest records the geometry and open() validates it."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    ks = np.arange(1, 2001, dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    st.close()
    with pytest.raises(ValueError, match="value_size"):
        BourbonStore.open(d, small_cfg(policy="never", mode="wisckey",
                                       value_size=64))
    with pytest.raises(ValueError, match="value_size"):
        BourbonStore.open(d, small_cfg(policy="never", mode="wisckey",
                                       vlog_seg_slots=1 << 8))
    # a smaller plr_delta would shrink the model search window below the
    # persisted models' error bound -> silent read loss; must be refused
    with pytest.raises(ValueError, match="plr_delta"):
        BourbonStore.open(d, small_cfg(
            policy="never", mode="wisckey",
            lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                          l1_cap_records=1 << 13, plr_delta=2)))
    # the refused opens must not have damaged anything
    st2 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    f, _ = st2.get_batch(ks)
    assert f.all()
    st2.close()


def test_second_open_of_live_store_refused(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    st.put_batch(np.arange(1, 101, dtype=np.int64))
    with pytest.raises(RuntimeError, match="already open"):
        BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    st.close()
    # released on close
    st2 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    st2.close()


def test_level_granularity_survives_reopen(tmp_path):
    """Level models fit before close are persisted (MANIFEST ``lmodel``
    record + sidecar) and reload without relearning; levels whose model
    never landed resubmit their learning jobs.  See test_level_models.py
    for the full persistence matrix."""
    d = str(tmp_path / "db")
    cfg = small_cfg(granularity="level", policy="always")
    st = BourbonStore.open(d, cfg)
    ks = np.arange(1, 20001, dtype=np.int64) * 3
    st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    st.drain_learning()
    fitted = [i for i in range(1, 7) if st.level_models[i] is not None]
    st.close()
    st2 = BourbonStore.open(d, small_cfg(granularity="level",
                                         policy="always"))
    assert any(st2.tree.levels[i] for i in range(1, 7))
    assert fitted and all(st2.level_models[i] is not None for i in fitted)
    assert st2.drain_learning() == 0   # nothing left to relearn
    f, _ = st2.get_batch(ks[:4096])
    assert f.all()
    st2.close()


def test_writes_after_close_rejected(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    ks = np.arange(1, 101, dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    st.close()
    with pytest.raises(RuntimeError, match="closed"):
        st.put_batch(ks, _values_for(ks, 1))
    with pytest.raises(RuntimeError, match="closed"):
        st.delete_batch(ks)
    with pytest.raises(RuntimeError, match="closed"):
        st.gc_value_log()


def test_unreferenced_sstable_swept_on_recovery(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    ks = np.arange(1, 3001, dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    st.close()
    # simulate a crash between file write and manifest edit
    orphan = os.path.join(d, "099999.sst")
    live = [n for n in os.listdir(d) if n.endswith(".sst")][0]
    with open(os.path.join(d, live), "rb") as f:
        data = f.read()
    with open(orphan, "wb") as f:
        f.write(data)
    st2 = BourbonStore.open(d, small_cfg(policy="never", mode="wisckey"))
    assert not os.path.exists(orphan)
    f_, _ = st2.get_batch(np.concatenate([ks, ks[-1:] + 999]))
    assert f_[:-1].all() and not f_[-1]
    st2.close()
