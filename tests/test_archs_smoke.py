"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; assert shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import (decode_step, forward, init_caches, init_params,
                          loss_fn)

B, S = 2, 32


def make_batch(cfg, rng):
    r1, r2, r3 = jax.random.split(rng, 3)
    batch = {"labels": jax.random.randint(r2, (B, S), 0, cfg.vocab)}
    if cfg.inputs_embeds:
        batch["embeds"] = jax.random.normal(r1, (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(r1, (B, S), 0, cfg.vocab)
    if cfg.n_image_tokens:
        batch["image_embed"] = jax.random.normal(
            r3, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    rng = jax.random.key(0)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, jax.random.key(1))
    logits, aux = forward(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          aux={"image_embed": batch.get("image_embed")},
                          remat=None)
    assert logits.shape == (B, S, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))

    def step(p, b):
        (l, metrics), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, b, remat="full"), has_aux=True)(p)
        p = jax.tree.map(lambda w, gw: w - 1e-3 * gw.astype(w.dtype), p, g)
        return p, l

    params2, loss = jax.jit(step)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss {loss}"
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(params2)[0]
    assert not np.array_equal(np.asarray(d0, np.float32),
                              np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    T = 64
    caches = init_caches(cfg, B, T)
    aux = {}
    if cfg.n_image_tokens:
        aux["image_embed"] = jax.random.normal(
            jax.random.key(3), (B, cfg.n_image_tokens, cfg.d_model),
            jnp.float32)
    if cfg.inputs_embeds:
        x = jax.random.normal(jax.random.key(2), (B, 1, cfg.d_model),
                              jnp.float32)
        logits, caches = jax.jit(
            lambda p, c, e: decode_step(p, cfg, c, embeds=e, aux=aux)
        )(params, caches, x)
    else:
        tok = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab)
        logits, caches = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, tokens=t, aux=aux)
        )(params, caches, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite decode logits"


def test_decode_matches_forward_prefix():
    """Decoding tokens one-by-one must match the parallel forward (tests KV
    cache correctness) for a full-attention arch."""
    cfg = get_smoke_config("qwen2.5-14b")
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens=toks, remat=None)
    caches = init_caches(cfg, B, 8)
    outs = []
    for i in range(8):
        lg, caches = decode_step(params, cfg, caches, tokens=toks[:, i: i + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Same invariant for the recurrent (xLSTM) path."""
    cfg = get_smoke_config("xlstm-1.3b")
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, 8), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens=toks, remat=None)
    caches = init_caches(cfg, B, 8)
    outs = []
    for i in range(8):
        lg, caches = decode_step(params, cfg, caches, tokens=toks[:, i: i + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)
