"""CBA-scheduled maintenance: auto value-log GC driven by dead-entry
estimates, MANIFEST checkpointing, GC edge cases, and the scheduler's
cost-benefit decisions.  Plus the drain_learning / _engine_mode / stats
contract fixes that ride along."""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import (BourbonStore, CostModel, LSMConfig,
                        MaintenanceConfig, StoreConfig)
from repro.core.cba import CBAConfig, MaintenanceScheduler
from repro.core.engine import EngineConfig
from repro.storage import read_manifest


def small_cfg(**kw):
    defaults = dict(policy="never", mode="wisckey", value_size=16,
                    vlog_seg_slots=1 << 10,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _values_for(keys: np.ndarray, version: int, value_size: int = 16):
    v = np.zeros((keys.shape[0], value_size), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _overwrite_rounds(st, keys, rounds, batch=1024):
    for ver in range(rounds):
        for off in range(0, keys.shape[0], batch):
            ks = keys[off: off + batch]
            st.put_batch(ks, _values_for(ks, ver))


# ------------------------------------------------------- dead-entry tracking

def test_write_path_dead_estimates_match_liveness(tmp_path):
    """The incremental per-segment estimates must agree with the ground
    truth (entries whose pointer the LSM no longer returns)."""
    st = BourbonStore.open(str(tmp_path / "db"),
                           small_cfg(maintenance=MaintenanceConfig(
                               auto_gc=False, auto_checkpoint=False)))
    keys = np.arange(1, 3001, dtype=np.int64) * 3
    _overwrite_rounds(st, keys, 3)
    st.delete_batch(keys[:500])
    st.flush_all()
    # ground truth per sealed segment
    for seg in st.vlog.sealed_segments():
        ptrs, ks, _, _ = st.vlog.read_segment(seg, with_values=False)
        cur = st._host_get_vptrs(ks)
        true_dead = int((cur != ptrs).sum())
        assert st.vlog.dead_by_seg.get(seg, 0) == true_dead, f"seg {seg}"
    st.close()


def test_duplicate_keys_within_batch_counted(tmp_path):
    st = BourbonStore.open(str(tmp_path / "db"),
                           small_cfg(maintenance=MaintenanceConfig(
                               auto_gc=False, auto_checkpoint=False)))
    ks = np.array([5, 5, 5, 9], dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    # two of the three '5' slots died at append time, '9' is live
    assert st.vlog.dead_entries == 2
    st.put_batch(np.array([5, 9], np.int64))   # supersedes both live slots
    assert st.vlog.dead_entries == 4
    st.close()


# --------------------------------------------------------------- GC edges

def test_gc_empty_sealed_segment_dead_ratio_one(tmp_path):
    """A sealed segment whose file lost every entry (e.g. OS dropped an
    unsynced file) reads as 0 complete entries -> dead_ratio 1.0 -> must
    be reclaimed without relocating anything."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(maintenance=MaintenanceConfig(
        auto_gc=False, auto_checkpoint=False)))
    ks = np.arange(1, 2049, dtype=np.int64)        # seals segments 0 and 1
    st.put_batch(ks, _values_for(ks, 0))
    victim = st.vlog.sealed_segments()[0]
    from repro.storage.format import vlog_path
    with open(vlog_path(d, victim), "r+b") as f:
        f.truncate(0)
    res = st.gc_value_log(min_dead_ratio=0.3, segments=[victim])
    assert res["segments_removed"] == 1
    assert res["entries_moved"] == 0
    assert victim in st.vlog.removed
    # the sibling segment was untouched and its keys still read fine
    f2, _ = st.get_batch(ks[1024:])
    assert f2.all()
    st.close()


def test_gc_max_segments_mid_chunk(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(maintenance=MaintenanceConfig(
        auto_gc=False, auto_checkpoint=False)))
    keys = np.arange(1, 6001, dtype=np.int64) * 7
    _overwrite_rounds(st, keys, 3)                 # most segments mostly dead
    st.flush_all()
    n_sealed = len(st.vlog.sealed_segments())
    assert n_sealed > 3
    res = st.gc_value_log(min_dead_ratio=0.1, max_segments=3)
    assert res["segments_removed"] == 3            # stopped mid-chunk
    assert len(st.vlog.removed) == 3
    # reads unharmed, and a follow-up pass may keep going
    f, _ = st.get_batch(keys)
    assert f.all()
    res2 = st.gc_value_log(min_dead_ratio=0.1, max_segments=None)
    assert res2["segments_removed"] >= 1
    f, _ = st.get_batch(keys)
    assert f.all()
    st.close()


def test_gc_then_close_then_reopen_keeps_estimates_and_removed(tmp_path):
    d = str(tmp_path / "db")
    cfg = small_cfg(maintenance=MaintenanceConfig(auto_gc=False,
                                                  auto_checkpoint=False))
    st = BourbonStore.open(d, cfg)
    keys = np.arange(1, 5001, dtype=np.int64) * 3
    _overwrite_rounds(st, keys, 3)
    st.delete_batch(keys[:800])
    st.flush_all()
    res = st.gc_value_log(min_dead_ratio=0.5)
    assert res["segments_removed"] > 0
    removed = set(st.vlog.removed)
    dead_by_seg = dict(st.vlog.dead_by_seg)
    dead_total = st.vlog.dead_entries
    st.close()

    st2 = BourbonStore.open(d, cfg)
    assert st2.vlog.removed == removed
    assert st2.vlog.dead_by_seg == dead_by_seg
    assert st2.vlog.dead_entries == dead_total
    assert st2.stats()["vlog_segments_removed"] == len(removed)
    # the estimates keep accumulating correctly after reopen
    st2.put_batch(keys[1000:1200], _values_for(keys[1000:1200], 9))
    assert st2.vlog.dead_entries >= dead_total
    f, _ = st2.get_batch(keys[800:])
    assert f.all()
    st2.close()


# ----------------------------------------------------------- auto-GC (CBA)

def test_auto_gc_bounds_disk_under_sustained_overwrites(tmp_path):
    """The acceptance scenario: sustained overwrites with zero manual
    gc_value_log calls must keep vlog disk bytes bounded and every
    remaining sealed segment below the dead-ratio watermark (modulo the
    per-segment T_wait window)."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())     # auto_gc on by default
    keys = np.arange(1, 4001, dtype=np.int64) * 3
    working_set_bytes = keys.shape[0] * st.vlog.entry_size
    _overwrite_rounds(st, keys, 12)
    st.flush_all()
    s = st.stats()
    assert s["auto_gc"]["runs"] > 0
    assert s["auto_gc"]["segments_removed"] > 0
    appended = st.vlog._head * st.vlog.entry_size
    assert appended > 8 * working_set_bytes    # the workload really churned
    # bounded: disk stays within a small multiple of the live set
    assert s["vlog_disk_bytes"] < 4 * working_set_bytes, \
        f"vlog grew unbounded: {s['vlog_disk_bytes']}B"
    # every sealed segment past its T_wait is below the watermark
    t_wait = st.cba.gc_t_wait(st.vlog.seg_slots)
    now = st.clock.now
    for seg in st.vlog.sealed_segments():
        if now >= st.cba.sealed_at.get(seg, now) + t_wait:
            assert st.vlog.dead_ratio_est(seg) < \
                st.cfg.maintenance.gc_dead_ratio + 0.35
    # reads exact after all that churn
    st.cfg.fetch_values = True
    st.cfg.engine.fetch_values = True
    f, vals = st.get_batch(keys)
    assert f.all()
    assert (vals[:, 1] == 11).all()            # newest version everywhere
    assert s["gc_us"] > 0                      # charged to the virtual clock
    st.close()


def test_scheduler_skips_unprofitable_segments(tmp_path):
    """Candidacy must respect watermark, T_wait, and B>C — without I/O."""
    from repro.storage import DurableValueLog
    vlog = DurableValueLog(16, str(tmp_path), seg_slots=64)
    vlog.append_kv(np.arange(256, dtype=np.int64),
                   np.arange(256, dtype=np.int64),
                   np.zeros((256, 16), np.uint8))   # seals segments 0..3
    sched = MaintenanceScheduler(CBAConfig(), CostModel(),
                                 MaintenanceConfig(gc_t_wait_us=100.0))
    vlog.note_dead(np.arange(0, 64, dtype=np.int64))     # seg 0 fully dead
    vlog.note_dead(np.arange(64, 68, dtype=np.int64))    # seg 1 barely dead
    # T_wait not elapsed: nothing is a candidate yet
    assert sched.gc_candidates(vlog, now=0.0) == []
    assert sched.gc_decisions["waiting"] > 0
    # after T_wait: seg 0 profitable, seg 1 under the watermark
    picked = sched.gc_candidates(vlog, now=500.0)
    assert picked == [0]
    assert sched.gc_decisions["skipped"] > 0
    # a dead-but-tiny-benefit segment loses to cost when the rate is ~0
    starved = MaintenanceScheduler(
        CBAConfig(), CostModel(gc_benefit_per_dead_byte=1e-9),
        MaintenanceConfig(gc_t_wait_us=0.0))
    assert starved.gc_candidates(vlog, now=500.0) == []
    vlog.close()


# ------------------------------------------------------ MANIFEST checkpoint

def test_manifest_checkpoint_recovers_identical_state(tmp_path):
    d = str(tmp_path / "db")
    cfg = small_cfg(maintenance=MaintenanceConfig(
        auto_gc=True, checkpoint_bytes=2048))
    st = BourbonStore.open(d, cfg)
    keys = np.arange(1, 4001, dtype=np.int64) * 3
    _overwrite_rounds(st, keys, 8)
    st.flush_all()
    s = st.stats()
    assert s["manifest_checkpoints"] > 0
    assert s["manifest_bytes"] < 2048 + 1024   # folded, not still growing
    # exactly one numbered manifest remains, and it replays to the very
    # state the engine holds in memory
    manifests = [n for n in os.listdir(d) if n.startswith("MANIFEST-")]
    assert len(manifests) == 1
    state, no = read_manifest(d)
    assert no == st._storage.manifest.no
    assert state == st._storage.state
    st.close()

    st2 = BourbonStore.open(d, cfg)
    f, _ = st2.get_batch(keys)
    assert f.all()
    st2.close()


def test_orphan_manifest_from_crashed_checkpoint_swept(tmp_path):
    """Crash between writing MANIFEST-<n+1> and switching CURRENT leaves
    an orphan; the next open must ignore and remove it."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    ks = np.arange(1, 2001, dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    st.close()
    orphan = os.path.join(d, "MANIFEST-000042")
    with open(orphan, "wb") as f:
        f.write(b"half-written checkpoint")
    st2 = BourbonStore.open(d, small_cfg())
    assert not os.path.exists(orphan)
    f_, _ = st2.get_batch(ks)
    assert f_.all()
    st2.close()


def test_checkpoint_not_retriggered_when_folded_state_large(tmp_path):
    """Once the folded state itself exceeds the threshold, scheduling must
    key on tail bytes since the last fold — total size would re-checkpoint
    on every tick, and base must reset across reopen too."""
    d = str(tmp_path / "db")
    cfg = small_cfg(maintenance=MaintenanceConfig(checkpoint_bytes=512))
    st = BourbonStore.open(d, cfg)
    keys = np.arange(1, 4001, dtype=np.int64) * 3
    _overwrite_rounds(st, keys, 6)
    st.flush_all()
    assert st._storage.manifest_bytes() > 512   # folded state > threshold
    n = st.cba.checkpoints
    for _ in range(30):
        st.get_batch(keys[:64])                 # ticks with no new edits
    assert st.cba.checkpoints == n, "checkpoint loop on read-only ticks"
    st.close()
    st2 = BourbonStore.open(d, cfg)
    n2 = st2.cba.checkpoints
    for _ in range(30):
        st2.get_batch(keys[:64])
    assert st2.cba.checkpoints == n2, "checkpoint re-fired after reopen"
    st2.close()


def test_dangling_current_raises_not_empty_store(tmp_path):
    """CURRENT naming a missing manifest must error — replaying it as an
    empty store would sweep every live file as garbage."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    st.put_batch(np.arange(1, 2001, dtype=np.int64))
    st.flush_all()
    st.close()
    mpath = [n for n in os.listdir(d) if n.startswith("MANIFEST-")][0]
    os.rename(os.path.join(d, mpath), os.path.join(d, "stash"))
    with pytest.raises(FileNotFoundError, match="CURRENT"):
        BourbonStore.open(d, small_cfg())
    # nothing was deleted by the failed open; restoring recovers fully
    os.rename(os.path.join(d, "stash"), os.path.join(d, mpath))
    st2 = BourbonStore.open(d, small_cfg())
    f, _ = st2.get_batch(np.arange(1, 2001, dtype=np.int64))
    assert f.all()
    st2.close()


def test_explicit_checkpoint_roundtrip(tmp_path):
    """Engine-level checkpoint: fold, retire, replay equals state."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg(maintenance=MaintenanceConfig(
        auto_gc=False, auto_checkpoint=False)))
    keys = np.arange(1, 4001, dtype=np.int64) * 5
    _overwrite_rounds(st, keys, 2)
    st.flush_all()
    st.gc_value_log(min_dead_ratio=0.3)
    eng = st._storage
    before = dataclasses.replace(eng.state,
                                 live=dict(eng.state.live),
                                 vlog_removed=set(eng.state.vlog_removed),
                                 vlog_dead=dict(eng.state.vlog_dead))
    old_no = eng.manifest.no
    folded = eng.checkpoint()
    assert folded > 0
    assert eng.manifest.no == old_no + 1
    state, no = read_manifest(d)
    assert no == old_no + 1
    assert state == before
    st.close()


# ------------------------------------------------------------- satellites

def test_drain_learning_returns_job_count(tmp_path):
    cfg = small_cfg(mode="bourbon", policy="always",
                    cba=CBAConfig(policy="always", t_wait_us=0.0))
    st = BourbonStore.open(str(tmp_path / "db"), cfg)
    ks = np.arange(1, 8001, dtype=np.int64) * 3
    st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    n_files = st.stats()["n_files"]
    assert n_files > 0
    drained = st.drain_learning()
    assert drained >= n_files - st._models_swept_at or drained > 0
    assert not st.executor.queue and not st.executor.running
    # idempotent: nothing left to drain
    assert st.drain_learning() == 0
    st.close()


def test_drain_learning_raises_instead_of_silent_giveup(tmp_path):
    cfg = small_cfg(mode="bourbon", policy="always",
                    cba=CBAConfig(policy="always", t_wait_us=0.0),
                    costs=CostModel(learn_per_key=1e9))  # jobs ~never finish
    st = BourbonStore.open(str(tmp_path / "db"), cfg)
    ks = np.arange(1, 4001, dtype=np.int64)
    st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    assert st.executor.queue or st.executor.running
    with pytest.raises(RuntimeError, match="outstanding"):
        st.drain_learning(max_us=50_000.0)
    st.close()


def test_engine_mode_not_model_pure_on_empty_tree():
    st = BourbonStore(StoreConfig(mode="bourbon", policy="always"))
    assert not list(st.tree.all_files())
    assert st._engine_mode() == "model"
    # still resolves correctly once files exist
    st.put_batch(np.arange(1, 30001, dtype=np.int64))
    st.flush_all()
    st.learn_all()
    assert st._engine_mode() == "model_pure"


def test_stats_data_bytes_from_dtypes():
    st = BourbonStore(StoreConfig(mode="bourbon", policy="never"))
    st.put_batch(np.arange(1, 30001, dtype=np.int64))
    st.flush_all()
    s = st.stats()
    want = sum(t.n * (t.keys.dtype.itemsize + t.seqs.dtype.itemsize
                      + t.vptrs.dtype.itemsize)
               for t in st.tree.all_files())
    assert s["data_bytes"] == want
    assert want == s["n_records"] * 24      # int64 triple today
