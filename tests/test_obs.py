"""Observability plane: registry/label semantics, exporter round-trips,
stage tracer sampling, the lazy per-level probe-split (no extra blocking
device transfers on the read hot path), counter monotonicity across
epoch events (memtable roll, compaction, store reopen), the per-shard
labeled stats breakdown, and the served-from-cache reconciliation
through ``PipelinedServer`` snapshots."""

import json

import numpy as np
import pytest

from repro.core import LSMConfig, StoreConfig
from repro.core.engine import EngineConfig, LookupResult
from repro.core.lsm import N_LEVELS
from repro.core.store import BourbonStore
from repro.distributed import ShardedConfig, ShardedStore
from repro.obs import (EventLog, MetricsRegistry, NULL_TRACER, Obs,
                       ObsConfig, READ_STAGES, StageTracer, parse_prometheus,
                       publish_stats, to_json, to_prometheus)
from repro.server import (PipelineConfig, PipelinedServer, ServerConfig,
                          ServerRequest)

VALUE_SIZE = 16


def _store_cfg(**kw):
    defaults = dict(granularity="level", policy="always",
                    value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _keys(n, seed=0, stride=7):
    return np.random.default_rng(seed).permutation(
        np.arange(1, n + 1, dtype=np.int64) * stride)


def _sharded(tmp_path, keys, n_shards=2, **kw):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    return ShardedStore.open(str(tmp_path / "db"),
                             ShardedConfig(n_shards=n_shards,
                                           boundaries=bounds),
                             _store_cfg(**kw))


def _values(keys, version=0):
    v = np.zeros((keys.shape[0], VALUE_SIZE), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _fill(store, keys, chunk=1 << 11):
    for off in range(0, keys.shape[0], chunk):
        store.put_batch(keys[off: off + chunk])
    store.flush_all()


def _sample(snap, name, **labels):
    for s in snap[name]["samples"]:
        if dict(s["labels"]) == labels:
            return s["value"]
    raise KeyError((name, labels))


# ------------------------------------------------------------------ registry

def test_registry_instruments_and_label_identity():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", shard="0")
    c.inc()
    c.inc(4)
    # same (name, labels) -> same instrument regardless of kwarg order
    assert reg.counter("reqs_total", shard="0") is c
    assert reg.counter("reqs_total", shard="1") is not c
    g = reg.gauge("depth", shard="0", level="2")
    g.set(7)
    assert reg.gauge("level", **{"level": "2", "shard": "0"}) is not g
    h = reg.histogram("lat_us")
    for x in (0.5, 3.0, 3.0, 1e9):
        h.observe(x)
    assert h.count == 4 and h.max == 1e9 and h.mean == pytest.approx(
        (0.5 + 3.0 + 3.0 + 1e9) / 4)
    assert h.buckets[-1] == 1          # 1e9 us lands in the overflow bucket
    snap = reg.snapshot()
    assert _sample(snap, "reqs_total", shard="0") == 5.0
    assert _sample(snap, "depth", shard="0", level="2") == 7.0
    # kind mismatch on an existing family is an error, not a silent alias
    with pytest.raises(ValueError):
        reg.gauge("reqs_total", shard="0")


def test_counter_observe_total_restart_detection():
    reg = MetricsRegistry()
    c = reg.counter("gets_total")
    c.observe_total(10)
    c.observe_total(25)
    assert c.value == 25
    # a lower total = the source restarted (reopen): its new cumulative
    # count is fresh progress, and the registry counter stays monotonic
    c.observe_total(4)
    assert c.value == 29
    c.observe_total(6)
    assert c.value == 31


def test_delta_counter_rates_and_restart_detection():
    """``MetricsRegistry.delta``: counters report cur-prev per window,
    with the same restart rule as ``observe_total`` — a current value
    below the previous one means the source restarted, so the whole
    current value is the window's progress."""
    reg = MetricsRegistry()
    c = reg.counter("server_gets_total", shard="0")
    g = reg.gauge("server_queued")
    c.inc(10)
    g.set(7)
    prev = reg.snapshot()
    c.inc(5)
    g.set(3)
    d = reg.delta(prev)
    assert _sample(d, "server_gets_total", shard="0") == 5
    assert _sample(d, "server_queued") == 3          # gauges: current
    # restart: simulate by replacing the counter's cumulative value
    prev2 = reg.snapshot()
    c.value = 2.0                                    # restarted source
    d2 = reg.delta(prev2)
    assert _sample(d2, "server_gets_total", shard="0") == 2
    # a sample new in cur counts from zero; prev-only samples are omitted
    reg.counter("server_puts_total").inc(4)
    d3 = reg.delta(prev2)
    assert _sample(d3, "server_puts_total") == 4
    assert all(n in reg.snapshot() for n in d3)


def test_delta_histogram_bucket_deltas_and_restart():
    reg = MetricsRegistry()
    h = reg.histogram("server_stage_us", stage="dispatch")
    h.observe(3.0)
    h.observe(100.0)
    prev = reg.snapshot()
    h.observe(100.0)
    d = reg.delta(prev)
    v = _sample(d, "server_stage_us", stage="dispatch")
    assert v["count"] == 1 and v["sum"] == 100.0
    assert sum(v["buckets"]) == 1                    # one new observation
    assert v["max"] == 100.0                         # current max, not rate
    assert "exemplars" not in v                      # not a rate: dropped
    # histogram restart rule keys on count going backwards
    h2 = reg.histogram("server_stage_us", stage="dispatch")
    assert h2 is h
    prev2 = reg.snapshot()
    h.count = 1
    h.sum = 50.0
    h.buckets = [0] * len(h.buckets)
    h.buckets[0] = 1
    d2 = reg.delta(prev2)
    v2 = _sample(d2, "server_stage_us", stage="dispatch")
    assert v2["count"] == 1 and v2["sum"] == 50.0    # whole cur is fresh


def test_collector_keyed_replacement():
    reg = MetricsRegistry()
    reg.register_collector("src", lambda r: r.counter("a").observe_total(5))
    reg.snapshot()
    # same key replaces: the stale collector must not double-report
    reg.register_collector("src", lambda r: r.counter("a").observe_total(2))
    snap = reg.snapshot()
    assert _sample(snap, "a") == 7.0   # 5, then restart-to-2
    reg.unregister_collector("src")
    assert _sample(reg.snapshot(), "a") == 7.0


# ----------------------------------------------------------------- exporters

def _demo_registry():
    reg = MetricsRegistry()
    reg.counter("ops_total", shard="0").inc(3)
    reg.counter("ops_total", shard="1").inc(5)
    reg.gauge("depth").set(2.5)
    h = reg.histogram("stage_us", stage='tricky"name\\')
    h.observe(3.0)
    h.observe(900.0)
    publish_stats(reg, "layer", {
        "num": 7, "flag": True, "skipme": "a string", "none": None,
        "sub": {"x": 1.5}, "by_level": {0: 10, 2: 30},
        "per_shard_us": [1.0, 2.0],
    })
    return reg


def test_json_snapshot_round_trips_exactly():
    snap = _demo_registry().snapshot()
    assert json.loads(to_json(snap)) == snap


def test_publish_stats_flatten_semantics():
    snap = _demo_registry().snapshot()
    assert _sample(snap, "layer_num") == 7.0
    assert _sample(snap, "layer_flag") == 1.0
    assert _sample(snap, "layer_sub_x") == 1.5
    assert _sample(snap, "layer_by_level", key="2") == 30.0
    assert _sample(snap, "layer_per_shard_us", index="1") == 2.0
    assert "layer_skipme" not in snap and "layer_none" not in snap


def test_prometheus_export_parses_back():
    reg = _demo_registry()
    snap = reg.snapshot()
    back = parse_prometheus(to_prometheus(snap))
    assert back[("ops_total", (("shard", "0"),))] == 3.0
    assert back[("ops_total", (("shard", "1"),))] == 5.0
    assert back[("depth", ())] == 2.5
    assert back[("layer_by_level", (("key", "2"),))] == 30.0
    # histogram expansion: escaped label value, cumulative buckets, sum
    lbl = (("stage", 'tricky"name\\'),)
    assert back[("stage_us_count", lbl)] == 2.0
    assert back[("stage_us_sum", lbl)] == 903.0
    assert back[("stage_us_max", lbl)] == 900.0
    inf_key = ("stage_us_bucket", (("le", "+Inf"),) + lbl)
    inf_key = ("stage_us_bucket", tuple(sorted((("le", "+Inf"),) + lbl)))
    assert back[inf_key] == 2.0


# -------------------------------------------------------------------- tracer

def test_tracer_sampling_and_timeline():
    reg = MetricsRegistry()
    tr = StageTracer(reg, sample_every=2, timeline_ticks=4)
    h = tr.stage("work")
    assert tr.stage("work") is h        # pre-bound: get-or-create
    for i in range(6):
        tick = tr.begin_tick()
        t0 = h.begin()
        if i % 2 == 0:
            assert t0 > 0.0             # armed tick
        else:
            assert t0 == 0.0            # unsampled: end() must no-op
        h.end(t0)
        tr.end_tick(tick)
    assert tr.ticks_seen == 6 and tr.sampled_ticks == 3
    assert h.count == 3
    tl = tr.timeline()
    assert len(tl) == 3 and all("work" in row for row in tl)
    assert [row["tick"] for row in tl] == [0, 2, 4]
    assert h.hist.count == 3            # histogram fed only when sampled


def test_null_tracer_is_inert():
    h = NULL_TRACER.stage("anything")
    t = NULL_TRACER.begin_tick()
    assert h.begin() == 0.0
    h.end(0.0)
    NULL_TRACER.end_tick(t)
    assert NULL_TRACER.timeline() == []


def test_event_log_bounded():
    ev = EventLog(cap=3)
    for i in range(5):
        ev.log("learn", level=i)
    assert ev.total == 5 and len(ev) == 3
    assert [e["level"] for e in ev.tail()] == [2, 3, 4]
    assert ev.tail(1)[0]["kind"] == "learn"


# ----------------------------------------------------- store instrumentation

def test_store_snapshot_covers_stats_and_events():
    st = BourbonStore(_store_cfg())
    obs = Obs(ObsConfig(sample_every=1))
    st.attach_obs(obs, labels={"shard": "0"})
    keys = _keys(6000, seed=3)
    _fill(st, keys)
    st.learn_all()
    f, _ = st.get_batch(keys[:256])
    assert f.all()
    snap = obs.snapshot()
    s = st.stats()
    lb = {"shard": "0"}
    assert _sample(snap, "store_gets_total", **lb) == s["n_gets"]
    assert _sample(snap, "store_puts_total", **lb) == s["n_puts"]
    assert _sample(snap, "store_n_records", **lb) == s["n_records"]
    assert _sample(snap, "store_files_learned_total",
                   **lb) == s["files_learned"]
    # per-level gauges agree with the tree
    for li, tables in enumerate(st.tree.levels):
        assert _sample(snap, "store_level_files", level=str(li),
                       **lb) == len(tables)
    # the maintenance event log saw the learning decisions (with their
    # CBA cost estimates attached)
    kinds = {e["kind"] for e in obs.events.tail()}
    assert "learn" in kinds
    assert all("cost_us" in e for e in obs.events.tail()
               if e["kind"] == "learn")


def test_probe_split_no_extra_blocking_transfers():
    """Satellite: per-level model/baseline probe counts must ride the
    lazy-materialization pattern — obs-on adds ZERO host syncs per batch
    (one device add only), and the accumulator syncs once per snapshot."""
    keys = _keys(6000, seed=4)

    def run(with_obs):
        st = BourbonStore(_store_cfg())
        obs = Obs() if with_obs else None
        if with_obs:
            st.attach_obs(obs)
        _fill(st, keys)
        st.learn_all()
        base = LookupResult.n_materializations
        for off in range(0, 2048, 256):
            f, _ = st.get_batch(keys[off: off + 256])
            assert f.all()
        return st, obs, LookupResult.n_materializations - base

    st_off, _, mat_off = run(False)
    st_on, obs, mat_on = run(True)
    # identical number of result materializations: the probe split never
    # forces an extra device->host sync on the read path
    assert mat_on == mat_off
    assert st_on.engine.probe_acc_materializations == 0
    snap = obs.snapshot()                  # first (and only) sync happens here
    assert st_on.engine.probe_acc_materializations == 1
    mp = sum(_sample(snap, "engine_probes_total", level=str(li), path="model")
             for li in range(N_LEVELS))
    bp = sum(_sample(snap, "engine_probes_total", level=str(li),
                     path="baseline") for li in range(N_LEVELS))
    assert mp == st_on.lookups_model_path
    assert bp == st_on.lookups_baseline_path
    assert mp + bp > 0


def test_probe_split_paths_by_mode():
    """wisckey mode attributes every probe to the baseline path; a fully
    learned bourbon store attributes every probe to the model path."""
    keys = _keys(6000, seed=5)
    for mode, want_path in (("wisckey", "baseline"), ("bourbon", "model")):
        st = BourbonStore(_store_cfg(mode=mode))
        obs = Obs()
        st.attach_obs(obs)
        _fill(st, keys)
        if mode == "bourbon":
            st.learn_all()
        st.get_batch(keys[:512])
        snap = obs.snapshot()
        other = "model" if want_path == "baseline" else "baseline"
        want = sum(_sample(snap, "engine_probes_total", level=str(li),
                           path=want_path) for li in range(N_LEVELS))
        got_other = sum(_sample(snap, "engine_probes_total", level=str(li),
                                path=other) for li in range(N_LEVELS))
        assert want > 0 and got_other == 0, mode


# ----------------------------------------------- counters across epoch events

def test_counters_monotonic_across_roll_and_compaction():
    st = BourbonStore(_store_cfg())
    obs = Obs()
    st.attach_obs(obs)
    keys = _keys(8000, seed=6)
    prev = {}
    for off in range(0, keys.shape[0], 1 << 10):   # many memtable rolls
        st.put_batch(keys[off: off + (1 << 10)])
        st.get_batch(keys[max(0, off - 256): max(256, off)])
        snap = obs.snapshot()
        for name in ("store_gets_total", "store_puts_total",
                     "store_files_learned_total"):
            cur = _sample(snap, name)
            assert cur >= prev.get(name, 0.0), name
            prev[name] = cur
    assert prev["store_puts_total"] == keys.shape[0]


def test_counters_survive_store_reopen(tmp_path):
    keys = _keys(4000, seed=7)
    obs = Obs()
    st = BourbonStore.open(tmp_path / "db", _store_cfg())
    st.attach_obs(obs)
    _fill(st, keys)
    st.get_batch(keys[:512])
    x = _sample(obs.snapshot(), "store_gets_total")
    assert x == 512
    st.close()
    # reopen: the new instance counts n_gets from zero, and its collector
    # REPLACES the old one (same key) — totals keep accumulating
    st = BourbonStore.open(tmp_path / "db", _store_cfg())
    st.attach_obs(obs)
    st.get_batch(keys[:256])
    snap = obs.snapshot()
    assert _sample(snap, "store_gets_total") == 512 + 256
    # records gauge reflects the recovered store, not a stale double
    assert _sample(snap, "store_n_records") == st.stats()["n_records"]
    st.close()


# ------------------------------------------------------------- sharded store

def test_sharded_stats_per_shard_breakdown(tmp_path):
    keys = _keys(8000, seed=8)
    st = _sharded(tmp_path, keys, n_shards=2)
    _fill(st, keys, chunk=1 << 10)
    st.get_batch(keys[:256])
    s = st.stats()
    ps = s["per_shard"]
    assert sorted(ps) == ["shard-0", "shard-1"]
    for field in ("n_records", "n_files", "files_learned", "gc_us",
                  "checkpoint_us", "vlog_disk_bytes",
                  "manifest_checkpoints"):
        assert sum(p[field] for p in ps.values()) == s[
            {"checkpoint_us": "checkpoint_us"}.get(field, field)], field
    assert sum(p["auto_gc"]["runs"] for p in ps.values()) == \
        s["auto_gc"]["runs"]
    # both shards actually hold data (the split is by quantile)
    assert all(p["n_records"] > 0 for p in ps.values())
    assert all(p["epoch"] >= 1 for p in ps.values())
    st.close()


def test_sharded_attach_obs_labels_and_fleet_aggregate(tmp_path):
    keys = _keys(6000, seed=9)
    st = _sharded(tmp_path, keys, n_shards=2)
    obs = Obs()
    st.attach_obs(obs)
    _fill(st, keys, chunk=1 << 10)
    st.get_batch(keys[:128])
    snap = obs.snapshot()
    shards = {dict(s["labels"])["shard"]
              for s in snap["store_n_records"]["samples"]}
    assert shards == {"0", "1"}
    agg = st.stats()
    assert _sample(snap, "fleet_n_records") == agg["n_records"]
    assert _sample(snap, "fleet_gets_total") == agg["n_gets"]
    per = sum(_sample(snap, "store_n_records", shard=s) for s in ("0", "1"))
    assert per == agg["n_records"]
    st.detach_obs()
    assert st.shards[0].engine.record_probe_split is False
    st.close()


# ------------------------------------------------------------------- servers

def _serve_reads(srv, keys, rounds=6, per_req=32, rid0=10_000):
    rng = np.random.default_rng(11)
    rid = rid0
    reqs = []
    for _ in range(rounds):
        for _ in range(8):
            r = ServerRequest(rid, "get", rng.choice(keys, per_req))
            assert srv.submit(r)
            reqs.append(r)
            rid += 1
        srv.tick()
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    return reqs


def test_pipelined_server_snapshot_completeness(tmp_path):
    """Acceptance: one snapshot carries every layered stats() metric with
    per-level and per-shard labels, all read-path stages have sampled
    observations, and both exporters round-trip it."""
    keys = _keys(6000, seed=10)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True)
    srv = PipelinedServer(st, PipelineConfig(
        max_wait_ticks=0, obs=ObsConfig(sample_every=1)))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks)))
        rid += 1
        srv.run_until_drained()
    _serve_reads(srv, keys)
    snap = srv.obs.snapshot()
    s = srv.stats()
    # every stage observed
    stages = {dict(x["labels"])["stage"]: x["value"]["count"]
              for x in snap["server_stage_us"]["samples"]}
    assert all(stages.get(name, 0) > 0 for name in READ_STAGES), stages
    # server layer
    assert _sample(snap, "server_completed_total") == s["completed"]
    assert _sample(snap, "server_submitted_total") == s["submitted"]
    assert _sample(snap, "server_batches_total") == s["batches"]
    assert _sample(snap, "server_queued") == s["queued"]
    # pipeline layer
    for k in ("dispatched", "retired", "write_barriers", "bubbles",
              "epoch_violations", "max_depth_seen"):
        assert _sample(snap, f"server_pipeline_{k}") == s["pipeline"][k], k
    # cache layer
    assert _sample(snap, "cache_hits_total") == s["cache"]["hits"]
    assert _sample(snap, "server_cache_hit_rate") == s["cache"]["hit_rate"]
    # coordinator layer (per-shard lists become index= labels)
    assert _sample(snap, "server_coordinator_runs") == \
        s["coordinator"]["runs"]
    assert "server_coordinator_per_shard_us" in snap
    # store/fleet layer with shard labels
    assert _sample(snap, "fleet_n_records") == s["store"]["n_records"]
    assert {dict(x["labels"])["shard"]
            for x in snap["store_gets_total"]["samples"]} == {"0", "1"}
    # per-level labels
    assert {dict(x["labels"])["level"]
            for x in snap["store_level_files"]["samples"]} \
        >= {str(i) for i in range(N_LEVELS)}
    # exporters round-trip the whole thing
    assert json.loads(to_json(snap)) == snap
    back = parse_prometheus(to_prometheus(snap))
    assert back[("server_completed_total", ())] == s["completed"]
    assert back[("fleet_n_records", ())] == s["store"]["n_records"]
    st.close()


def test_cache_counters_reconcile_with_served_totals(tmp_path):
    keys = _keys(4000, seed=12)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True)
    srv = PipelinedServer(st, PipelineConfig(
        max_wait_ticks=0, obs=ObsConfig(sample_every=1)))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks)))
        rid += 1
        srv.run_until_drained()
    hot = keys[:64]
    for _ in range(4):                     # repeated hot reads: cache hits
        _serve_reads(srv, hot, rounds=2, per_req=16, rid0=rid)
        rid += 1000
    snap = srv.obs.snapshot()
    s = srv.stats()
    assert s["served_from_cache"] > 0
    # the server's served-from-cache total IS the cache's hit counter —
    # both through stats() and through the registry
    assert s["served_from_cache"] == s["cache"]["hits"]
    assert _sample(snap, "cache_hits_total") == s["cache"]["hits"]
    assert _sample(snap, "server_served_from_cache_total") == \
        s["served_from_cache"]
    # every key either came from the cache or probed the store
    assert _sample(snap, "server_served_from_cache_total") + \
        _sample(snap, "server_store_probe_keys_total") == \
        s["served_from_cache"] + s["store_probe_keys"]
    # write invalidations show up and reconcile too
    ks = hot[:32]
    assert srv.submit(ServerRequest(rid, "put", ks, _values(ks, 1)))
    srv.run_until_drained()
    snap2 = srv.obs.snapshot()
    assert _sample(snap2, "cache_inval_write_total") == \
        srv.cache.stats()["inval_write"]
    st.close()


def test_obs_disabled_server_serves_and_is_uninstrumented(tmp_path):
    keys = _keys(3000, seed=13)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True)
    # attach-then-disable: constructing the obs-off server must detach
    # the previous plane (clean obs-off bench arm)
    st.attach_obs(Obs())
    srv = PipelinedServer(st, PipelineConfig(
        max_wait_ticks=0, obs=ObsConfig(enabled=False)))
    assert srv.obs is None
    assert st.shards[0].engine.record_probe_split is False
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks)))
        rid += 1
        srv.run_until_drained()
    reqs = _serve_reads(srv, keys, rounds=3)
    assert all(r.found.all() for r in reqs)
    st.close()


def test_sync_server_snapshot_has_stages(tmp_path):
    keys = _keys(3000, seed=14)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True)
    from repro.server import BourbonServer
    srv = BourbonServer(st, ServerConfig(
        max_wait_ticks=0, obs=ObsConfig(sample_every=1)))
    rid = 0
    for off in range(0, keys.shape[0], 500):
        ks = keys[off: off + 500]
        assert srv.submit(ServerRequest(rid, "put", ks, _values(ks)))
        rid += 1
        srv.run_until_drained()
    _serve_reads(srv, keys, rounds=3)
    snap = srv.obs.snapshot()
    stages = {dict(x["labels"])["stage"]: x["value"]["count"]
              for x in snap["server_stage_us"]["samples"]}
    assert all(stages.get(name, 0) > 0 for name in READ_STAGES), stages
    tl = srv.obs.timeline()
    assert tl and all("tick" in row for row in tl)
    st.close()
