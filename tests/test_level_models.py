"""Level-granularity model persistence (§4.3 + the LearnedKV storage-
coupling argument): MANIFEST ``lmodel`` records + ``lm-*.plm`` sidecars,
reopen serving the model path with an empty learn queue, torn-edit
fallback to relearning, and the epoch-keyed engine cache."""

import os

import numpy as np
import pytest

from repro.core import BourbonStore, LSMConfig, StoreConfig
from repro.core.engine import EngineConfig, LookupEngine
from repro.core.lsm import LSMTree, N_LEVELS
from repro.core.plr import greedy_plr_np
from repro.core.sstable import build_sstable


def level_cfg(**kw):
    defaults = dict(granularity="level", policy="always", value_size=16,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _load(st: BourbonStore, keys: np.ndarray) -> None:
    for off in range(0, keys.shape[0], 4096):
        st.put_batch(keys[off: off + 4096])
    st.flush_all()


# ----------------------------------------------------------- manifest schema

def test_manifest_lmodel_record_and_invalidation():
    from repro.storage import ManifestState, checkpoint_edit

    state = ManifestState(live={})
    state.apply({"add": [[1, 2]]})
    state.apply({"lmodel": {"2": 5}})
    assert state.level_models == {2: 5}
    # any structural change at the level drops its record
    state.apply({"add": [[3, 2]]})
    assert state.level_models == {}
    state.apply({"lmodel": {"2": 6}})
    state.apply({"del": [1]})          # fid 1 lives at level 2
    assert state.level_models == {}
    # one edit carrying both: invalidation first, then the new record
    state.apply({"lmodel": {"2": 7}, "add": [[9, 3]]})
    assert state.level_models == {2: 7}
    # a checkpoint edit replays to the identical state from scratch
    replayed = ManifestState(live={})
    replayed.apply(checkpoint_edit(state))
    assert replayed.level_models == {2: 7}
    assert replayed.live == state.live


def test_level_model_sidecar_roundtrip(tmp_path):
    from repro.storage import load_level_model, write_level_model

    keys = np.cumsum(np.random.default_rng(0).integers(1, 9, 5000))
    m = greedy_plr_np(keys, delta=8)
    path = str(tmp_path / "lm-1-000003.plm")
    write_level_model(path, m)
    r = load_level_model(path)
    assert int(r.n_segments) == int(m.n_segments)
    np.testing.assert_allclose(np.asarray(r.slopes),
                               np.asarray(m.slopes)[:int(m.n_segments)])
    # torn sidecar: never an error, always "relearn"
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    assert load_level_model(path) is None
    assert load_level_model(str(tmp_path / "missing.plm")) is None


# ---------------------------------------------------------------- round trip

def test_reopen_serves_level_models_with_empty_learn_queue(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, level_cfg())
    keys = np.random.default_rng(2).permutation(
        np.arange(1, 20001, dtype=np.int64) * 3)
    _load(st, keys)
    st.learn_all()     # level models + L0 file models, all persisted
    st.close()

    st2 = BourbonStore.open(d, level_cfg())
    # the whole point: nothing queued, nothing running, nothing relearned
    assert not st2.executor.queue and not st2.executor.running
    s = st2.stats()
    assert s["level_models_recovered"] >= 1
    assert s["files_learned"] == 0
    nonempty = [i for i in range(1, N_LEVELS) if st2.tree.levels[i]]
    assert nonempty
    assert all(st2.level_models[i] is not None for i in nonempty)
    # first GET is model-pure: every lookup takes the model path and no
    # learning job ever entered the pipeline
    f, _ = st2.get_batch(keys[:4096])
    assert f.all()
    miss, _ = st2.get_batch(keys[:4096] + 1)
    assert not miss.any()
    assert st2.executor.jobs_done == 0
    assert st2.lookups_baseline_path == 0
    assert st2.lookups_model_path > 0
    st2.close()


def test_async_fit_level_models_persist_across_crash(tmp_path):
    """Models fit by the executor (not learn_all) are swept into the
    MANIFEST by _tick; a hard crash afterwards must not lose them."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, level_cfg())
    keys = np.random.default_rng(3).permutation(
        np.arange(1, 16001, dtype=np.int64) * 5)
    _load(st, keys)
    st.drain_learning()
    fitted = [i for i in range(1, N_LEVELS)
              if st.level_models[i] is not None]
    assert fitted
    del st  # crash: no close

    st2 = BourbonStore.open(d, level_cfg())
    assert all(st2.level_models[i] is not None for i in fitted)
    assert st2.stats()["level_models_recovered"] >= len(fitted)
    assert not st2.executor.queue and not st2.executor.running
    f, _ = st2.get_batch(keys[:4096])
    assert f.all()
    assert st2.executor.jobs_done == 0
    st2.close()


# ------------------------------------------------------------ torn recovery

def test_torn_lmodel_manifest_edit_falls_back_to_relearning(tmp_path):
    """learn_all's lmodel edits are the manifest tail after a crash;
    tearing the last frame must drop (only) that level's model and
    resubmit its learning job on reopen."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, level_cfg())
    keys = np.random.default_rng(4).permutation(
        np.arange(1, 20001, dtype=np.int64) * 3)
    _load(st, keys)
    st.learn_all()
    nonempty = [i for i in range(1, N_LEVELS) if st.tree.levels[i]]
    del st  # crash

    mpath = [os.path.join(d, n) for n in os.listdir(d)
             if n.startswith("MANIFEST")][0]
    with open(mpath, "r+b") as f:      # tear the trailing lmodel frame
        f.truncate(os.path.getsize(mpath) - 3)

    st2 = BourbonStore.open(d, level_cfg())
    # the torn level relearns; reads stay correct before and after
    missing = [i for i in nonempty if st2.level_models[i] is None]
    assert missing
    assert {j.level for j in st2.executor.queue
            if j.is_level} >= set(missing)
    f, _ = st2.get_batch(keys[:4096])
    assert f.all()
    st2.drain_learning()
    assert all(st2.level_models[i] is not None for i in nonempty)
    f, _ = st2.get_batch(keys[4096:8192])
    assert f.all()
    st2.close()


def test_torn_lmodel_sidecar_falls_back_to_relearning(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, level_cfg())
    keys = np.random.default_rng(5).permutation(
        np.arange(1, 20001, dtype=np.int64) * 7)
    _load(st, keys)
    st.learn_all()
    st.close()
    sidecars = sorted(n for n in os.listdir(d) if n.endswith(".plm"))
    assert sidecars
    victim = os.path.join(d, sidecars[0])
    with open(victim, "r+b") as f:     # torn write: half the model block
        f.truncate(os.path.getsize(victim) // 2)
    torn_level = int(sidecars[0].split("-")[1])

    st2 = BourbonStore.open(d, level_cfg())
    assert st2.level_models[torn_level] is None
    assert any(j.level == torn_level for j in st2.executor.queue
               if j.is_level)
    f, _ = st2.get_batch(keys[:4096])
    assert f.all()
    st2.drain_learning()
    assert st2.level_models[torn_level] is not None
    st2.close()


def test_structure_change_invalidates_persisted_level_model(tmp_path):
    """A flush/compaction after the lmodel edit must drop the record (and
    sweep the sidecar) so the next reopen relearns instead of serving a
    model fit over a different file set."""
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, level_cfg())
    keys = np.random.default_rng(6).permutation(
        np.arange(1, 20001, dtype=np.int64) * 9)
    _load(st, keys[:16000])
    st.learn_all()
    changed_before = set(st._lm_persisted)
    _load(st, keys[16000:])            # structural change -> invalidation
    st.close()

    st2 = BourbonStore.open(d, level_cfg())
    s = st2.stats()
    # whatever levels the second load touched lost their persisted models
    touched = changed_before - set(st2._lm_persisted)
    assert touched
    for i in touched:
        assert st2.level_models[i] is None
    st2.drain_learning()
    f, _ = st2.get_batch(keys[:8192])
    assert f.all()
    st2.close()


# ------------------------------------------------------------- engine cache

def test_engine_level_model_cache_keyed_on_epoch():
    """Same level version + different model object must rebuild the
    cached LevelModel — (ver, id(model)) could collide after GC reuses
    the address; the monotonic epoch cannot."""
    tree = LSMTree(LSMConfig())
    rng = np.random.default_rng(7)
    keys = np.cumsum(rng.integers(1, 50, 4096)).astype(np.int64)
    n = keys.shape[0]
    t = build_sstable(keys, np.arange(n, dtype=np.int64),
                      np.arange(n, dtype=np.int64), 1, 0.0)
    tree.levels[1] = [t]
    eng = LookupEngine(EngineConfig())
    lms = [None] * N_LEVELS
    m1 = greedy_plr_np(keys, delta=8)
    m1.epoch = 0
    lms[1] = m1
    s1 = eng.build_state(tree, lms)
    assert int(s1.level_models[1].nseg) == int(m1.n_segments)
    # swap in a different model at the same level version
    m2 = greedy_plr_np(keys[: n // 8], delta=8)
    m2.epoch = 1
    lms[1] = m2
    s2 = eng.build_state(tree, lms)
    assert int(s2.level_models[1].nseg) == int(m2.n_segments)
    assert int(s2.level_models[1].nseg) != int(m1.n_segments)
    # unstamped models get engine-assigned unique (negative) epochs
    m3 = greedy_plr_np(keys[: n // 2], delta=8)
    lms[1] = m3
    s3 = eng.build_state(tree, lms)
    assert int(s3.level_models[1].nseg) == int(m3.n_segments)
    assert m3.epoch < -1
    # the same object is a cache hit (no rebuild)
    s4 = eng.build_state(tree, lms)
    assert s4.level_models[1] is s3.level_models[1]
