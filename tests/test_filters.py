"""Filter plane: stacked bloom-probe kernel parity, zero-false-negative
property, CBA sizing, MANIFEST ``filter`` records + ``flt-*.bf`` sidecars
(reopen-no-rebuild, torn-sidecar fallback), and filtered-vs-unfiltered
GET identity on mixed hit/miss batches."""

import os

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as hst
except ImportError:
    from _hypothesis_shim import given, settings, st as hst

from repro.core import BourbonStore, LSMConfig, StoreConfig
from repro.core.bloom import bloom_build_np, bloom_probe_np, bloom_words
from repro.core.engine import EngineConfig
from repro.core.filters import (FilterConfig, build_level_filter,
                                filter_maybe_np)
from repro.core.lsm import N_LEVELS
from repro.kernels import ops
from repro.kernels import ref as kref


def small_cfg(**kw):
    defaults = dict(value_size=16,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _load(st: BourbonStore, keys: np.ndarray) -> None:
    for off in range(0, keys.shape[0], 4096):
        st.put_batch(keys[off: off + 4096])
    st.flush_all()


def _stack(rng, n_levels=3, n_keys=2000, bpk=10, k=7):
    """Build a padded (L, W) filter stack + the per-level key sets."""
    key_sets, filters = [], []
    for li in range(n_levels):
        ks = np.unique(rng.integers(0, 1 << 40, n_keys * (li + 1)))
        key_sets.append(ks)
        filters.append(build_level_filter(ks, bpk, k))
    W = max(64, 1 << (max(f.n_words for f in filters) - 1).bit_length())
    bits = np.zeros((n_levels, W), np.uint64)
    nw = np.zeros(n_levels, np.int32)
    for li, f in enumerate(filters):
        bits[li, : f.n_words] = f.bits
        nw[li] = f.n_words
    return key_sets, filters, bits, nw


# ------------------------------------------------------------------ kernels

@pytest.mark.parametrize("B", [64, 100, 256, 300, 1000])
@pytest.mark.parametrize("k", [4, 7])
def test_bloom_stack_kernel_parity(B, k):
    """Pallas interpret-mode stack probe == jnp oracle == per-level host
    probe, including non-power-of-two batches the wrapper must pad."""
    rng = np.random.default_rng(B + k)
    key_sets, filters, bits, nw = _stack(rng, k=k)
    probes = np.concatenate([key_sets[0][:B // 2],
                             rng.integers(0, 1 << 40, B - B // 2)])
    want = np.stack([bloom_probe_np(f.bits, probes, k, n_words=f.n_words)
                     for f in filters])
    ref = np.asarray(kref.bloom_probe_stack_ref(
        jnp.asarray(bits), jnp.asarray(nw), jnp.asarray(probes), k))
    pal = np.asarray(ops.bloom_probe_stack(
        jnp.asarray(bits), jnp.asarray(nw), jnp.asarray(probes),
        k_hashes=k, impl="pallas_interpret"))
    np.testing.assert_array_equal(ref, want)
    np.testing.assert_array_equal(pal, want)


def test_bloom_stack_kernel_empty_row_is_all_maybe():
    """nw == 0 marks a level without a filter: its row must be all-True
    (pruning on it would drop real keys)."""
    rng = np.random.default_rng(0)
    _, _, bits, nw = _stack(rng, n_levels=3)
    nw[1] = 0
    bits[1] = 0
    probes = rng.integers(0, 1 << 40, 128)
    for impl in ("ref", "pallas_interpret"):
        out = np.asarray(ops.bloom_probe_stack(
            jnp.asarray(bits), jnp.asarray(nw), jnp.asarray(probes),
            k_hashes=7, impl=impl))
        assert out[1].all()


@pytest.mark.parametrize("B", [60, 100, 257, 500])
def test_bloom_probe_pallas_pads_ragged_batches(B):
    """Regression: bloom_probe_pallas asserted B % block_b == 0; it must
    pad internally and slice the result instead."""
    rng = np.random.default_rng(B)
    keys = np.unique(rng.integers(0, 1 << 40, 4000))
    W = bloom_words(keys.shape[0])
    bits = jnp.asarray(bloom_build_np(keys, W, 7))
    probes = jnp.asarray(rng.integers(0, 1 << 40, B))
    want = np.asarray(kref.bloom_probe_kernel_ref(bits, probes, 7,
                                                  jnp.int32(W)))
    got = np.asarray(ops.bloom_probe(bits, probes, W, k_hashes=7,
                                     impl="pallas_interpret"))
    assert got.shape == (B,)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(hst.integers(0, 2**31), hst.integers(16, 400), hst.integers(6, 14))
def test_filter_zero_false_negatives_property(seed, n, bpk):
    """Every inserted key must pass its filter — host probe AND stacked
    kernel agree (a false negative would lose a real read)."""
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(-(1 << 50), 1 << 50, n))
    f = build_level_filter(keys, bpk, 7)
    assert f.maybe(keys).all()
    bits = jnp.asarray(f.bits[None, :])
    nw = jnp.asarray(np.array([f.n_words], np.int32))
    out = np.asarray(ops.bloom_probe_stack(bits, nw, jnp.asarray(keys),
                                           k_hashes=7, impl="pallas_interpret"))
    assert out[0].all()


def test_filter_maybe_np_empty_and_missing_levels():
    keys = np.arange(0, 1000, dtype=np.int64) * 3
    f = build_level_filter(keys, 10, 7)
    m = filter_maybe_np([f, None], keys[:16])
    assert m[0].all() and m[1].all()      # None level never prunes
    absent = keys[:16] + 1
    m2 = filter_maybe_np([f], absent)
    assert not m2[0].any() or m2[0].sum() < 4   # ~1% FPR at 10 bpk


# --------------------------------------------------------------- CBA sizing

def test_cba_filter_sizing_bounds_and_bootstrap():
    from repro.core.cba import CBAConfig, MaintenanceScheduler
    from repro.core.clock import CostModel

    sch = MaintenanceScheduler(CBAConfig(), CostModel())
    # no stats yet: bootstrap at the base sizing
    assert sch.filter_bits_per_key(1, 10_000, 10, 6, 16, 7) == 10
    assert sch.filter_decisions["bootstrap"] == 1
    # fpr is monotone decreasing in bits-per-key with fixed k
    fprs = [sch.filter_fpr(b, 7) for b in range(6, 17)]
    assert all(x > y for x, y in zip(fprs, fprs[1:]))
    assert 0.005 < sch.filter_fpr(10, 7) < 0.015


# ------------------------------------------------------------------ durable

def test_manifest_filter_record_and_invalidation():
    from repro.storage import ManifestState, checkpoint_edit

    state = ManifestState(live={})
    state.apply({"add": [[1, 2]]})
    state.apply({"filter": {"2": 5}})
    assert state.filters == {2: 5}
    # any structural change at the level drops its record
    state.apply({"add": [[3, 2]]})
    assert state.filters == {}
    state.apply({"filter": {"2": 6}})
    state.apply({"del": [1]})          # fid 1 lives at level 2
    assert state.filters == {}
    state.apply({"filter": {"2": 7}, "add": [[9, 3]]})
    assert state.filters == {2: 7}
    replayed = ManifestState(live={})
    replayed.apply(checkpoint_edit(state))
    assert replayed.filters == {2: 7}


def test_filter_sidecar_roundtrip_and_torn_fallback(tmp_path):
    from repro.storage import load_level_filter, write_level_filter

    keys = np.unique(np.random.default_rng(0).integers(0, 1 << 40, 5000))
    f = build_level_filter(keys, 12, 7)
    path = str(tmp_path / "flt-1-000003.bf")
    write_level_filter(path, f)
    r = load_level_filter(path)
    assert (r.n_words, r.k_hashes, r.bits_per_key, r.n_keys) == \
        (f.n_words, f.k_hashes, f.bits_per_key, f.n_keys)
    np.testing.assert_array_equal(r.bits, f.bits)
    assert r.maybe(keys).all()
    # torn sidecar: never an error, always "rebuild"
    with open(path, "r+b") as fh:
        fh.truncate(os.path.getsize(path) // 2)
    assert load_level_filter(path) is None
    assert load_level_filter(str(tmp_path / "missing.bf")) is None


def test_reopen_serves_filters_without_rebuild(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    keys = np.random.default_rng(1).permutation(
        np.arange(1, 12001, dtype=np.int64) * 5)
    _load(st, keys)
    f, _ = st.get_batch(keys[:512])           # builds + uses filters
    assert f.all()
    built = st.stats()["filters_built"]
    assert built > 0
    assert st.stats()["filters_persisted"]    # swept into the MANIFEST
    st.close()

    st2 = BourbonStore.open(d, small_cfg())
    assert st2.stats()["filters_recovered"] > 0
    miss, _ = st2.get_batch(keys[:512] + 1)   # filtered path, zero rebuild
    assert not miss.any()
    assert st2.stats()["filters_built"] == 0
    assert st2.stats()["filter_screened"] > 0
    hit, _ = st2.get_batch(keys[:512])
    assert hit.all()
    st2.close()


def test_torn_filter_sidecar_rebuilds_lazily(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    keys = np.random.default_rng(2).permutation(
        np.arange(1, 12001, dtype=np.int64) * 3)
    _load(st, keys)
    st.get_batch(keys[:256])
    st.close()

    torn = [n for n in os.listdir(d) if n.startswith("flt-")]
    assert torn
    for name in torn:
        with open(os.path.join(d, name), "r+b") as fh:
            fh.truncate(8)

    st2 = BourbonStore.open(d, small_cfg())
    assert st2.stats()["filters_recovered"] == 0
    f, _ = st2.get_batch(keys[:512])          # lazy rebuild, reads intact
    assert f.all()
    assert st2.stats()["filters_built"] > 0
    miss, _ = st2.get_batch(keys[:512] + 1)
    assert not miss.any()
    st2.close()


def test_structure_change_invalidates_filters(tmp_path):
    d = str(tmp_path / "db")
    st = BourbonStore.open(d, small_cfg())
    keys = np.random.default_rng(3).permutation(
        np.arange(1, 12001, dtype=np.int64) * 7)
    _load(st, keys)
    st.get_batch(keys[:256])
    ver0 = list(st._filter_versions)
    # more writes force flush/compaction: the touched levels' filters are
    # invalidated and rebuilt with the new key sets
    more = keys[:6000] + 1
    _load(st, more)
    f, _ = st.get_batch(np.concatenate([keys[:256], more[:256]]))
    assert f.all()
    assert list(st._filter_versions) != ver0
    st.close()


# ----------------------------------------------------------------- identity

def test_filtered_vs_unfiltered_results_identical():
    keys = np.random.default_rng(4).permutation(
        np.arange(1, 20001, dtype=np.int64) * 4)
    mixed = np.concatenate([keys[:1024], keys[:1024] + 1,
                            keys[5000:5512], keys[5000:5512] + 2])

    def run(enabled):
        st = BourbonStore(small_cfg(
            filters=FilterConfig(enabled=enabled)))
        for off in range(0, keys.shape[0], 4096):
            st.put_batch(keys[off: off + 4096])
        st.learn_all()
        return st, st.get_batch(mixed)

    st_on, (f_on, v_on) = run(True)
    st_off, (f_off, v_off) = run(False)
    np.testing.assert_array_equal(f_on, f_off)
    np.testing.assert_array_equal(np.asarray(v_on), np.asarray(v_off))
    assert st_on.stats()["filter_screened"] > 0
    assert st_off.stats()["filter_screened"] == 0


def test_sharded_filtered_vs_unfiltered_identical(tmp_path):
    from repro.distributed import ShardedConfig, ShardedStore

    keys = np.random.default_rng(5).permutation(
        np.arange(1, 16001, dtype=np.int64) * 6)
    mixed = np.concatenate([keys[:1024], keys[:1024] + 1])

    def run(enabled, sub):
        st = ShardedStore.open(
            str(tmp_path / sub),
            ShardedConfig(n_shards=2, key_lo=0, key_hi=int(keys.max()) + 2),
            small_cfg(filters=FilterConfig(enabled=enabled)))
        for off in range(0, keys.shape[0], 4096):
            st.put_batch(keys[off: off + 4096])
        out = st.get_batch(mixed)
        state = st.device_state()
        st.close()
        return out, state

    (f_on, v_on), state_on = run(True, "on")
    (f_off, v_off), state_off = run(False, "off")
    assert "fbits" in state_on and "fbits" not in state_off
    np.testing.assert_array_equal(f_on, f_off)
    np.testing.assert_array_equal(np.asarray(v_on), np.asarray(v_off))
    assert f_on[:1024].all() and not f_on[1024:].any()


def test_tombstones_pass_filters_and_report_missing():
    """A deleted key must stay deleted on the filtered path: the tombstone
    passes its level filter (it's in the key set), the engine finds it,
    and the GET reports not-found — zero false 'found's either way."""
    st = BourbonStore(small_cfg())
    keys = np.arange(1, 8001, dtype=np.int64) * 9
    for off in range(0, keys.shape[0], 4096):
        st.put_batch(keys[off: off + 4096])
    st.flush_all()
    dead = keys[::4]
    st.delete_batch(dead)
    st.flush_all()
    f, _ = st.get_batch(keys[:2048])
    assert not f[::4].any()
    assert f[np.arange(2048) % 4 != 0].all()
