"""Minimal stand-in for the hypothesis API used by this test suite.

When the real ``hypothesis`` package is unavailable (bare containers), the
property tests fall back to this shim: each ``@given`` test runs
``max_examples`` times with values drawn from seeded ``random.Random``
instances, so failures are reproducible.  Only the strategies the suite
actually uses are implemented (integers, sampled_from, lists).
"""

from __future__ import annotations

import random

__all__ = ["given", "settings", "st"]

_DEFAULT_MAX_EXAMPLES = 20


class settings:
    """Accepts (and mostly ignores) hypothesis settings kwargs."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(lo, hi))


def _sampled_from(choices) -> _Strategy:
    seq = list(choices)
    return _Strategy(lambda r: r.choice(seq))


def _lists(elem: _Strategy, min_size: int = 0, max_size: int = 10,
           unique: bool = False) -> _Strategy:
    def draw(r):
        n = r.randint(min_size, max_size)
        if not unique:
            return [elem.draw(r) for _ in range(n)]
        out: set = set()
        attempts = 0
        while len(out) < n and attempts < 100 * (n + 1):
            out.add(elem.draw(r))
            attempts += 1
        if len(out) < min_size:   # hypothesis treats min_size as hard
            raise ValueError(
                f"could not draw {min_size} unique elements "
                f"(domain too small?)")
        return list(out)
    return _Strategy(draw)


class st:
    integers = staticmethod(_integers)
    sampled_from = staticmethod(_sampled_from)
    lists = staticmethod(_lists)


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            for example in range(n):
                rng = random.Random(example)
                drawn = [s.draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)
        # NOT functools.wraps: exposing the wrapped signature would make
        # pytest treat the drawn parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
