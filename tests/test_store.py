"""End-to-end store behaviour: correctness of get/put across compactions,
learning modes, CBA accounting, level learning."""

import numpy as np
import pytest

from repro.core import BourbonStore, StoreConfig, LSMConfig, make_dataset
from repro.core.engine import EngineConfig


def small_cfg(**kw):
    lsm = LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                    l1_cap_records=1 << 13)
    return StoreConfig(lsm=lsm, engine=EngineConfig(seg_cap=2048), **kw)


@pytest.fixture(scope="module")
def loaded():
    keys = make_dataset("osm", 1 << 15, seed=11)
    return keys


@pytest.mark.parametrize("mode,policy,gran", [
    ("wisckey", "never", "file"),
    ("bourbon", "always", "file"),
    ("bourbon", "cba", "file"),
    ("bourbon", "always", "level"),
])
def test_get_returns_inserted(loaded, mode, policy, gran):
    keys = loaded
    st = BourbonStore(small_cfg(mode=mode, policy=policy, granularity=gran))
    rng = np.random.default_rng(0)
    perm = rng.permutation(keys)
    for off in range(0, keys.shape[0], 4096):
        st.put_batch(perm[off:off + 4096])
    st.flush_all()
    if mode == "bourbon":
        st.learn_all()
    probes = rng.choice(keys, size=4096, replace=False)
    found, _ = st.get_batch(probes)
    assert found.all()
    # negative probes miss
    neg = probes + 1
    mask = ~np.isin(neg, keys)
    found_n, _ = st.get_batch(neg)
    assert not found_n[mask].any()


def test_updates_win(loaded):
    st = BourbonStore(small_cfg(mode="bourbon", policy="always"))
    keys = loaded[:8192]
    v1 = np.zeros((keys.shape[0], 64), np.uint8); v1[:, 0] = 1
    v2 = np.zeros((keys.shape[0], 64), np.uint8); v2[:, 0] = 2
    st.cfg.fetch_values = True
    st.cfg.engine.fetch_values = True
    st.put_batch(keys, v1)
    st.put_batch(keys, v2)   # overwrite
    st.flush_all()
    found, vals = st.get_batch(keys[:1024])
    assert found.all()
    assert (vals[:, 0] == 2).all()


def test_deletes(loaded):
    st = BourbonStore(small_cfg())
    keys = loaded[:4096]
    st.put_batch(keys)
    st.delete_batch(keys[:100])
    st.flush_all()
    found, _ = st.get_batch(keys[:200])
    assert not found[:100].any()
    assert found[100:].all()


def test_compaction_pushes_down(loaded):
    st = BourbonStore(small_cfg())
    rng = np.random.default_rng(1)
    st.put_batch(rng.permutation(loaded))
    st.flush_all()
    depth = [len(l) for l in st.tree.levels]
    assert sum(depth[1:]) > 0, "data should reach lower levels"
    assert st.tree.total_records() == loaded.shape[0]
    # disjointness invariant at levels >= 1
    for li in range(1, 7):
        tabs = sorted(st.tree.levels[li], key=lambda t: t.min_key)
        for a, b in zip(tabs, tabs[1:]):
            assert a.max_key < b.min_key


def test_cba_skips_learning_under_writes(loaded):
    """With heavy writes + no reads, benefit ~ 0 => CBA must skip files once
    bootstrapped (guideline 4)."""
    keys = loaded
    st_always = BourbonStore(small_cfg(mode="bourbon", policy="always"))
    st_cba = BourbonStore(small_cfg(mode="bourbon", policy="cba"))
    rng = np.random.default_rng(3)
    for s in (st_always, st_cba):
        s.put_batch(rng.permutation(keys[: 1 << 14]))
        s.flush_all()
    # write-heavy phase: no lookups at all
    for s in (st_always, st_cba):
        for _ in range(12):
            s.put_batch(rng.choice(keys, 4096))
        s.drain_learning()
    assert st_cba.executor.learn_time_us <= st_always.executor.learn_time_us
    assert st_cba.cba.decisions["skipped"] > 0


def test_level_learning_invalidated_by_writes(loaded):
    st = BourbonStore(small_cfg(mode="bourbon", policy="always",
                                granularity="level"))
    rng = np.random.default_rng(4)
    st.put_batch(rng.permutation(loaded[: 1 << 14]))
    st.flush_all()
    st.learn_all()
    assert any(m is not None for m in st.level_models)
    ver_before = list(st.tree.level_version)
    for _ in range(8):
        st.put_batch(rng.choice(loaded, 4096))
    assert st.tree.level_version != ver_before
    # changed levels must have dropped their models
    for i in range(1, 7):
        if st.tree.level_version[i] != ver_before[i]:
            assert st.level_models[i] is None or st.executor.level_attempts > 0


def test_model_path_fraction_reported(loaded):
    st = BourbonStore(small_cfg(mode="bourbon", policy="always"))
    rng = np.random.default_rng(5)
    st.put_batch(rng.permutation(loaded))
    st.flush_all()
    st.learn_all()
    st.get_batch(rng.choice(loaded, 4096))
    s = st.stats()
    assert s["model_path_frac"] > 0.99
    assert s["space_overhead"] < 0.05   # paper: 0-2%
