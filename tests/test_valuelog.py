"""ValueLog edge cases the GC path relies on: out-of-range/tombstone
pointers, device-view invalidation across growth, and tombstone shadowing
through the store."""

import numpy as np

from repro.core import BourbonStore, LSMConfig, StoreConfig
from repro.core.valuelog import ValueLog


def test_get_batch_np_out_of_range_and_negative():
    vl = ValueLog(value_size=8, capacity=16)
    vals = np.full((4, 8), 7, np.uint8)
    ptrs = vl.append_batch(vals)
    np.testing.assert_array_equal(ptrs, [0, 1, 2, 3])
    probe = np.array([-1, 0, 3, 4, 1 << 40], np.int64)  # tombstone, ok, ok,
    out = vl.get_batch_np(probe)                        # past head, absurd
    assert (out[0] == 0).all()       # negative (tombstone) -> zeros
    assert (out[1] == 7).all()
    assert (out[2] == 7).all()
    assert (out[3] == 0).all()       # >= head -> zeros, no wraparound read
    assert (out[4] == 0).all()
    # the clamp must not have written through to live slots
    assert (vl.get_batch_np(np.array([0], np.int64)) == 7).all()


def test_device_view_tracks_growth():
    vl = ValueLog(value_size=4, capacity=4)   # tiny: force arena doubling
    a = vl.append_batch(np.full((3, 4), 1, np.uint8))
    dv1 = vl.device_view()
    assert dv1.shape == (3, 4)
    b = vl.append_batch(np.full((6, 4), 2, np.uint8))   # grows past capacity
    dv2 = vl.device_view()                              # must be invalidated
    assert dv2.shape == (9, 4)
    assert (np.asarray(dv2)[np.asarray(a)] == 1).all()
    assert (np.asarray(dv2)[np.asarray(b)] == 2).all()
    # stale view object unchanged (functional), fresh view has the appends
    assert dv1.shape == (3, 4)


def test_append_kv_matches_append_batch():
    vl = ValueLog(value_size=4)
    k = np.arange(5, dtype=np.int64)
    s = np.arange(5, dtype=np.int64)
    v = np.full((5, 4), 9, np.uint8)
    ptrs = vl.append_kv(k, s, v)
    np.testing.assert_array_equal(ptrs, np.arange(5))
    assert (vl.get_batch_np(ptrs) == 9).all()


def test_store_delete_batch_tombstone_shadowing():
    cfg = StoreConfig(mode="wisckey", policy="never", value_size=8,
                      lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                    l1_cap_records=1 << 13))
    st = BourbonStore(cfg)
    keys = np.arange(1, 2001, dtype=np.int64) * 3
    st.put_batch(keys)
    st.delete_batch(keys[:500])
    st.flush_all()                       # tombstones flushed over the puts
    found, vptr = st.get_batch(keys)
    assert not found[:500].any()         # tombstone shadows older version
    assert found[500:].all()
    assert (vptr[:500] == -1).all()      # reported vptr is the tombstone
    # deleting again (already-dead keys) stays not-found
    st.delete_batch(keys[:100])
    st.flush_all()
    found, _ = st.get_batch(keys[:500])
    assert not found.any()
    # re-put resurrects with a fresh value pointer
    st.put_batch(keys[:250])
    found, vptr = st.get_batch(keys[:500])
    assert found[:250].all() and not found[250:].any()
    assert (vptr[:250] >= 0).all()
