"""Distributed range-partitioned store: correctness on a local mesh, and
the durable ShardedStore lifecycle (kill → reopen from shard directories →
serve through the shard_map path).  Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh) to
exercise the real multi-device mesh; on one device the mesh tests fall
back to n_shards=1 or skip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LSMConfig, StoreConfig
from repro.core.datasets import make_dataset
from repro.core.distributed import (KEY_SENTINEL, DistStoreConfig,
                                    build_dist_get, build_dist_state,
                                    build_dist_state_from_shards,
                                    dist_get_local)
from repro.core.engine import EngineConfig
from repro.core.jaxcompat import make_mesh, set_mesh
from repro.distributed import ShardedConfig, ShardedStore, load_shard_snapshot


def test_local_shard_lookup():
    keys = make_dataset("osm", 4096, seed=3)
    vptrs = np.arange(4096, dtype=np.int64)
    cfg = DistStoreConfig(n_keys=4096, probe_batch=256)
    state = build_dist_state(keys, vptrs, n_shards=4, cfg=cfg)
    rng = np.random.default_rng(0)
    probes = jnp.asarray(rng.choice(keys, 256))
    # probe each shard; union of hits must cover every probe exactly once
    hits = np.zeros(256, np.int32)
    vals = np.zeros(256, np.int64)
    for s in range(4):
        shard = {k: jnp.asarray(v[s: s + 1]) for k, v in state.items()}
        h, v = dist_get_local(shard, probes, cfg.delta)
        hits += np.asarray(h, np.int32)
        vals += np.where(np.asarray(h), np.asarray(v), 0)
    assert (hits == 1).all()
    np.testing.assert_array_equal(
        vals, np.searchsorted(keys, np.asarray(probes)))


def test_dist_get_shardmap_single_device():
    """shard_map path on the 1-device CPU mesh (n_shards=1)."""
    keys = make_dataset("ar", 2048, seed=5)
    vptrs = np.arange(2048, dtype=np.int64)
    cfg = DistStoreConfig(n_keys=2048, probe_batch=128)
    mesh = make_mesh((1,), ("data",), axis_type="Explicit")
    state_np = build_dist_state(keys, vptrs, n_shards=1, cfg=cfg)
    state = {k: jnp.asarray(v) for k, v in state_np.items()}
    fn = build_dist_get(mesh, cfg)
    rng = np.random.default_rng(1)
    pos = rng.choice(keys, 64)
    neg = pos + 1
    probes = jnp.asarray(np.concatenate([pos, neg]))
    with set_mesh(mesh):
        found, vptr = fn(state, probes)
    found = np.asarray(found)
    assert found[:64].all()
    miss_mask = ~np.isin(np.asarray(neg), keys)
    assert not found[64:][miss_mask].any()
    np.testing.assert_array_equal(np.asarray(vptr)[:64],
                                  np.searchsorted(keys, pos))


def test_empty_shard_masked_from_sentinel_probe():
    """A shard with no records keeps lo = hi = KEY_SENTINEL; a probe equal
    to the sentinel must not match it (it would index a zeroed model)."""
    keys = np.array([10, 20, 30, 40, 50], dtype=np.int64)  # 4 shards -> last empty
    vptrs = np.arange(5, dtype=np.int64)
    cfg = DistStoreConfig(n_keys=5, probe_batch=4)
    state = build_dist_state(keys, vptrs, n_shards=4, cfg=cfg)
    assert state["n"][3] == 0
    probes = jnp.asarray(np.array([KEY_SENTINEL, 10, KEY_SENTINEL - 1, 50],
                                  dtype=np.int64))
    hits = np.zeros(4, np.int32)
    for s in range(4):
        shard = {k: jnp.asarray(v[s: s + 1]) for k, v in state.items()}
        h, _ = dist_get_local(shard, probes, cfg.delta)
        hits += np.asarray(h, np.int32)
    np.testing.assert_array_equal(hits, [0, 1, 0, 1])


def test_build_dist_state_from_shards_variable_sizes():
    """The durable-plane builder sizes geometry to the live maxima, so
    shards recovered from directories of very different sizes stack."""
    rng = np.random.default_rng(9)
    k0 = np.sort(rng.choice(1 << 40, 5000, replace=False)).astype(np.int64)
    k1 = np.sort(rng.choice(1 << 40, 37, replace=False) + (1 << 41)).astype(np.int64)
    snaps = [(k0, np.arange(5000, dtype=np.int64)),
             (np.empty(0, np.int64), np.empty(0, np.int64)),
             (k1, np.arange(37, dtype=np.int64))]
    state = build_dist_state_from_shards(snaps, delta=8)
    assert state["keys"].shape[0] == 3
    np.testing.assert_array_equal(state["n"], [5000, 0, 37])
    probes = jnp.asarray(np.concatenate([k0[:64], k1, k0[:10] + 1]))
    hits = np.zeros(probes.shape[0], np.int32)
    vals = np.zeros(probes.shape[0], np.int64)
    for s in range(3):
        shard = {k: jnp.asarray(v[s: s + 1]) for k, v in state.items()}
        h, v = dist_get_local(shard, probes, 8)
        hits += np.asarray(h, np.int32)
        vals += np.where(np.asarray(h), np.asarray(v), 0)
    assert (hits[:101] == 1).all() and (hits[101:] == 0).all()
    np.testing.assert_array_equal(vals[:64], np.arange(64))
    np.testing.assert_array_equal(vals[64:101], np.arange(37))


# ------------------------------------------------------------- ShardedStore

def _shard_store_cfg(**kw):
    defaults = dict(granularity="level", policy="always", value_size=16,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _values_for(keys: np.ndarray, version: int, value_size: int = 16):
    v = np.zeros((keys.shape[0], value_size), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _sharded(tmp_path, keys, n_shards):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    scfg = ShardedConfig(n_shards=n_shards, boundaries=bounds)
    return ShardedStore.open(str(tmp_path / "db"), scfg, _shard_store_cfg())


def test_sharded_store_roundtrip_values_and_tombstones(tmp_path):
    rng = np.random.default_rng(0)
    keys = rng.permutation(np.arange(1, 12001, dtype=np.int64) * 7)
    st = _sharded(tmp_path, keys, n_shards=2)
    for off in range(0, keys.shape[0], 2048):
        ks = keys[off: off + 2048]
        st.put_batch(ks, _values_for(ks, 0))
    # overwrites route to the same shard; tombstones shadow
    st.put_batch(keys[:2000], _values_for(keys[:2000], 1))
    st.delete_batch(keys[2000:3000])
    probes = np.concatenate([keys, keys[:500] + 1])
    found, vals = st.get_batch(probes, with_values=True)
    assert found[:2000].all() and (vals[:2000, 1] == 1).all()
    assert not found[2000:3000].any()
    assert found[3000:12000].all() and (vals[3000:12000, 1] == 0).all()
    miss = ~np.isin(keys[:500] + 1, keys)
    assert not found[12000:][miss].any()
    st.close()


def test_sharded_store_kill_reopen_from_directories(tmp_path):
    """The acceptance scenario: killed after N batched puts, the store
    reopens from its per-shard directories alone and answers a mixed
    hit/miss GET through the shard_map path, with persisted file- and
    level-models serving lookups before any learning job runs."""
    rng = np.random.default_rng(1)
    keys = rng.permutation(np.arange(1, 20001, dtype=np.int64) * 3)
    n_shards = 4 if len(jax.devices()) >= 4 else 2
    st = _sharded(tmp_path, keys, n_shards)
    flushed, tail = keys[:16384], keys[16384:17000]
    for off in range(0, flushed.shape[0], 4096):
        ks = flushed[off: off + 4096]
        st.put_batch(ks, _values_for(ks, 0))
    st.flush_all()
    st.learn_all()                     # file + level models, all persisted
    st.put_batch(tail, _values_for(tail, 0))   # WAL-only at kill time
    del st  # CRASH: no close
    import gc
    gc.collect()

    st2 = ShardedStore.open(str(tmp_path / "db"))   # directories alone
    s = st2.stats()
    assert s["n_shards"] == n_shards
    assert s["files_learned"] == 0                  # nothing relearned
    assert s["models_recovered"] > 0
    assert s["level_models_recovered"] > 0
    assert all(not sh.executor.queue and not sh.executor.running
               for sh in st2.shards)
    # mixed GET: flushed keys (snapshot path), WAL-recovered keys
    # (memtable overlay), and misses
    probes = np.concatenate([flushed[:4000], tail, flushed[:500] + 1])
    found, vals = st2.get_batch(probes, with_values=True)
    n_hit = 4000 + tail.shape[0]
    assert found[:n_hit].all()
    assert (vals[:n_hit, 0] == (probes[:n_hit] % 251)).all()
    miss = ~np.isin(flushed[:500] + 1, keys[:17000])
    assert not found[n_hit:][miss].any()
    # the GET ran with zero learning jobs: persisted models served it
    assert all(sh.executor.jobs_done == 0 for sh in st2.shards)
    # per-shard engine path is model-pure too (no baseline lookups)
    f, _ = st2.shards[0].get_batch(flushed[:512])
    assert st2.shards[0].lookups_baseline_path == 0
    st2.close()

    # topology guards: wrong shard count / boundaries refused, and a lost
    # SHARDS.json over live shard directories must never re-create one
    with pytest.raises(ValueError, match="shards"):
        ShardedStore.open(str(tmp_path / "db"),
                          ShardedConfig(n_shards=n_shards + 1))
    with pytest.raises(ValueError, match="boundaries"):
        ShardedStore.open(str(tmp_path / "db"),
                          ShardedConfig(n_shards=n_shards,
                                        boundaries=tuple(
                                            range(1, n_shards))))
    os.unlink(str(tmp_path / "db" / "SHARDS.json"))
    with pytest.raises(RuntimeError, match="SHARDS.json"):
        ShardedStore.open(str(tmp_path / "db"))


def test_sharded_config_rejects_duplicate_boundaries():
    with pytest.raises(ValueError, match="ascending"):
        ShardedConfig(n_shards=3, boundaries=(100, 100)).splits()
    with pytest.raises(ValueError, match="ascending"):
        ShardedConfig(n_shards=3, boundaries=(200, 100)).splits()
    with pytest.raises(ValueError, match="ascending"):
        ShardedConfig(n_shards=4, boundaries=(100, 200)).splits()


def test_sharded_state_epoch_refreshes_on_memtable_roll(tmp_path):
    rng = np.random.default_rng(2)
    keys = rng.permutation(np.arange(1, 6001, dtype=np.int64) * 11)
    st = _sharded(tmp_path, keys, n_shards=2)
    small = keys[:512]
    st.put_batch(small, _values_for(small, 0))
    f, _ = st.get_batch(small)         # served by the memtable overlay
    assert f.all()
    e0 = st.state_epoch
    for off in range(0, keys.shape[0], 2048):   # enough to roll memtables
        ks = keys[off: off + 2048]
        st.put_batch(ks, _values_for(ks, 1))
    st.flush_all()
    f, _ = st.get_batch(keys)          # now served by the snapshot path
    assert f.all()
    assert st.state_epoch > e0         # device state refreshed on the roll
    # a pure read does not rebuild the state
    e1 = st.state_epoch
    st.get_batch(keys[:256])
    assert st.state_epoch == e1
    st.close()


def test_load_shard_snapshot_matches_live_tree(tmp_path):
    rng = np.random.default_rng(3)
    keys = rng.permutation(np.arange(1, 8001, dtype=np.int64) * 5)
    st = _sharded(tmp_path, keys, n_shards=2)
    st.put_batch(keys, _values_for(keys, 0))
    st.delete_batch(keys[:1000])
    st.flush_all()
    from repro.distributed import merge_live
    want = [merge_live(list(sh.tree.all_files())) for sh in st.shards]
    st.close()
    for i, (wk, wv) in enumerate(want):
        gk, gv = load_shard_snapshot(str(tmp_path / "db" / f"shard-{i}"))
        np.testing.assert_array_equal(gk, wk)
        np.testing.assert_array_equal(gv, wv)
        assert not np.isin(keys[:1000], gk).any()


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices for a 4-shard mesh")
def test_sharded_store_uses_shard_map_on_multidevice(tmp_path):
    rng = np.random.default_rng(4)
    keys = rng.permutation(np.arange(1, 16001, dtype=np.int64) * 13)
    st = _sharded(tmp_path, keys, n_shards=4)
    assert st.uses_shard_map
    st.put_batch(keys, _values_for(keys, 0))
    st.flush_all()
    probes = np.concatenate([keys[:4096], keys[:1024] + 1])
    found, _ = st.get_batch(probes)
    assert found[:4096].all()
    miss = ~np.isin(keys[:1024] + 1, keys)
    assert not found[4096:][miss].any()
    st.close()
