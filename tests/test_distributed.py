"""Distributed range-partitioned store: correctness on a local mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.datasets import make_dataset
from repro.core.distributed import (DistStoreConfig, build_dist_get,
                                    build_dist_state, dist_get_local)
from repro.core.jaxcompat import make_mesh, set_mesh


def test_local_shard_lookup():
    keys = make_dataset("osm", 4096, seed=3)
    vptrs = np.arange(4096, dtype=np.int64)
    cfg = DistStoreConfig(n_keys=4096, probe_batch=256)
    state = build_dist_state(keys, vptrs, n_shards=4, cfg=cfg)
    rng = np.random.default_rng(0)
    probes = jnp.asarray(rng.choice(keys, 256))
    # probe each shard; union of hits must cover every probe exactly once
    hits = np.zeros(256, np.int32)
    vals = np.zeros(256, np.int64)
    for s in range(4):
        shard = {k: jnp.asarray(v[s: s + 1]) for k, v in state.items()}
        h, v = dist_get_local(shard, probes, cfg.delta)
        hits += np.asarray(h, np.int32)
        vals += np.where(np.asarray(h), np.asarray(v), 0)
    assert (hits == 1).all()
    np.testing.assert_array_equal(
        vals, np.searchsorted(keys, np.asarray(probes)))


def test_dist_get_shardmap_single_device():
    """shard_map path on the 1-device CPU mesh (n_shards=1)."""
    keys = make_dataset("ar", 2048, seed=5)
    vptrs = np.arange(2048, dtype=np.int64)
    cfg = DistStoreConfig(n_keys=2048, probe_batch=128)
    mesh = make_mesh((1,), ("data",), axis_type="Explicit")
    state_np = build_dist_state(keys, vptrs, n_shards=1, cfg=cfg)
    state = {k: jnp.asarray(v) for k, v in state_np.items()}
    fn = build_dist_get(mesh, cfg)
    rng = np.random.default_rng(1)
    pos = rng.choice(keys, 64)
    neg = pos + 1
    probes = jnp.asarray(np.concatenate([pos, neg]))
    with set_mesh(mesh):
        found, vptr = fn(state, probes)
    found = np.asarray(found)
    assert found[:64].all()
    miss_mask = ~np.isin(np.asarray(neg), keys)
    assert not found[64:][miss_mask].any()
    np.testing.assert_array_equal(np.asarray(vptr)[:64],
                                  np.searchsorted(keys, pos))
