"""HLO collective parser (trip-count awareness) + roofline arithmetic."""

import numpy as np
import pytest

from repro.launch.hlo_parse import (collective_breakdown, collective_bytes,
                                    parse_hlo_computations, _shape_bytes,
                                    _trip_count)
from repro.launch.roofline import analyze_cell, model_flops

FAKE_HLO = """\
HloModule test

%body.1 (p: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %ar = bf16[128,256] all-reduce(bf16[128,256] %x), to_apply=%add.0
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], bf16[128,256])) -> pred[] {
  %iv = s32[] get-tuple-element(...)
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(s32[] %iv, s32[] %c), direction=LT
}

%inner (x: f32[64]) -> f32[64] {
  %ag = f32[512] all-gather(f32[64] %x), dimensions={0}
  ROOT %r = f32[64] reduce-scatter(f32[512] %ag), dimensions={0}
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %w = (s32[], bf16[128,256]) while((s32[], bf16[128,256]) %init), \
condition=%cond.1, body=%body.1
  %call1 = f32[64] fusion(f32[64] %z), kind=kLoop, calls=%inner
  %a2a = bf16[32,32] all-to-all(bf16[32,32] %y), dimensions={0}
  ROOT %out = bf16[128,256] get-tuple-element(%w), index=0
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("(f32[2,3], s32[4])") == 24 + 16
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") >= 0


def test_trip_count_extraction():
    comps = parse_hlo_computations(FAKE_HLO)
    assert "cond.1" in comps
    assert _trip_count(comps["cond.1"]) == 24


def test_collective_breakdown_with_while_multiplier():
    bd = collective_breakdown(FAKE_HLO)
    # all-reduce inside the while body: 128*256*2 bytes * 24 trips
    assert bd["all-reduce"] == 128 * 256 * 2 * 24
    # nested fusion call: all-gather f32[512] + reduce-scatter f32[64]
    assert bd["all-gather"] == 512 * 4
    assert bd["reduce-scatter"] == 64 * 4
    # entry-level all-to-all
    assert bd["all-to-all"] == 32 * 32 * 2
    assert collective_bytes(FAKE_HLO) == sum(bd.values())


def test_analyze_cell_terms():
    full = {
        "arch": "qwen2-0.5b", "shape": "train_4k", "mesh": "16x16",
        "n_devices": 256,
        "cost": {"flops": 1e12, "bytes accessed": 1e11},
        "collectives": {"all-gather": 5e9},
        "memory": {"peak_bytes": 8 << 30},
        "compile_s": 1.0,
    }
    u1 = {"cost": {"flops": 4e11, "bytes accessed": 5e10}}
    u2 = {"cost": {"flops": 5e11, "bytes accessed": 6e10}}
    c = analyze_cell(full, u1, u2)
    # qwen2-0.5b has 24 units: total = u2 + 22 * (u2 - u1)
    assert np.isclose(c["flops_per_dev"], 5e11 + 22 * 1e11)
    assert np.isclose(c["bytes_per_dev"], 6e10 + 22 * 1e10)
    assert np.isclose(c["t_collective_s"], 5e9 / 50e9)
    assert c["dominant"] in ("compute", "memory", "collective")
    assert c["fits_hbm"]


def test_model_flops_conventions():
    t = model_flops("qwen2-0.5b", "train_4k")
    p = model_flops("qwen2-0.5b", "prefill_32k")
    d = model_flops("qwen2-0.5b", "decode_32k")
    assert t / p == pytest.approx(3.0, rel=0.01)   # 6ND vs 2ND, same tokens
    assert d < p / 1000                            # one token per seq
    # MoE active < total
    from repro.configs import get_config
    cfg = get_config("mixtral-8x22b")
    assert cfg.active_param_count() < 0.5 * cfg.param_count()
