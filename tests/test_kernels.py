"""Per-kernel interpret-mode validation against the jnp oracles,
sweeping shapes/dtypes, plus hypothesis property tests."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: seeded-random fallback (see _hypothesis_shim)
    from _hypothesis_shim import given, settings, st

from repro.core.bloom import bloom_build_np, bloom_words
from repro.core.datasets import make_dataset
from repro.core.plr import greedy_plr_np
from repro.kernels import ops
from repro.kernels import ref as kref


def _padded_keys(name, n, cap, seed=0):
    keys = make_dataset(name, n, seed=seed)
    pad = np.full(cap, np.iinfo(np.int64).max, np.int64)
    pad[:n] = keys
    return keys, jnp.asarray(pad)


@pytest.mark.parametrize("name", ["linear", "normal", "osm"])
@pytest.mark.parametrize("n,cap,B", [(1000, 1024, 256), (5000, 8192, 512)])
@pytest.mark.parametrize("delta", [4, 8])
def test_plr_lookup_kernel(name, n, cap, B, delta):
    keys, _ = _padded_keys(name, n, cap)
    m = greedy_plr_np(keys, delta=delta, pad_to=512)
    rng = np.random.default_rng(1)
    probes = jnp.asarray(rng.choice(keys, B))
    want = kref.plr_lookup_ref(m.starts, m.slopes, m.intercepts,
                               m.n_segments, probes, jnp.int32(n))
    got = ops.plr_lookup(m.starts, m.slopes, m.intercepts, m.n_segments,
                         probes, n, impl="pallas_interpret", block_b=B)
    # jit-fused FMA vs eager mul+add can differ by one ulp exactly at the
    # .5 rounding boundary -> positions may differ by 1; the bounded-search
    # window (delta+1 slack) absorbs this by construction.
    assert np.abs(np.asarray(got) - np.asarray(want)).max() <= 1
    # positions actually within delta of the true index
    true_idx = np.searchsorted(keys, np.asarray(probes))
    assert np.abs(np.asarray(got) - true_idx).max() <= delta + 1


@pytest.mark.parametrize("name", ["normal", "uspr"])
@pytest.mark.parametrize("delta", [4, 8, 16])
def test_bounded_search_kernel(name, delta):
    n, cap, B = 4000, 4096, 512
    keys, padded = _padded_keys(name, n, cap)
    rng = np.random.default_rng(2)
    hit_probes = rng.choice(keys, B // 2)
    miss_probes = hit_probes + 1  # mostly misses
    probes = jnp.asarray(np.concatenate([hit_probes, miss_probes]))
    true_idx = np.searchsorted(keys, np.asarray(probes)).astype(np.int32)
    jitter = rng.integers(-delta, delta + 1, B).astype(np.int32)
    pos = jnp.asarray(np.clip(true_idx + jitter, 0, n - 1))
    want_idx, want_found = kref.bounded_search_ref(padded, pos, probes,
                                                   jnp.int32(n), delta)
    got_idx, got_found = ops.bounded_search(padded, pos, probes, n,
                                            delta=delta,
                                            impl="pallas_interpret",
                                            block_b=256)
    np.testing.assert_array_equal(np.asarray(got_found), np.asarray(want_found))
    f = np.asarray(want_found)
    np.testing.assert_array_equal(np.asarray(got_idx)[f], np.asarray(want_idx)[f])
    # found iff the probe is a real key whose index is within the window
    in_keys = np.isin(np.asarray(probes), keys)
    within = np.abs(true_idx - np.asarray(pos)) <= delta + 1
    np.testing.assert_array_equal(f, in_keys & within)


@pytest.mark.parametrize("n_keys,k", [(100, 7), (5000, 7), (5000, 4)])
def test_bloom_probe_kernel(n_keys, k):
    keys = make_dataset("uspr", n_keys, seed=3)
    W = bloom_words(n_keys)
    bits = jnp.asarray(bloom_build_np(keys, W, k))
    rng = np.random.default_rng(4)
    B = 512
    probes_np = np.concatenate([rng.choice(keys, B // 2),
                                rng.integers(0, 1 << 52, B // 2)])
    probes = jnp.asarray(probes_np)
    want = kref.bloom_probe_kernel_ref(bits, probes, k, jnp.int32(W))
    got = ops.bloom_probe(bits, probes, W, k_hashes=k,
                          impl="pallas_interpret", block_b=256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # no false negatives ever
    assert np.asarray(want)[: B // 2].all()
    # false positive rate sane for 10 bits/key
    fp = np.asarray(want)[B // 2:][~np.isin(probes_np[B // 2:], keys)]
    assert fp.mean() < 0.1


@pytest.mark.parametrize("name", ["linear", "normal", "osm"])
@pytest.mark.parametrize("block_records", [64, 256])
def test_sstable_search_kernel(name, block_records):
    n, cap, B = 3000, 4096, 512
    keys, padded = _padded_keys(name, n, cap)
    nb = -(-n // block_records)
    NB = max(1, cap // block_records)
    fences = np.full(NB, np.iinfo(np.int64).max, np.int64)
    fences[:nb] = keys[::block_records][:nb]
    fences = jnp.asarray(fences)
    rng = np.random.default_rng(5)
    probes_np = np.concatenate([rng.choice(keys, B // 2),
                                rng.choice(keys, B // 2) + 1])
    probes = jnp.asarray(probes_np)
    want_idx, want_found = kref.sstable_search_ref(
        fences, padded, probes, jnp.int32(nb), jnp.int32(n), block_records)
    got_idx, got_found = ops.sstable_search(
        fences, padded, probes, nb, n, block_records=block_records,
        impl="pallas_interpret", block_b=256)
    np.testing.assert_array_equal(np.asarray(got_found), np.asarray(want_found))
    f = np.asarray(want_found)
    np.testing.assert_array_equal(np.asarray(got_idx)[f], np.asarray(want_idx)[f])
    # oracle sanity: found exactly for real keys
    np.testing.assert_array_equal(f, np.isin(probes_np, keys))


@settings(max_examples=25, deadline=None)
@given(st.integers(100, 2000), st.sampled_from([2, 8, 24]),
       st.integers(0, 2**31))
def test_property_model_path_end_to_end(n, delta, seed):
    """PLR predict + bounded search finds every present key (pipeline
    invariant: model error bound => window always contains the key)."""
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(0, 1 << 50, n * 2, dtype=np.int64))[:n]
    if keys.shape[0] < n:
        return
    cap = 1 << int(np.ceil(np.log2(n)))
    padded = np.full(cap, np.iinfo(np.int64).max, np.int64)
    padded[:n] = keys
    m = greedy_plr_np(keys, delta=delta)
    B = 256
    probes = jnp.asarray(rng.choice(keys, B))
    pos = kref.plr_lookup_ref(m.starts, m.slopes, m.intercepts, m.n_segments,
                              probes, jnp.int32(n))
    idx, found = kref.bounded_search_ref(jnp.asarray(padded), pos, probes,
                                         jnp.int32(n), delta)
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(padded)[np.asarray(idx)],
                                  np.asarray(probes))
