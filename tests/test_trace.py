"""Causal request tracing: sampling discipline, span-graph fan-in /
fan-out edges, cross-thread span handoff under forced out-of-order
IOPool completion, group-commit WAL fan-in, critical-path extraction
into ``server_critical_path_us``, histogram exemplars, EventLog
trace-id stamps, and the Chrome trace-event / Perfetto export — unit
level plus end-to-end through the threaded pipelined server."""

import json
import threading
import time
from types import SimpleNamespace

import numpy as np

from repro.core import LSMConfig, StoreConfig
from repro.core.engine import EngineConfig
from repro.distributed import ShardedConfig, ShardedStore
from repro.io import IOPool, wait_all
from repro.obs import (CRITICAL_STAGES, CausalTracer, MetricsRegistry,
                       NULL_CTRACE, Obs, ObsConfig, SPAN_NAMES)
from repro.server import (PipelineConfig, PipelinedServer, ServerRequest)
from repro.storage.wal import GroupCommitWAL

VALUE_SIZE = 16


def _store_cfg(**kw):
    defaults = dict(granularity="level", policy="always",
                    value_size=VALUE_SIZE, vlog_seg_slots=1 << 9,
                    lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                  l1_cap_records=1 << 13),
                    engine=EngineConfig(seg_cap=4096))
    defaults.update(kw)
    return StoreConfig(**defaults)


def _keys(n, seed=0, stride=7):
    return np.random.default_rng(seed).permutation(
        np.arange(1, n + 1, dtype=np.int64) * stride)


def _sharded(tmp_path, keys, n_shards=2, **kw):
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, n_shards) / n_shards))
    return ShardedStore.open(str(tmp_path / "db"),
                             ShardedConfig(n_shards=n_shards,
                                           boundaries=bounds),
                             _store_cfg(**kw))


def _values(keys, version=0):
    v = np.zeros((keys.shape[0], VALUE_SIZE), np.uint8)
    v[:, 0] = (keys % 251).astype(np.uint8)
    v[:, 1] = version % 251
    return v


def _sample(snap, name, **labels):
    for s in snap[name]["samples"]:
        if dict(s["labels"]) == labels:
            return s["value"]
    raise KeyError((name, labels))


def _req(ctx):
    """join_batch only reads ``.trace`` off a request."""
    return SimpleNamespace(trace=ctx)


# ------------------------------------------------------------------ sampling

def test_admission_sampling_rate():
    ct = CausalTracer(MetricsRegistry(), sample_every=4)
    admits = [ct.admit(tick=i) for i in range(16)]
    traced = [i for i, c in enumerate(admits) if c is not None]
    assert traced == [0, 4, 8, 12]       # first admission always traced
    assert ct.traced_requests == 4
    tids = {admits[i].tid for i in traced}
    assert len(tids) == 4
    # each traced request opened its root + queue_wait spans
    names = [s.name for s in ct.spans()]
    assert names.count("request") == 4 and names.count("queue_wait") == 4


def test_unsampled_request_is_one_identity_test_everywhere():
    ct = CausalTracer(MetricsRegistry(), sample_every=2)
    assert ct.admit() is not None
    assert ct.admit() is None            # downstream sees None
    assert ct.join_batch([_req(None)]) is None
    assert ct.begin_span("dispatch", None) is None
    ct.end_span(None, stage="dispatch")  # None-safe
    ct.complete(None)
    assert ct.completed_requests == 0


def test_null_tracer_is_inert():
    assert NULL_CTRACE.admit() is None
    assert NULL_CTRACE.join_batch([]) is None
    assert NULL_CTRACE.wal_append() is None
    assert NULL_CTRACE.begin_maintenance() is None
    assert NULL_CTRACE.active_tid() == 0
    assert NULL_CTRACE.spans() == []
    assert NULL_CTRACE.to_trace_events()["traceEvents"] == []
    assert "disabled" in NULL_CTRACE.describe_trace(1)


# ---------------------------------------------------------------- span graph

def test_batch_fan_in_links_and_queue_wait_credit():
    ct = CausalTracer(MetricsRegistry(), sample_every=1)
    a, b = ct.admit(), ct.admit()
    time.sleep(0.002)
    bt = ct.join_batch([_req(a), _req(None), _req(b)])
    assert bt.name == "batch" and bt.args["n_requests"] == 3
    # flow links: one per *traced* member, to the member's root span
    assert bt.links == [a.root.sid, b.root.sid]
    # queue_wait spans were closed and credited to each member
    for c in (a, b):
        assert c.queue_span.t1 > 0
        assert c.segments["queue_wait"] > 0
    # a second join does not re-close or double-credit queue spans
    q = a.segments["queue_wait"]
    ct.join_batch([_req(a)])
    assert a.segments["queue_wait"] == q


def test_critical_path_dominant_stage_and_exemplars():
    reg = MetricsRegistry()
    ct = CausalTracer(reg, sample_every=1)
    ctx = ct.admit(tick=2)
    ctx.segments.update({"dispatch": 10.0, "device_compute": 500.0,
                         "value_fetch": 20.0})
    ct.complete(ctx, tick=5)
    assert ctx.root.t1 > 0
    assert ctx.root.args["critical"] == "device_compute"
    assert ctx.root.args["done_tick"] == 5
    snap = reg.snapshot()
    v = _sample(snap, "server_critical_path_us", stage="device_compute")
    assert v["count"] == 1
    # the observation carries the trace id as a bucket exemplar
    ex = list(v["exemplars"].values())
    assert ex and ex[0]["trace_id"] == ctx.tid
    # per-segment exemplars annotate the stage-latency family
    sv = _sample(snap, "server_stage_us", stage="compute")
    assert any(e["trace_id"] == ctx.tid
               for e in sv["exemplars"].values())
    # annotate() never counts as an observation
    assert sv["count"] == 0
    # every critical stage family is pre-bound (present in the snapshot)
    have = {dict(s["labels"])["stage"]
            for s in snap["server_critical_path_us"]["samples"]}
    assert have == set(CRITICAL_STAGES)


def test_describe_trace_tree_and_cross_trace_marker():
    ct = CausalTracer(MetricsRegistry(), sample_every=1)
    a, b = ct.admit(), ct.admit()
    bt = ct.join_batch([_req(a), _req(b)])   # bt rides a's trace id
    dsp = ct.begin_span("dispatch", bt, shard=0)
    ct.end_span(dsp, stage="dispatch")
    ct.end_span(bt)
    ct.complete(a)
    ct.complete(b)
    own = ct.describe_trace(a.tid)
    assert own.startswith(f"trace {a.tid}:")
    assert "-- request" in own and "-- dispatch" in own
    # the batch span belongs to a's trace but links from b's root, so
    # b's view shows it as a cross-trace fan-in
    other = ct.describe_trace(b.tid)
    assert "~> batch" in other
    assert f"links=[{a.root.sid}, {b.root.sid}]" in other
    assert "no spans in ring" in ct.describe_trace(10_000)


# ------------------------------------------------- cross-thread span handoff

def test_cross_thread_handoff_out_of_order_completion():
    """A span begun on the submitting thread and finished inside an
    IOPool worker keeps its parent edge and never tears, even when the
    workers complete in reverse submission order (same forced-reverse
    harness as test_io.py)."""
    ct = CausalTracer(MetricsRegistry(), sample_every=1, ring=256)
    pool = IOPool(workers=4, name="io")
    gate = threading.Event()
    n_tasks = 4
    ctxs, batches, iospans, tasks = [], [], [], []
    for i in range(n_tasks):
        ctx = ct.admit(tick=0)
        bt = ct.join_batch([_req(ctx)])
        iosp = ct.begin_span("io_task", bt, link=bt, keys=8)
        assert iosp.track == threading.current_thread().name

        def task(i=i, iosp=iosp):
            if i == n_tasks - 1:
                gate.set()               # last submitted finishes first
            else:
                gate.wait(5.0)
                time.sleep(0.001 * (n_tasks - i))
            ct.end_span(iosp, retrack=True)

        ctxs.append(ctx)
        batches.append(bt)
        iospans.append(iosp)
        tasks.append(task)
    try:
        wait_all([pool.submit(t) for t in tasks])
    finally:
        pool.close()
    for i, (ctx, bt, iosp) in enumerate(zip(ctxs, batches, iospans)):
        assert iosp.t1 >= iosp.t0 > 0    # ended exactly once, never torn
        assert iosp.parent == bt.sid and iosp.tid == ctx.tid
        assert iosp.links == [bt.sid]
        assert iosp.track.startswith("io-")   # re-stamped to the worker
    # the forced schedule completed the first submission last
    assert iospans[0].t1 == max(s.t1 for s in iospans)
    # export draws each worker's track; flow arrows stay matched
    ev = ct.to_trace_events()["traceEvents"]
    tracks = {e["args"]["name"] for e in ev if e["ph"] == "M"}
    assert any(t.startswith("io-") for t in tracks)


# ------------------------------------------------------------- WAL tracing

def test_group_commit_wal_fan_in(tmp_path):
    """M traced appends collapse into one wal_commit span on the
    committer thread; every append span ends at durability, crediting
    the wal_fsync segment before sync() returns."""
    ct = CausalTracer(MetricsRegistry(), sample_every=1)
    w = GroupCommitWAL(str(tmp_path / "wal.log"))
    w.tracer = ct
    ctx = ct.admit()
    bt = ct.join_batch([_req(ctx)], kind="write")
    assert bt.name == "write_apply"
    ct.set_write(bt)
    arr = np.arange(4, dtype=np.int64)
    for _ in range(3):
        w.append(arr, arr, arr)
    ct.set_write(None)
    w.sync()
    ct.end_span(bt)
    ct.complete(ctx)
    w.close()
    spans = ct.spans()
    appends = [s for s in spans if s.name == "wal_append"]
    commits = [s for s in spans if s.name == "wal_commit"]
    assert len(appends) == 3 and len(commits) == 1
    assert all(s.t1 > 0 and s.tid == ctx.tid for s in appends)
    assert all(s.parent == bt.sid for s in appends)
    cm = commits[0]
    assert cm.args["group"] == 3
    assert set(cm.links) == {s.sid for s in appends}  # fan-in arrows
    assert cm.track == "wal-commit"                   # committer thread
    # durability latency was credited before sync() returned
    assert ctx.segments["wal_fsync"] > 0


def test_untraced_wal_append_is_free_and_crash_drops_spans(tmp_path):
    ct = CausalTracer(MetricsRegistry(), sample_every=1)
    w = GroupCommitWAL(str(tmp_path / "wal.log"))
    w.tracer = ct
    arr = np.arange(4, dtype=np.int64)
    w.append(arr, arr, arr)              # no write armed: no span
    assert [s for s in ct.spans() if s.name == "wal_append"] == []
    ctx = ct.admit()
    bt = ct.join_batch([_req(ctx)], kind="write")
    ct.set_write(bt)
    w.append(arr, arr, arr)
    ct.set_write(None)
    w.crash()                            # queued frame dropped pre-commit
    assert [s for s in ct.spans() if s.name == "wal_commit"] == []


# ------------------------------------------------------- EventLog stamping

def test_gc_event_trace_id_resolves_to_maintenance_span():
    obs = Obs(ObsConfig(sample_every=1, trace_sample_every=1))
    obs.events.log("flush")              # outside any bubble
    msp = obs.ctrace.begin_maintenance(tick=7, kind="bubble")
    obs.events.log("gc", segments_removed=2, cost_us=10.0)
    obs.ctrace.end_maintenance(msp)
    ev = {e["kind"]: e for e in obs.events.tail()}
    assert ev["flush"]["trace_id"] == 0 and "tick" in ev["flush"]
    gc_ev = ev["gc"]
    assert gc_ev["trace_id"] == msp.tid > 0
    assert gc_ev["segments_removed"] == 2
    spans = obs.ctrace.get_trace(gc_ev["trace_id"])
    assert [s.name for s in spans] == ["maintenance"]
    assert spans[0].args == {"tick": 7, "kind": "bubble"}
    assert spans[0].t1 > 0
    assert obs.ctrace.active_tid() == 0  # disarmed after the bubble
    assert "maintenance" in obs.describe_trace(gc_ev["trace_id"])


# ----------------------------------------------------------------- export

def _flow_pairs(events):
    starts = {e["id"]: e for e in events if e["ph"] == "s"}
    finishes = {e["id"]: e for e in events if e["ph"] == "f"}
    return starts, finishes


def _check_trace_events(doc):
    """Structural validity of a Chrome trace-event / Perfetto export."""
    evs = doc["traceEvents"]
    json.dumps(doc)                      # plain JSON types throughout
    meta = [e for e in evs if e["ph"] == "M"]
    body = [e for e in evs if e["ph"] != "M"]
    assert all(e["name"] == "thread_name" for e in meta)
    assert {e["tid"] for e in meta} >= {e["tid"] for e in body}
    # ts monotone non-decreasing, X events complete with dur >= 0
    ts = [e["ts"] for e in body]
    assert ts == sorted(ts) and (not ts or ts[0] >= 0)
    xs = [e for e in body if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 for e in xs)
    assert all(e["ph"] in ("X", "s", "f") for e in body)
    # every flow id has exactly one s and one f, arrow never goes back
    starts, finishes = _flow_pairs(body)
    assert set(starts) == set(finishes)
    for fid, s in starts.items():
        assert finishes[fid]["ts"] >= s["ts"]
        assert finishes[fid]["bp"] == "e"
    return xs, starts


def test_trace_events_structure_unit():
    ct = CausalTracer(MetricsRegistry(), sample_every=1)
    assert ct.to_trace_events() == {"traceEvents": [],
                                    "displayTimeUnit": "ms"}
    a, b = ct.admit(), ct.admit()
    bt = ct.join_batch([_req(a), _req(b)])
    dsp = ct.begin_span("dispatch", bt)
    ssp = ct.begin_span("shard_probe", dsp, link=dsp, shard=1)
    ct.end_span(ssp)
    ct.end_span(dsp, stage="dispatch")
    ct.end_span(bt)
    ct.complete(a)
    ct.complete(b)
    xs, starts = _check_trace_events(ct.to_trace_events())
    names = {e["name"] for e in xs}
    assert {"request", "queue_wait", "batch", "dispatch",
            "shard_probe"} <= names
    # fan-in (2 roots -> batch) + fan-out (dispatch -> shard_probe)
    assert len(starts) == 3
    by_sid = {e["args"]["sid"]: e for e in xs}
    assert by_sid[ssp.sid]["args"]["parent"] == dsp.sid
    assert by_sid[ssp.sid]["args"]["shard"] == 1


# ------------------------------------------------------------- end to end

def test_traced_threaded_pipelined_server_end_to_end(tmp_path):
    """Acceptance: tracing on through the threaded pipelined server with
    group-commit WAL — zero epoch violations, populated critical-path
    histograms with exemplars, a structurally valid Perfetto export
    whose flow links connect request, batch, shard, io-task, and
    wal-commit spans, and EventLog stamps resolving into the ring."""
    keys = _keys(3000, seed=21)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True,
                  wal_group_commit=True)
    srv = PipelinedServer(st, PipelineConfig(
        max_batch_keys=256, max_wait_ticks=0, io_workers=2,
        bubble_every_ticks=8,
        obs=ObsConfig(sample_every=1, trace_sample_every=2,
                      trace_ring=1 << 16)))
    ct = srv.obs.ctrace
    rng = np.random.default_rng(3)
    rid = 0
    # overwrite every key across several rounds so the value log
    # accumulates dead entries — that is what gives the maintenance
    # bubbles auto-GC work to log (mirrors test_pipeline's bubble test)
    for rnd in range(3):
        for off in range(0, keys.shape[0], 500):
            ks = keys[off: off + 500]
            assert srv.submit(
                ServerRequest(rid, "put", ks, _values(ks, version=rnd)))
            rid += 1
            srv.run_until_drained()
    reqs = []
    for _ in range(6):
        for _ in range(8):
            r = ServerRequest(rid, "get", rng.choice(keys, 32))
            assert srv.submit(r)
            reqs.append(r)
            rid += 1
        srv.tick()
    srv.run_until_drained()
    for _ in range(64):                  # idle ticks: maintenance bubbles
        srv.tick()
    assert all(r.done for r in reqs)
    assert srv.stats()["pipeline"]["epoch_violations"] == 0
    assert ct.traced_requests > 0
    assert ct.completed_requests > 0

    # ---- span graph: every expected span name was drawn
    spans = ct.spans()
    by_sid = {s.sid: s for s in spans}
    names = {s.name for s in spans}
    assert {"request", "queue_wait", "batch", "dispatch", "shard_probe",
            "device_compute", "io_task", "value_fetch", "write_apply",
            "wal_append", "wal_commit", "wal_sync",
            "maintenance"} <= names
    assert names <= set(SPAN_NAMES)
    # fan-out: shard probes and io tasks hang off their dispatch span
    for s in spans:
        if s.name in ("shard_probe", "io_task"):
            assert by_sid[s.parent].name == "dispatch"
        if s.name == "batch":            # fan-in from member roots
            assert s.links
            assert all(by_sid[l].name == "request" for l in s.links
                       if l in by_sid)
        if s.name == "wal_commit":       # fan-in from member appends
            assert all(by_sid[l].name == "wal_append" for l in s.links
                       if l in by_sid)
            assert s.track == "wal-commit"
        if s.name == "io_task" and s.t1:
            assert s.track.startswith("io-")

    # ---- critical path: one observation per completed request, with
    # exemplars pointing back at real traces
    snap = srv.obs.snapshot()
    crit = snap["server_critical_path_us"]["samples"]
    assert sum(s["value"]["count"] for s in crit) == \
        ct.completed_requests
    exemplars = [e for s in crit
                 for e in s["value"].get("exemplars", {}).values()]
    assert exemplars
    tid = exemplars[0]["trace_id"]
    assert ct.get_trace(tid)
    text = srv.obs.describe_trace(tid)
    assert text.startswith(f"trace {tid}:") and "request" in text

    # ---- EventLog stamps resolve into the ring
    stamped = [e for e in srv.obs.events.tail() if e["trace_id"] > 0]
    assert stamped                       # bubbles logged maintenance work
    for e in stamped[-4:]:
        assert any(s.name == "maintenance"
                   for s in ct.get_trace(e["trace_id"]))

    # ---- Perfetto export is structurally valid end to end
    xs, _ = _check_trace_events(srv.obs.trace_events())
    assert {"request", "batch", "shard_probe", "io_task",
            "wal_commit"} <= {e["name"] for e in xs}
    st.close()


def test_tracing_disabled_server_serves_and_exports_empty(tmp_path):
    keys = _keys(800, seed=5)
    st = _sharded(tmp_path, keys, n_shards=2, fetch_values=True)
    srv = PipelinedServer(st, PipelineConfig(
        max_wait_ticks=0,
        obs=ObsConfig(sample_every=1, trace_sample_every=0)))
    assert srv.obs.ctrace is NULL_CTRACE
    rid = 0
    assert srv.submit(ServerRequest(rid, "put", keys, _values(keys)))
    srv.run_until_drained()
    r = ServerRequest(1, "get", keys[:64])
    assert srv.submit(r)
    srv.run_until_drained()
    assert r.done
    assert srv.obs.trace_events()["traceEvents"] == []
    assert "disabled" in srv.obs.describe_trace(1)
    snap = srv.obs.snapshot()
    assert _sample(snap, "obs_traced_requests_total") == 0
    st.close()
