#!/usr/bin/env python
"""bourbonlint CLI — static invariant checks for the Bourbon repo.

Usage:
    python scripts/lint.py [paths...] [--rules HOTSYNC,DURORDER]
                           [--baseline .bourbonlint-baseline.json]
                           [--update-baseline] [--json]
                           [--show-baselined]
    python scripts/lint.py --report dead-modules

Exit status is 1 when there are findings not covered by a justified
suppression or the baseline (or, for dead-modules, when a module outside
the allowlist is unreachable), else 0.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import (DEAD_MODULE_ALLOWLIST, SUPPRESS, apply_baseline,
                            dead_module_report, default_rules, load_baseline,
                            make_baseline, run_lint, save_baseline)


def _report_dead_modules(as_json: bool) -> int:
    rep = dead_module_report(REPO_ROOT, DEAD_MODULE_ALLOWLIST)
    if as_json:
        print(json.dumps(rep, indent=1))
    else:
        print(f"import graph: {rep['reachable']}/{rep['total']} modules "
              f"reachable from {rep['roots']} root files")
        for mod in rep["quarantined"]:
            print(f"  quarantined (allowlisted): {mod}")
        for mod in rep["dead"]:
            print(f"  DEAD: {mod} is unreachable from repro/__init__, "
                  f"tests, benchmarks, and scripts")
        if rep["dead"]:
            print(f"{len(rep['dead'])} dead module(s) outside the "
                  f"allowlist; delete them or add them to "
                  f"DEAD_MODULE_ALLOWLIST with a reason")
    return 1 if rep["dead"] else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bourbonlint", description=__doc__)
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(REPO_ROOT, "src", "repro")])
    ap.add_argument("--rules", help="comma-separated rule ids to run")
    ap.add_argument("--baseline", help="baseline JSON file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to cover current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--show-baselined", action="store_true",
                    help="also print baselined/suppressed findings")
    ap.add_argument("--report", choices=["dead-modules"],
                    help="run a report instead of the rule checks")
    args = ap.parse_args(argv)

    if args.report == "dead-modules":
        return _report_dead_modules(args.as_json)

    only = args.rules.split(",") if args.rules else None
    rules = default_rules(REPO_ROOT, only=only)
    findings = run_lint(args.paths, rules, root=REPO_ROOT)

    expired = []
    if args.baseline:
        baseline = load_baseline(args.baseline)
        expired = apply_baseline(findings, baseline)
        if args.update_baseline:
            save_baseline(args.baseline, make_baseline(findings))
            print(f"baseline rewritten: {args.baseline}")
            return 0

    new = [f for f in findings if not f.suppressed and not f.baselined]
    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in findings
                         if args.show_baselined
                         or (not f.suppressed and not f.baselined)],
            "new": len(new),
            "expired_baseline": expired,
        }, indent=1))
    else:
        for f in findings:
            if f.suppressed or f.baselined:
                if args.show_baselined:
                    tag = "suppressed" if f.suppressed else "baselined"
                    print(f"  ({tag}) {f.render()}")
                continue
            print(f.render())
        for e in expired:
            print(f"note: baseline entry no longer occurs "
                  f"({e['rule']} {e['path']} {e['message']!r} "
                  f"x{e['count']}); prune with --update-baseline")
        n_supp = sum(1 for f in findings if f.suppressed)
        n_base = sum(1 for f in findings if f.baselined)
        print(f"bourbonlint: {len(new)} new finding(s), "
              f"{n_base} baselined, {n_supp} suppressed")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
