#!/usr/bin/env python
"""CI gate: the host I/O pool must not change a single result bit.

The threaded serving path (``io_workers > 0``) moves each batch's
resolve — device sync, overlay merge, value-log fetch — onto pool
workers, and the group-commit WAL moves fsyncs onto a committer thread.
Both are *performance* planes: worker count, scheduling, and completion
order must be invisible in every answer the server gives.  This script
runs one fixed mixed workload through the pipelined server with
``io_workers`` 0 (inline — the seed's serial semantics), 1, and 4 on
identical fresh stores (group-commit WAL on, so the committer thread is
exercised too) and fails unless all three produce byte-identical
found/value arrays per request, identical epoch vectors, and
``epoch_violations == 0``.

Exit status 0 = identical; 1 = any divergence (printed per request).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import LSMConfig, StoreConfig  # noqa: E402
from repro.core.engine import EngineConfig  # noqa: E402
from repro.core.filters import FilterConfig  # noqa: E402
from repro.distributed import ShardedConfig, ShardedStore  # noqa: E402
from repro.server import (PipelineConfig, PipelinedServer,  # noqa: E402
                          ServerRequest)

N_KEYS = 1 << 12
N_SHARDS = 4
CLIENTS = 8
ROUNDS = 6
KEYS_PER_REQ = 64
POOL_SIZES = (0, 1, 4)


def _open_store(path: str, keys: np.ndarray) -> ShardedStore:
    bounds = tuple(int(b) for b in
                   np.quantile(keys, np.arange(1, N_SHARDS) / N_SHARDS))
    # filters explicitly on: the screen/host-answer paths must stay
    # deterministic under the threaded resolve too (the +1 miss keys in
    # the streams exercise them)
    cfg = StoreConfig(granularity="level", policy="always", value_size=16,
                      vlog_seg_slots=1 << 9, wal_group_commit=True,
                      filters=FilterConfig(enabled=True),
                      lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                    l1_cap_records=1 << 13),
                      engine=EngineConfig(seg_cap=4096))
    st = ShardedStore.open(path, ShardedConfig(n_shards=N_SHARDS,
                                               boundaries=bounds), cfg)
    for off in range(0, keys.shape[0], 1 << 11):
        st.put_batch(keys[off: off + (1 << 11)])
    st.flush_all()
    st.learn_all()
    return st


def _streams(keys: np.ndarray) -> list[list[tuple[str, np.ndarray]]]:
    """Fixed per-client (op, keys) streams: mostly GETs (some keys
    absent), a few PUT barriers so write drains interleave with the
    threaded resolves."""
    rng = np.random.default_rng(7)
    universe = np.concatenate([keys, keys + 1])   # +1 keys mostly miss
    streams = []
    for c in range(CLIENTS):
        reqs = []
        for r in range(ROUNDS):
            if c == 0 and r % 3 == 2:
                reqs.append(("put",
                             rng.choice(keys, KEYS_PER_REQ)
                             .astype(np.int64)))
            else:
                reqs.append(("get",
                             rng.choice(universe, KEYS_PER_REQ)
                             .astype(np.int64)))
        streams.append(reqs)
    return streams


def _run(io_workers: int, keys: np.ndarray, streams) -> tuple[list, int]:
    d = tempfile.mkdtemp(prefix=f"bourbon_iodet_w{io_workers}_")
    try:
        st = _open_store(os.path.join(d, "db"), keys)
        srv = PipelinedServer(st, PipelineConfig(
            max_batch_keys=256, max_wait_ticks=0, queue_capacity=64,
            max_batches_per_tick=4, max_inflight=4, carry=1,
            io_workers=io_workers))
        reqs = []
        rid = 0
        nxt = [0] * CLIENTS
        pend: list[ServerRequest | None] = [None] * CLIENTS
        served = 0
        total = CLIENTS * ROUNDS
        try:
            while served < total:
                for c in range(CLIENTS):
                    if pend[c] is not None or nxt[c] >= ROUNDS:
                        continue
                    op, ks = streams[c][nxt[c]]
                    r = ServerRequest(rid, op, ks)
                    if srv.submit(r):
                        rid += 1
                        pend[c] = r
                        nxt[c] += 1
                        reqs.append(r)
                srv.tick()
                for c in range(CLIENTS):
                    if pend[c] is not None and pend[c].done:
                        pend[c] = None
                        served += 1
            violations = srv.stats()["pipeline"]["epoch_violations"]
        finally:
            srv.shutdown()
            st.close()
        out = []
        for r in reqs:
            if r.op == "get":
                out.append((r.rid,
                            np.asarray(r.found).tobytes(),
                            np.asarray(r.result).tobytes(),
                            tuple(r.epochs_served or ())))
            else:
                out.append((r.rid, b"put", b"", ()))
        return out, violations
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main() -> int:
    rng = np.random.default_rng(3)
    keys = rng.permutation(np.arange(1, N_KEYS + 1, dtype=np.int64) * 5)
    streams = _streams(keys)
    results = {}
    for w in POOL_SIZES:
        results[w], violations = _run(w, keys, streams)
        if violations != 0:
            print(f"FAIL: io_workers={w} epoch_violations={violations}")
            return 1
        print(f"io_workers={w}: {len(results[w])} requests served, "
              f"epoch_violations=0")
    ref = results[POOL_SIZES[0]]
    ok = True
    for w in POOL_SIZES[1:]:
        for (rid, f0, v0, e0), (rid2, f1, v1, e1) in zip(ref, results[w]):
            if (rid, f0, v0, e0) != (rid2, f1, v1, e1):
                print(f"FAIL: io_workers={w} diverges from inline at "
                      f"request {rid}")
                ok = False
                break
    if not ok:
        return 1
    print(f"OK: io_workers {POOL_SIZES} byte-identical across "
          f"{len(ref)} requests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
